//! TOSCA templates: the user-facing entrypoint of the deployment flow.
//!
//! The paper's flow starts from a curated TOSCA template ("SLURM Elastic
//! cluster" in the Orchestrator dashboard). This module parses the
//! TOSCA-simple-profile subset those templates use (via
//! [`crate::util::yaml`]) into a typed [`ClusterTemplate`], and ships the
//! curated templates as built-ins.

use anyhow::{bail, Context};

use crate::netsim::Cipher;
use crate::util::yaml::{self, Yaml};

/// Supported LRMS flavours (the paper's stack supports SLURM, HTCondor,
/// Mesos, Kubernetes, Nomad via CLUES plugins; we implement two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrmsKind {
    Slurm,
    HtCondor,
}

impl LrmsKind {
    pub fn name(self) -> &'static str {
        match self {
            LrmsKind::Slurm => "slurm",
            LrmsKind::HtCondor => "htcondor",
        }
    }
}

/// Host sizing requirements for a node template.
#[derive(Debug, Clone, PartialEq)]
pub struct HostRequirements {
    pub num_cpus: u32,
    pub mem_gb: f64,
}

/// Elasticity bounds from the `scalable` capability.
#[derive(Debug, Clone, PartialEq)]
pub struct Scalable {
    /// Initially deployed working nodes.
    pub count: u32,
    pub min_instances: u32,
    pub max_instances: u32,
}

/// Typed cluster template — everything the orchestrator needs.
#[derive(Debug, Clone)]
pub struct ClusterTemplate {
    pub name: String,
    pub description: String,
    pub lrms: LrmsKind,
    pub front_end: HostRequirements,
    pub worker: HostRequirements,
    pub scalable: Scalable,
    /// OpenVPN cipher for the overlay tunnels (§3.5.6).
    pub vpn_cipher: Cipher,
    /// Allow worker provisioning to burst beyond the first site.
    pub hybrid: bool,
    /// Seconds a node must stay idle before CLUES powers it off.
    pub idle_timeout_s: f64,
    /// Deploy a hot-backup central point (redundant star, Fig. 6).
    pub redundant_central_point: bool,
}

impl ClusterTemplate {
    /// Validate semantic constraints a syntactically fine template can
    /// still violate.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.scalable.max_instances < self.scalable.min_instances {
            bail!("max_instances < min_instances");
        }
        if self.scalable.count > self.scalable.max_instances {
            bail!("initial count {} exceeds max_instances {}",
                  self.scalable.count, self.scalable.max_instances);
        }
        if self.front_end.num_cpus == 0 || self.worker.num_cpus == 0 {
            bail!("nodes need at least one CPU");
        }
        if self.idle_timeout_s < 0.0 {
            bail!("idle_timeout must be non-negative");
        }
        Ok(())
    }
}

/// The curated "SLURM Elastic cluster" template, mirroring
/// indigo-dc/tosca-templates, restricted to the YAML subset we parse.
pub const SLURM_ELASTIC_TEMPLATE: &str = r#"
tosca_definitions_version: tosca_simple_yaml_1_0
description: Deploy an elastic SLURM cluster across hybrid cloud sites
metadata:
  display_name: SLURM Elastic cluster
topology_template:
  inputs:
    wn_num:
      type: integer
      default: 2
    wn_max:
      type: integer
      default: 5
    hybrid:
      type: boolean
      default: true
  node_templates:
    elastic_cluster:
      type: tosca.nodes.indigo.ElasticCluster
      properties:
        lrms: slurm
        idle_timeout: 600
        vpn_cipher: aes-256-gcm
        redundant_central_point: false
    lrms_front_end:
      type: tosca.nodes.indigo.LRMS.FrontEnd.Slurm
      capabilities:
        host:
          properties:
            num_cpus: 2
            mem_size: 4 GB
    lrms_wn:
      type: tosca.nodes.indigo.LRMS.WorkerNode.Slurm
      capabilities:
        scalable:
          properties:
            count: 2
            min_instances: 0
            max_instances: 5
        host:
          properties:
            num_cpus: 2
            mem_size: 4 GB
"#;

/// The same cluster shape on HTCondor (plugin-coverage template).
pub const HTCONDOR_ELASTIC_TEMPLATE: &str = r#"
tosca_definitions_version: tosca_simple_yaml_1_0
description: Deploy an elastic HTCondor pool across hybrid cloud sites
metadata:
  display_name: HTCondor Elastic cluster
topology_template:
  node_templates:
    elastic_cluster:
      type: tosca.nodes.indigo.ElasticCluster
      properties:
        lrms: htcondor
        idle_timeout: 600
        vpn_cipher: aes-128-gcm
        redundant_central_point: true
    lrms_front_end:
      type: tosca.nodes.indigo.LRMS.FrontEnd.HTCondor
      capabilities:
        host:
          properties:
            num_cpus: 2
            mem_size: 4 GB
    lrms_wn:
      type: tosca.nodes.indigo.LRMS.WorkerNode.HTCondor
      capabilities:
        scalable:
          properties:
            count: 1
            min_instances: 0
            max_instances: 8
        host:
          properties:
            num_cpus: 2
            mem_size: 2 GB
"#;

fn parse_mem_gb(v: &Yaml) -> anyhow::Result<f64> {
    match v {
        Yaml::Int(i) => Ok(*i as f64),
        Yaml::Float(f) => Ok(*f),
        Yaml::Str(s) => {
            let s = s.trim();
            if let Some(num) = s.strip_suffix("GB") {
                Ok(num.trim().parse::<f64>()?)
            } else if let Some(num) = s.strip_suffix("MB") {
                Ok(num.trim().parse::<f64>()? / 1024.0)
            } else {
                bail!("cannot parse memory size {s:?}")
            }
        }
        other => bail!("cannot parse memory size from {other}"),
    }
}

fn parse_host(node: &Yaml) -> anyhow::Result<HostRequirements> {
    let props = node
        .get_path("capabilities.host.properties")
        .context("node template missing capabilities.host.properties")?;
    Ok(HostRequirements {
        num_cpus: props
            .i64_at("num_cpus")
            .context("host missing num_cpus")? as u32,
        mem_gb: parse_mem_gb(
            props.get("mem_size").context("host missing mem_size")?)?,
    })
}

/// Parse a TOSCA document into a [`ClusterTemplate`].
pub fn parse(doc: &str) -> anyhow::Result<ClusterTemplate> {
    let y = yaml::parse(doc)?;
    if y.str_at("tosca_definitions_version").is_none() {
        bail!("not a TOSCA document: missing tosca_definitions_version");
    }
    let templates = y
        .get_path("topology_template.node_templates")
        .context("missing topology_template.node_templates")?;

    // Locate node templates by TOSCA type prefix, not by key name.
    let mut cluster = None;
    let mut fe = None;
    let mut wn = None;
    for (key, node) in templates.as_map().context("node_templates")? {
        let ty = node.str_at("type").unwrap_or("");
        if ty.contains("ElasticCluster") {
            cluster = Some((key.clone(), node));
        } else if ty.contains("LRMS.FrontEnd") {
            fe = Some(node);
        } else if ty.contains("LRMS.WorkerNode") {
            wn = Some(node);
        }
    }
    let (_, cluster) = cluster.context("no ElasticCluster node template")?;
    let fe = fe.context("no LRMS.FrontEnd node template")?;
    let wn = wn.context("no LRMS.WorkerNode node template")?;

    let props = cluster.get("properties").context("cluster properties")?;
    let lrms = match props.str_at("lrms") {
        Some("slurm") => LrmsKind::Slurm,
        Some("htcondor") => LrmsKind::HtCondor,
        Some(other) => bail!("unsupported LRMS {other:?}"),
        None => LrmsKind::Slurm,
    };
    let vpn_cipher = match props.str_at("vpn_cipher") {
        Some(s) => s.parse::<Cipher>()?,
        None => Cipher::Aes256Gcm,
    };
    let idle_timeout_s =
        props.get("idle_timeout").and_then(|v| v.as_f64()).unwrap_or(300.0);
    let redundant_central_point = props
        .get("redundant_central_point")
        .and_then(|v| v.as_bool())
        .unwrap_or(false);

    let scal = wn
        .get_path("capabilities.scalable.properties")
        .context("worker missing scalable capability")?;
    let scalable = Scalable {
        count: scal.i64_at("count").unwrap_or(1) as u32,
        min_instances: scal.i64_at("min_instances").unwrap_or(0) as u32,
        max_instances: scal
            .i64_at("max_instances")
            .context("scalable missing max_instances")? as u32,
    };

    let hybrid = y
        .get_path("topology_template.inputs.hybrid.default")
        .and_then(|v| v.as_bool())
        .unwrap_or(true);

    let tpl = ClusterTemplate {
        name: y
            .str_at("metadata.display_name")
            .unwrap_or("unnamed-cluster")
            .to_string(),
        description: y.str_at("description").unwrap_or("").to_string(),
        lrms,
        front_end: parse_host(fe)?,
        worker: parse_host(wn)?,
        scalable,
        vpn_cipher,
        hybrid,
        idle_timeout_s,
        redundant_central_point,
    };
    tpl.validate()?;
    Ok(tpl)
}

/// Parse the built-in curated template by display name.
pub fn builtin(name: &str) -> anyhow::Result<ClusterTemplate> {
    match name {
        "slurm" | "SLURM Elastic cluster" => parse(SLURM_ELASTIC_TEMPLATE),
        "htcondor" | "HTCondor Elastic cluster" => {
            parse(HTCONDOR_ELASTIC_TEMPLATE)
        }
        other => bail!("no built-in template {other:?} (try slurm/htcondor)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_builtin_slurm() {
        let t = builtin("slurm").unwrap();
        assert_eq!(t.lrms, LrmsKind::Slurm);
        assert_eq!(t.name, "SLURM Elastic cluster");
        assert_eq!(t.scalable.count, 2);
        assert_eq!(t.scalable.max_instances, 5);
        assert_eq!(t.front_end.num_cpus, 2);
        assert_eq!(t.worker.mem_gb, 4.0);
        assert_eq!(t.vpn_cipher, Cipher::Aes256Gcm);
        assert!(t.hybrid);
        assert_eq!(t.idle_timeout_s, 600.0);
        assert!(!t.redundant_central_point);
    }

    #[test]
    fn parses_builtin_htcondor() {
        let t = builtin("htcondor").unwrap();
        assert_eq!(t.lrms, LrmsKind::HtCondor);
        assert!(t.redundant_central_point);
        assert_eq!(t.scalable.max_instances, 8);
        assert_eq!(t.worker.mem_gb, 2.0);
    }

    #[test]
    fn unknown_builtin_rejected() {
        assert!(builtin("kubernetes").is_err());
    }

    #[test]
    fn missing_sections_rejected() {
        assert!(parse("tosca_definitions_version: x\n").is_err());
        assert!(parse("foo: bar\n").is_err());
    }

    #[test]
    fn semantic_validation() {
        let bad = SLURM_ELASTIC_TEMPLATE.replace(
            "max_instances: 5", "max_instances: 1");
        // count (2) > max_instances (1)
        assert!(parse(&bad).is_err());
    }

    #[test]
    fn mem_size_formats() {
        assert_eq!(parse_mem_gb(&Yaml::Str("4 GB".into())).unwrap(), 4.0);
        assert_eq!(parse_mem_gb(&Yaml::Str("512 MB".into())).unwrap(), 0.5);
        assert_eq!(parse_mem_gb(&Yaml::Int(8)).unwrap(), 8.0);
        assert!(parse_mem_gb(&Yaml::Str("lots".into())).is_err());
    }

    #[test]
    fn defaults_for_optional_properties() {
        let doc = r#"
tosca_definitions_version: tosca_simple_yaml_1_0
topology_template:
  node_templates:
    cluster:
      type: tosca.nodes.indigo.ElasticCluster
      properties:
        lrms: slurm
    fe:
      type: tosca.nodes.indigo.LRMS.FrontEnd.Slurm
      capabilities:
        host:
          properties:
            num_cpus: 1
            mem_size: 2 GB
    wn:
      type: tosca.nodes.indigo.LRMS.WorkerNode.Slurm
      capabilities:
        scalable:
          properties:
            max_instances: 3
        host:
          properties:
            num_cpus: 1
            mem_size: 2 GB
"#;
        let t = parse(doc).unwrap();
        assert_eq!(t.scalable.count, 1);
        assert_eq!(t.scalable.min_instances, 0);
        assert_eq!(t.vpn_cipher, Cipher::Aes256Gcm);
        assert_eq!(t.name, "unnamed-cluster");
    }
}
