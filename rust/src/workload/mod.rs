//! The paper's §4 workload: audio-classification jobs over a subset of
//! the Urban Sound Datasets (3,676 WAV files, four submission blocks).
//!
//! Each job processes one audio file with the DEEP audio classifier. The
//! first job on a fresh node additionally pays a one-time setup cost
//! (install udocker, pull the classifier image, create the container —
//! 4 min 30 s on average in the paper); the classification itself takes
//! 15–20 s per file.
//!
//! [`synth_clip`] generates the synthetic power spectrogram for a file id
//! — bit-compatible with `python/compile/model.py::synth_clip`, so the
//! logits computed through the PJRT runtime can be golden-checked against
//! the values the JAX build path recorded in the artifact manifest.

pub mod staging;
pub mod trace;

pub use staging::StagingPath;

use crate::sim::SimTime;
use crate::util::prng::Prng;

/// Model input geometry (must match python/compile/model.py).
pub const N_FRAMES: usize = 96;
pub const N_BINS: usize = 257;
pub const N_CLASSES: usize = 527;

/// Paper constants.
pub const TOTAL_FILES: u32 = 3676;
pub const SETUP_SECS_MEAN: f64 = 270.0; // 4 min 30 s
pub const JOB_SECS_MIN: f64 = 15.0;
pub const JOB_SECS_MAX: f64 = 20.0;

/// One submission block (Fig. 9).
#[derive(Debug, Clone)]
pub struct Block {
    pub at: SimTime,
    pub jobs: u32,
}

/// A workload: blocks of jobs submitted over time.
#[derive(Debug, Clone)]
pub struct Workload {
    pub blocks: Vec<Block>,
    /// Mean one-time per-node setup seconds.
    pub setup_secs: f64,
}

impl Workload {
    /// The paper's workload: 3,676 files in four equal blocks with
    /// waiting time in between (Fig. 9). `scale` shrinks the job count
    /// for fast tests (1.0 = full paper run).
    pub fn paper(scale: f64) -> Workload {
        let total = ((TOTAL_FILES as f64 * scale).round() as u32).max(4);
        // Clamp every block to at least one job: integer rounding at
        // extreme scales must never produce a zero-job (empty) block.
        let per = (total / 4).max(1);
        let last = total.saturating_sub(3 * per).max(1);
        let sizes = [per, per, per, last];
        // Block spacing: the first block lands at t=0 (the paper's
        // 15:00); later blocks arrive after roughly an hour of work plus
        // a short gap — early enough to catch nodes in power-off grace.
        // 70 min apart at full scale: one block takes ~60 min on the
        // full cluster, so nodes go idle just long enough for CLUES to
        // begin powering off before the next block rescues most of them
        // (the paper's 16:05 episode where only vnode-3 actually died).
        let starts = [0.0, 4200.0 * scale.max(0.02), 8400.0 * scale.max(0.02),
                      12600.0 * scale.max(0.02)];
        Workload {
            blocks: starts
                .iter()
                .zip(sizes)
                .map(|(&at, jobs)| Block { at: SimTime(at), jobs })
                .collect(),
            setup_secs: SETUP_SECS_MEAN,
        }
    }

    pub fn total_jobs(&self) -> u32 {
        self.blocks.iter().map(|b| b.jobs).sum()
    }

    /// Sample the duration of one classification job (15–20 s uniform,
    /// as reported in §4.1).
    pub fn sample_job_secs(rng: &mut Prng) -> f64 {
        rng.uniform(JOB_SECS_MIN, JOB_SECS_MAX)
    }

    /// Sample the one-time node setup duration (±15% around the mean).
    pub fn sample_setup_secs(&self, rng: &mut Prng) -> f64 {
        rng.uniform(self.setup_secs * 0.85, self.setup_secs * 1.15)
    }
}

/// Synthetic power spectrogram for `file_id`, flattened row-major
/// (N_FRAMES × N_BINS). Twin of the Python generator.
pub fn synth_clip(file_id: u64) -> Vec<f32> {
    let mut rng = Prng::for_stream(file_id);
    let f0 = 50.0 + rng.next_f32() as f64 * 450.0;
    let n_harm = 1 + (rng.next_f32() as f64 * 8.0) as u32;
    // f64 intermediate then f32 cast, matching numpy's promotion rules.
    let noise = (0.01 + rng.next_f32() as f64 * 0.05) as f32;
    let am = 0.5 + rng.next_f32() as f64 * 4.0;

    let mut spec = vec![noise; N_FRAMES * N_BINS];
    // Per-frame amplitude envelope.
    let env: Vec<f32> = (0..N_FRAMES)
        .map(|t| {
            (0.6 + 0.4 * (std::f64::consts::TAU * am * t as f64
                / N_FRAMES as f64).sin()) as f32
        })
        .collect();
    for h in 1..=n_harm {
        let centre = f0 * h as f64 / 8000.0 * (N_BINS as f64 - 1.0);
        if centre >= N_BINS as f64 {
            break;
        }
        let width = 1.5 + 0.5 * h as f64;
        for (ti, e) in env.iter().enumerate() {
            for fi in 0..N_BINS {
                let d = (fi as f64 - centre) / width;
                let peak = ((-0.5 * d * d).exp() / h as f64) as f32;
                spec[ti * N_BINS + fi] += e * peak;
            }
        }
    }
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_workload_shape() {
        let w = Workload::paper(1.0);
        assert_eq!(w.total_jobs(), TOTAL_FILES);
        assert_eq!(w.blocks.len(), 4);
        assert_eq!(w.blocks[0].at.0, 0.0);
        assert!(w.blocks[3].at.0 > w.blocks[2].at.0);
    }

    #[test]
    fn scaled_workload() {
        let w = Workload::paper(0.01);
        assert!(w.total_jobs() >= 32 && w.total_jobs() <= 40,
                "{}", w.total_jobs());
        // Block spacing shrinks with scale.
        assert!(w.blocks[1].at.0 < 200.0);
    }

    #[test]
    fn tiny_scale_never_yields_a_zero_job_block() {
        for scale in [1e-9, 1e-6, 0.0001, 0.0005, 0.001, 0.01, 1.0] {
            let w = Workload::paper(scale);
            assert!(w.blocks.iter().all(|b| b.jobs >= 1),
                    "scale {scale}: {:?}",
                    w.blocks.iter().map(|b| b.jobs).collect::<Vec<_>>());
            assert!(w.total_jobs() >= 4, "scale {scale}");
        }
    }

    #[test]
    fn job_durations_in_paper_range() {
        let mut rng = Prng::new(1);
        for _ in 0..1000 {
            let s = Workload::sample_job_secs(&mut rng);
            assert!((JOB_SECS_MIN..JOB_SECS_MAX).contains(&s));
        }
    }

    #[test]
    fn setup_duration_around_4m30s() {
        let w = Workload::paper(1.0);
        let mut rng = Prng::new(2);
        let mean: f64 = (0..2000)
            .map(|_| w.sample_setup_secs(&mut rng))
            .sum::<f64>() / 2000.0;
        assert!((mean - 270.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn synth_clip_deterministic_distinct_nonnegative() {
        let a = synth_clip(1);
        let b = synth_clip(1);
        let c = synth_clip(2);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), N_FRAMES * N_BINS);
        assert!(a.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn synth_clip_has_harmonic_structure() {
        // Energy must be concentrated, not flat noise.
        let a = synth_clip(0);
        let max = a.iter().cloned().fold(f32::MIN, f32::max);
        let mean = a.iter().sum::<f32>() / a.len() as f32;
        assert!(max > 5.0 * mean, "max={max} mean={mean}");
    }
}
