//! Streaming workload ingestion: arrival blocks pulled on demand.
//!
//! The materialized [`Workload`](super::Workload) allocates every
//! submission block up front, which caps a run at whatever arrival
//! process fits in memory. This module inverts that: a [`TraceSource`]
//! yields blocks one at a time, and the control plane buffers at most a
//! watermark's worth of look-ahead in a [`TraceFeed`], so a
//! multi-million-job trace is replayed with the frontend holding O(
//! watermark) jobs regardless of trace length.
//!
//! Three sources cover the spectrum the evaluation needs:
//!
//! * [`SynthSource`] wraps an existing [`Workload`] — the default. Every
//!   run streams through it, so synthetic and trace-driven replays share
//!   one submission path and are byte-identical by construction.
//! * [`CsvTrace`] parses an Azure-VM-style arrival CSV (`arrival_secs,
//!   jobs` rows, non-decreasing timestamps) incrementally off any
//!   `BufRead`, never holding more than one line.
//! * [`ArrivalGen`] synthesizes a Google-cluster-style arrival process —
//!   diurnal rate modulation plus random bursts — from an
//!   [`ArrivalProfile`] and a seed, deterministically.
//!
//! All pulls happen in control-shard handlers and every block is stamped
//! on the simulation clock, so the three replay engines see identical
//! event streams (the engine byte-identity contract).

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context};

use crate::sim::SimTime;
use crate::util::prng::Prng;

use super::{Block, Workload};

/// A pull-based arrival stream: the control plane asks for the next
/// submission block only when its look-ahead buffer drains below the
/// watermark, so implementations must never need the whole trace in
/// memory at once.
///
/// Contract: arrival times are finite, non-negative and non-decreasing
/// across successive blocks ([`TraceFeed`] re-validates centrally);
/// errors are reported through `anyhow` — a malformed trace must never
/// panic the simulation.
pub trait TraceSource: Send {
    /// Short human label for reports and milestones.
    fn label(&self) -> &str;

    /// Pull the next arrival block; `Ok(None)` means the trace is
    /// exhausted.
    fn next_block(&mut self) -> anyhow::Result<Option<Block>>;

    /// Total job count if the source knows it up front (cheap metadata,
    /// not a license to materialize).
    fn total_jobs_hint(&self) -> Option<u64> {
        None
    }
}

// ---------------------------------------------------------------------
// SynthSource: the materialized Workload, streamed.
// ---------------------------------------------------------------------

/// Streams an existing [`Workload`] block by block. This is the default
/// source for every run: wrapping the synthetic workload keeps one
/// single submission path, so `SynthSource ≡ Workload` holds by
/// construction (and is re-proven by digest compare in
/// `tests/trace_equivalence.rs`).
pub struct SynthSource {
    workload: Workload,
    next: usize,
}

impl SynthSource {
    pub fn new(workload: Workload) -> SynthSource {
        SynthSource { workload, next: 0 }
    }
}

impl TraceSource for SynthSource {
    fn label(&self) -> &str {
        "synth"
    }

    fn next_block(&mut self) -> anyhow::Result<Option<Block>> {
        let b = self.workload.blocks.get(self.next).cloned();
        if b.is_some() {
            self.next += 1;
        }
        Ok(b)
    }

    fn total_jobs_hint(&self) -> Option<u64> {
        Some(self.workload.total_jobs() as u64)
    }
}

// ---------------------------------------------------------------------
// CsvTrace: Azure-VM-style arrival CSV, parsed incrementally.
// ---------------------------------------------------------------------

/// Incremental parser for an Azure-VM-style arrival trace:
///
/// ```text
/// arrival_secs,jobs
/// 0,40
/// 30,25
/// # comments and blank lines are skipped
/// 60,31
/// ```
///
/// One `arrival_secs,jobs` row per submission block, timestamps
/// non-decreasing. The reader is consumed line by line, so a 10M-row
/// file streams in constant memory. Every malformed shape — wrong
/// column count, unparsable numbers, negative or non-finite times,
/// out-of-order rows, zero-job rows, a trace with no data rows at all —
/// surfaces as a clean `anyhow` error naming the line, never a panic.
pub struct CsvTrace<R: BufRead + Send> {
    reader: R,
    label: String,
    line_no: u64,
    rows: u64,
    last_at: f64,
    done: bool,
}

impl CsvTrace<BufReader<File>> {
    /// Open an arrival CSV on disk.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let file = File::open(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        Ok(CsvTrace::from_reader(
            BufReader::new(file),
            path.display().to_string(),
        ))
    }
}

impl<R: BufRead + Send> CsvTrace<R> {
    /// Wrap any buffered reader (a file, an in-memory cursor in tests).
    pub fn from_reader(reader: R, label: String) -> Self {
        CsvTrace {
            reader,
            label,
            line_no: 0,
            rows: 0,
            last_at: 0.0,
            done: false,
        }
    }

    fn parse_row(&self, line: &str) -> anyhow::Result<Block> {
        let mut cols = line.split(',');
        let (Some(at_s), Some(jobs_s), None) =
            (cols.next(), cols.next(), cols.next())
        else {
            bail!(
                "{} line {}: expected `arrival_secs,jobs`, got {:?}",
                self.label, self.line_no, line
            );
        };
        let at: f64 = at_s.trim().parse().with_context(|| {
            format!(
                "{} line {}: bad arrival_secs {:?}",
                self.label, self.line_no, at_s.trim()
            )
        })?;
        let jobs: u32 = jobs_s.trim().parse().with_context(|| {
            format!(
                "{} line {}: bad job count {:?}",
                self.label, self.line_no, jobs_s.trim()
            )
        })?;
        if !at.is_finite() || at < 0.0 {
            bail!(
                "{} line {}: arrival_secs must be finite and >= 0, got {at}",
                self.label, self.line_no
            );
        }
        if at < self.last_at {
            bail!(
                "{} line {}: out-of-order arrival {at} after {}",
                self.label, self.line_no, self.last_at
            );
        }
        if jobs == 0 {
            bail!(
                "{} line {}: zero-job block (drop the row instead)",
                self.label, self.line_no
            );
        }
        Ok(Block { at: SimTime(at), jobs })
    }
}

impl<R: BufRead + Send> TraceSource for CsvTrace<R> {
    fn label(&self) -> &str {
        &self.label
    }

    fn next_block(&mut self) -> anyhow::Result<Option<Block>> {
        if self.done {
            return Ok(None);
        }
        let mut line = String::new();
        loop {
            line.clear();
            let n = self
                .reader
                .read_line(&mut line)
                .with_context(|| format!("reading trace {}", self.label))?;
            if n == 0 {
                self.done = true;
                if self.rows == 0 {
                    bail!("{}: empty trace (no arrival rows)", self.label);
                }
                return Ok(None);
            }
            self.line_no += 1;
            let trimmed = line.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            // An optional header row is tolerated once, before any data.
            if self.rows == 0
                && trimmed
                    .chars()
                    .next()
                    .is_some_and(|c| c.is_ascii_alphabetic())
            {
                continue;
            }
            let block = self.parse_row(trimmed)?;
            self.rows += 1;
            self.last_at = block.at.0;
            return Ok(Some(block));
        }
    }
}

// ---------------------------------------------------------------------
// ArrivalGen: Google-cluster-style burst/diurnal arrival process.
// ---------------------------------------------------------------------

/// Shape of a generated arrival process: a base rate modulated by a
/// diurnal sinusoid, with random multiplicative bursts — the
/// bursty/heterogeneous profile of public cluster traces, without
/// shipping gigabytes of trace data.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalProfile {
    /// Mean arrival rate, jobs per simulated second.
    pub base_rate: f64,
    /// Diurnal modulation amplitude in `[0, 1)`: the rate swings between
    /// `base_rate * (1 - amp)` and `base_rate * (1 + amp)`.
    pub diurnal_amplitude: f64,
    /// Diurnal period in seconds (86400 for a literal day).
    pub diurnal_period_s: f64,
    /// Per-window probability of a burst window.
    pub burst_prob: f64,
    /// Rate multiplier during a burst window.
    pub burst_multiplier: f64,
    /// Arrival-window granularity: one block per window, in seconds.
    pub window_s: f64,
}

impl Default for ArrivalProfile {
    fn default() -> Self {
        ArrivalProfile {
            base_rate: 10.0,
            diurnal_amplitude: 0.5,
            diurnal_period_s: 86_400.0,
            burst_prob: 0.05,
            burst_multiplier: 3.0,
            window_s: 60.0,
        }
    }
}

impl ArrivalProfile {
    fn validate(&self) -> anyhow::Result<()> {
        if !(self.base_rate.is_finite() && self.base_rate > 0.0) {
            bail!("arrival profile: base_rate must be > 0, got {}",
                  self.base_rate);
        }
        if !(0.0..1.0).contains(&self.diurnal_amplitude) {
            bail!("arrival profile: diurnal_amplitude must be in [0,1), \
                   got {}", self.diurnal_amplitude);
        }
        if !(self.diurnal_period_s.is_finite()
            && self.diurnal_period_s > 0.0)
        {
            bail!("arrival profile: diurnal_period_s must be > 0, got {}",
                  self.diurnal_period_s);
        }
        if !(0.0..=1.0).contains(&self.burst_prob) {
            bail!("arrival profile: burst_prob must be in [0,1], got {}",
                  self.burst_prob);
        }
        if !(self.burst_multiplier.is_finite()
            && self.burst_multiplier >= 1.0)
        {
            bail!("arrival profile: burst_multiplier must be >= 1, got {}",
                  self.burst_multiplier);
        }
        if !(self.window_s.is_finite() && self.window_s > 0.0) {
            bail!("arrival profile: window_s must be > 0, got {}",
                  self.window_s);
        }
        Ok(())
    }
}

/// Deterministic generated trace: emits one block per arrival window
/// until exactly `total_jobs` jobs have been produced. Same seed and
/// profile → identical block stream, independent of engine or pull
/// cadence.
pub struct ArrivalGen {
    profile: ArrivalProfile,
    rng: Prng,
    t: f64,
    carry: f64,
    emitted: u64,
    total_jobs: u64,
    label: String,
}

impl ArrivalGen {
    pub fn new(seed: u64, total_jobs: u64, profile: ArrivalProfile)
        -> anyhow::Result<ArrivalGen> {
        profile.validate()?;
        if total_jobs == 0 {
            bail!("arrival generator: total_jobs must be > 0");
        }
        Ok(ArrivalGen {
            profile,
            rng: Prng::new(seed ^ 0x7ACE),
            t: 0.0,
            carry: 0.0,
            emitted: 0,
            total_jobs,
            label: format!("gen-{total_jobs}j"),
        })
    }
}

impl TraceSource for ArrivalGen {
    fn label(&self) -> &str {
        &self.label
    }

    fn next_block(&mut self) -> anyhow::Result<Option<Block>> {
        let p = self.profile;
        while self.emitted < self.total_jobs {
            let phase =
                std::f64::consts::TAU * self.t / p.diurnal_period_s;
            let mut rate =
                p.base_rate * (1.0 + p.diurnal_amplitude * phase.sin());
            if self.rng.chance(p.burst_prob) {
                rate *= p.burst_multiplier;
            }
            // Fractional arrivals carry over, so thin windows still
            // accumulate into jobs instead of rounding to nothing.
            self.carry += rate * p.window_s * self.rng.uniform(0.6, 1.4);
            let at = self.t;
            self.t += p.window_s;
            let due = (self.carry.floor() as u64)
                .min(self.total_jobs - self.emitted);
            if due == 0 {
                continue;
            }
            self.carry -= due as f64;
            self.emitted += due;
            return Ok(Some(Block { at: SimTime(at), jobs: due as u32 }));
        }
        Ok(None)
    }

    fn total_jobs_hint(&self) -> Option<u64> {
        Some(self.total_jobs)
    }
}

// ---------------------------------------------------------------------
// TraceFeed: the control plane's bounded look-ahead buffer.
// ---------------------------------------------------------------------

/// The watermark value meaning "buffer the whole trace up front" — the
/// pre-streaming behaviour, and the default so existing configurations
/// replay bit-for-bit.
pub const WATERMARK_UNBOUNDED: u32 = u32::MAX;

/// Bounded look-ahead between a [`TraceSource`] and the control plane.
///
/// The pull protocol: [`TraceFeed::refill`] draws blocks from the
/// source until at least `watermark_jobs` jobs are buffered (or the
/// source is exhausted) and hands back their global indexes and arrival
/// offsets for the caller to schedule; each [`TraceFeed::pop_front`]
/// consumes the oldest buffered block at its submission event. Control
/// calls `refill` once at workload start and again after every pop, so
/// the buffer breathes between `watermark_jobs` and zero while the
/// trace drains — frontend memory is O(watermark + one block),
/// independent of trace length, which [`TraceFeed::peak_buffered_jobs`]
/// records deterministically.
pub struct TraceFeed {
    source: Box<dyn TraceSource>,
    buf: VecDeque<Block>,
    watermark_jobs: u64,
    buffered_jobs: u64,
    peak_buffered: u64,
    pulled_blocks: u64,
    popped_blocks: u64,
    last_at: f64,
    exhausted: bool,
}

impl TraceFeed {
    pub fn new(source: Box<dyn TraceSource>, watermark_jobs: u32)
        -> TraceFeed {
        TraceFeed {
            source,
            buf: VecDeque::new(),
            watermark_jobs: watermark_jobs.max(1) as u64,
            buffered_jobs: 0,
            peak_buffered: 0,
            pulled_blocks: 0,
            popped_blocks: 0,
            last_at: 0.0,
            exhausted: false,
        }
    }

    pub fn label(&self) -> &str {
        self.source.label()
    }

    /// Pull blocks until the look-ahead holds at least the watermark,
    /// returning `(global_block_index, arrival_offset)` for each newly
    /// buffered block so the caller can schedule its submission event.
    ///
    /// On a source or validation error the feed marks itself exhausted
    /// and rolls back the blocks this call buffered (their events were
    /// never scheduled), so the run drains exactly what was already
    /// scheduled and the error surfaces as the run's fatal diagnosis.
    pub fn refill(&mut self)
        -> anyhow::Result<Vec<(u64, SimTime)>> {
        let mut newly: Vec<(u64, SimTime)> = Vec::new();
        let fail = |feed: &mut TraceFeed, n: usize, e: anyhow::Error| {
            feed.exhausted = true;
            for _ in 0..n {
                let b = feed.buf.pop_back().expect("rollback underflow");
                feed.buffered_jobs -= b.jobs as u64;
                feed.pulled_blocks -= 1;
            }
            Err(e)
        };
        while !self.exhausted && self.buffered_jobs < self.watermark_jobs {
            match self.source.next_block() {
                Ok(Some(b)) => {
                    if !b.at.0.is_finite() || b.at.0 < 0.0 {
                        let e = anyhow::anyhow!(
                            "trace {}: block {} arrival {} is not a \
                             finite non-negative offset",
                            self.source.label(), self.pulled_blocks,
                            b.at.0);
                        return fail(self, newly.len(), e);
                    }
                    if b.at.0 < self.last_at {
                        let e = anyhow::anyhow!(
                            "trace {}: block {} arrives at {} after {}",
                            self.source.label(), self.pulled_blocks,
                            b.at.0, self.last_at);
                        return fail(self, newly.len(), e);
                    }
                    self.last_at = b.at.0;
                    self.buffered_jobs += b.jobs as u64;
                    self.peak_buffered =
                        self.peak_buffered.max(self.buffered_jobs);
                    newly.push((self.pulled_blocks, b.at));
                    self.pulled_blocks += 1;
                    self.buf.push_back(b);
                }
                Ok(None) => self.exhausted = true,
                Err(e) => return fail(self, newly.len(), e),
            }
        }
        Ok(newly)
    }

    /// Consume the oldest buffered block (its submission event fired).
    pub fn pop_front(&mut self) -> Option<Block> {
        let b = self.buf.pop_front()?;
        self.buffered_jobs -= b.jobs as u64;
        self.popped_blocks += 1;
        Some(b)
    }

    /// Global index of the block [`TraceFeed::pop_front`] returns next.
    pub fn next_pop_index(&self) -> u64 {
        self.popped_blocks
    }

    /// True once the source has no further blocks *and* every buffered
    /// block's submission event has fired.
    pub fn drained(&self) -> bool {
        self.exhausted && self.buf.is_empty()
    }

    /// High-water mark of buffered (pulled, not yet submitted) jobs —
    /// the deterministic frontend-memory bound: at most the watermark
    /// plus the one block that crossed it.
    pub fn peak_buffered_jobs(&self) -> u64 {
        self.peak_buffered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn csv(text: &'static str) -> CsvTrace<Cursor<&'static [u8]>> {
        CsvTrace::from_reader(Cursor::new(text.as_bytes()),
                              "test.csv".into())
    }

    fn drain(src: &mut dyn TraceSource) -> anyhow::Result<Vec<Block>> {
        let mut out = Vec::new();
        while let Some(b) = src.next_block()? {
            out.push(b);
        }
        Ok(out)
    }

    #[test]
    fn synth_source_streams_the_workload_verbatim() {
        let w = Workload::paper(0.05);
        let mut s = SynthSource::new(w.clone());
        let blocks = drain(&mut s).unwrap();
        assert_eq!(blocks.len(), w.blocks.len());
        for (a, b) in blocks.iter().zip(&w.blocks) {
            assert_eq!(a.at.0, b.at.0);
            assert_eq!(a.jobs, b.jobs);
        }
        assert_eq!(s.total_jobs_hint(), Some(w.total_jobs() as u64));
        // Exhausted stays exhausted.
        assert!(s.next_block().unwrap().is_none());
    }

    #[test]
    fn csv_parses_header_comments_and_blanks() {
        let mut t = csv("arrival_secs,jobs\n# warmup\n\n0,40\n 30 , 25 \n\
                         60,31\n");
        let blocks = drain(&mut t).unwrap();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0].jobs, 40);
        assert_eq!(blocks[1].at.0, 30.0);
        assert_eq!(blocks[2].jobs, 31);
    }

    #[test]
    fn csv_rejects_malformed_shapes_without_panicking() {
        for (bad, why) in [
            ("0,10\n30\n", "missing column"),
            ("0,10\n30,5,9\n", "extra column"),
            ("0,ten\n", "non-numeric jobs"),
            ("zero,10\n5,1\n", "non-numeric time after header slot"),
            ("0,10\n-5,4\n", "negative time"),
            ("0,10\nNaN,4\n", "non-finite time"),
            ("60,10\n30,4\n", "out-of-order time"),
            ("0,0\n", "zero jobs"),
            ("", "empty trace"),
            ("# only comments\n\n", "comment-only trace"),
            ("arrival_secs,jobs\n", "header-only trace"),
        ] {
            let err = drain(&mut csv(bad))
                .expect_err(&format!("{why}: {bad:?} must not parse"));
            assert!(!err.to_string().is_empty(), "{why}");
        }
    }

    #[test]
    fn csv_errors_name_the_line() {
        let err = drain(&mut csv("0,10\n30,bogus\n")).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"),
                "error should name line 2: {err:#}");
    }

    #[test]
    fn generator_is_deterministic_and_exact() {
        let profile = ArrivalProfile {
            base_rate: 5.0,
            window_s: 30.0,
            ..ArrivalProfile::default()
        };
        let a = drain(&mut ArrivalGen::new(9, 2000, profile).unwrap())
            .unwrap();
        let b = drain(&mut ArrivalGen::new(9, 2000, profile).unwrap())
            .unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.at.0, x.jobs), (y.at.0, y.jobs));
        }
        assert_eq!(a.iter().map(|x| x.jobs as u64).sum::<u64>(), 2000);
        assert!(a.windows(2).all(|w| w[0].at.0 <= w[1].at.0),
                "arrivals must be non-decreasing");
        assert!(a.iter().all(|x| x.jobs > 0));
        let c = drain(&mut ArrivalGen::new(10, 2000, profile).unwrap())
            .unwrap();
        assert!(a.len() != c.len()
                    || a.iter().zip(&c).any(|(x, y)| x.jobs != y.jobs),
                "different seeds should differ");
    }

    #[test]
    fn generator_rejects_bad_profiles() {
        let bad = [
            ArrivalProfile { base_rate: 0.0, ..Default::default() },
            ArrivalProfile { diurnal_amplitude: 1.5, ..Default::default() },
            ArrivalProfile { burst_prob: 2.0, ..Default::default() },
            ArrivalProfile { burst_multiplier: 0.5, ..Default::default() },
            ArrivalProfile { window_s: -1.0, ..Default::default() },
            ArrivalProfile { diurnal_period_s: 0.0, ..Default::default() },
        ];
        for p in bad {
            assert!(ArrivalGen::new(1, 10, p).is_err(), "{p:?}");
        }
        assert!(ArrivalGen::new(1, 0, ArrivalProfile::default()).is_err());
    }

    #[test]
    fn feed_bounds_lookahead_by_the_watermark() {
        let w = Workload::paper(1.0); // 4 blocks of ~919 jobs
        let max_block =
            w.blocks.iter().map(|b| b.jobs as u64).max().unwrap();
        let mut feed =
            TraceFeed::new(Box::new(SynthSource::new(w.clone())), 100);
        let newly = feed.refill().unwrap();
        // 100-job watermark: one ~919-job block crosses it.
        assert_eq!(newly.len(), 1);
        assert!(!feed.drained());
        let mut popped = 0u64;
        loop {
            let Some(b) = feed.pop_front() else { break };
            popped += b.jobs as u64;
            for (i, _) in feed.refill().unwrap() {
                assert!(i < w.blocks.len() as u64);
            }
        }
        assert!(feed.drained());
        assert_eq!(popped, w.total_jobs() as u64);
        assert!(feed.peak_buffered_jobs() <= 100 + max_block,
                "peak {} must stay within watermark + one block",
                feed.peak_buffered_jobs());
        assert!(feed.peak_buffered_jobs() < w.total_jobs() as u64);
    }

    #[test]
    fn unbounded_feed_buffers_everything_up_front() {
        let w = Workload::paper(0.1);
        let mut feed = TraceFeed::new(
            Box::new(SynthSource::new(w.clone())), WATERMARK_UNBOUNDED);
        let newly = feed.refill().unwrap();
        assert_eq!(newly.len(), w.blocks.len());
        assert!(feed.refill().unwrap().is_empty());
        assert_eq!(feed.peak_buffered_jobs(), w.total_jobs() as u64);
        for (want, (got, _)) in newly.iter().enumerate() {
            assert_eq!(want as u64, *got);
        }
    }

    #[test]
    fn feed_rolls_back_and_stops_on_a_source_error() {
        let mut feed = TraceFeed::new(
            Box::new(CsvTrace::from_reader(
                Cursor::new(&b"0,5\nbroken\n"[..]), "bad.csv".into())),
            WATERMARK_UNBOUNDED);
        // The whole refill fails: the 0,5 block it pulled alongside the
        // broken row is rolled back (its event was never scheduled), so
        // the buffer only ever holds scheduled blocks.
        assert!(feed.refill().is_err());
        assert!(feed.pop_front().is_none());
        assert!(feed.drained());
        assert!(feed.refill().unwrap().is_empty());
        assert_eq!(feed.next_pop_index(), 0);
    }

    #[test]
    fn feed_keeps_blocks_scheduled_before_a_later_error() {
        // Watermark 3: the first refill succeeds with the 0,5 block;
        // the second hits the broken row and rolls back nothing extra.
        let mut feed = TraceFeed::new(
            Box::new(CsvTrace::from_reader(
                Cursor::new(&b"0,5\nbroken\n"[..]), "bad.csv".into())),
            3);
        let newly = feed.refill().unwrap();
        assert_eq!(newly.len(), 1);
        assert_eq!(feed.pop_front().map(|b| b.jobs), Some(5));
        assert!(feed.refill().is_err());
        assert!(feed.drained());
    }
}
