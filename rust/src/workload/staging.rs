//! Data staging over the overlay: the paper's per-node setup steps
//! ("pull the audio classifier Docker image from Docker Hub", "download
//! the WAV file") expressed as transfers through the deployment's actual
//! network path, instead of a flat constant.
//!
//! This makes node setup time *endogenous*: a node behind a vRouter whose
//! tunnel uses a slow cipher, sharing the CP with other pulls, takes
//! measurably longer to become productive — the coupling §3.5.6 warns
//! about. `RunConfig`-level code keeps the paper-calibrated constant by
//! default and switches to this model for the ablation bench.

use crate::netsim::{transfer_time, Network, OverlayHop};
use crate::vrouter::Overlay;

/// The classifier image the paper pulls per node (deep-oc-audio class
/// images are ~1.3 GB compressed on Docker Hub).
pub const IMAGE_BYTES: f64 = 1.3e9;
/// Mean WAV file size: 2.8 GB / 3,676 files.
pub const AUDIO_FILE_BYTES: f64 = 2.8e9 / 3676.0;
/// udocker install + container create (the non-network parts), seconds.
pub const LOCAL_SETUP_SECS: f64 = 55.0;

/// Where a node pulls external data from, overlay-wise: traffic enters
/// the deployment at the CP (the only public egress in Figure 1) and is
/// routed to the node's site.
#[derive(Debug, Clone)]
pub struct StagingPath {
    pub hops: Vec<OverlayHop>,
    /// Concurrent pulls sharing the CP at the same moment.
    pub concurrent: u32,
}

impl StagingPath {
    /// Resolve the path from the CP/front-end element to `node_element`.
    pub fn resolve(overlay: &Overlay, net: &Network, cp: &str,
                   node_element: &str, concurrent: u32)
        -> anyhow::Result<StagingPath> {
        let path = overlay
            .element_path(cp, node_element)
            .ok_or_else(|| anyhow::anyhow!(
                "{cp} cannot reach {node_element} over the overlay"))?;
        Ok(StagingPath { hops: overlay.hops(net, &path)?, concurrent })
    }

    /// Seconds to move `bytes` along this path (store-and-forward, CP
    /// crypto shared across concurrent pulls).
    pub fn transfer_secs(&self, bytes: f64) -> f64 {
        let raw = transfer_time(bytes, &self.hops);
        // Fan-in penalty applies to the bandwidth share, not latency:
        // approximate by scaling the whole transfer by the share when
        // more than one pull is in flight.
        if self.concurrent > 1 {
            // Latency portion is negligible next to a GB-scale pull.
            raw * self.concurrent as f64
        } else {
            raw
        }
    }

    /// Full one-time setup: local work + the image pull.
    pub fn setup_secs(&self) -> f64 {
        LOCAL_SETUP_SECS + self.transfer_secs(IMAGE_BYTES)
    }

    /// Per-job staging: one audio file in, one JSON result out (results
    /// are tiny; modelled as 16 KiB).
    pub fn per_job_staging_secs(&self) -> f64 {
        self.transfer_secs(AUDIO_FILE_BYTES) + self.transfer_secs(16e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Cipher, LinkSpec, NetId, Network};
    use crate::sim::SimTime;
    use crate::vrouter::Overlay;

    fn setup(cipher: Cipher) -> (Network, Overlay, NetId, NetId) {
        let mut net = Network::new();
        let cesnet = net.add_location("cesnet");
        let aws = net.add_location("aws");
        net.set_link(cesnet, aws, LinkSpec::transatlantic());
        let mut ov = Overlay::new(cipher);
        ov.add_central_point("front-end", cesnet, 0x0A000000,
                             SimTime(0.0)).unwrap();
        ov.add_site_router("vrouter-aws", aws, 0x0A010000, SimTime(1.0))
            .unwrap();
        (net, ov, cesnet, aws)
    }

    #[test]
    fn remote_site_pull_includes_tunnel_cost() {
        let (net, ov, ..) = setup(Cipher::Aes256Gcm);
        let local = StagingPath::resolve(&ov, &net, "front-end",
                                         "front-end", 1).unwrap();
        let remote = StagingPath::resolve(&ov, &net, "front-end",
                                          "vrouter-aws", 1).unwrap();
        assert!(remote.setup_secs() > local.setup_secs());
        // A 1.3 GB pull over a ~500 Mbps tunnel ≈ 20+ s of transfer.
        assert!(remote.setup_secs() > LOCAL_SETUP_SECS + 15.0);
    }

    #[test]
    fn weaker_cipher_stages_faster() {
        let mut secs = Vec::new();
        for cipher in [Cipher::Plain, Cipher::Aes256Gcm,
                       Cipher::BlowfishCbc] {
            let (net, ov, ..) = setup(cipher);
            let p = StagingPath::resolve(&ov, &net, "front-end",
                                         "vrouter-aws", 1).unwrap();
            secs.push(p.setup_secs());
        }
        assert!(secs[0] <= secs[1] && secs[1] < secs[2], "{secs:?}");
        // On the 500 Mbps transatlantic link the AEAD ciphers are
        // link-limited; only BF-CBC (~140 Mbps) is crypto-limited and
        // materially slower — exactly the §3.5.6 shape.
        assert!(secs[2] / secs[1] > 1.5, "{secs:?}");
    }

    #[test]
    fn fan_in_slows_concurrent_pulls() {
        let (net, ov, ..) = setup(Cipher::Aes128Gcm);
        let alone = StagingPath::resolve(&ov, &net, "front-end",
                                         "vrouter-aws", 1).unwrap();
        let shared = StagingPath::resolve(&ov, &net, "front-end",
                                          "vrouter-aws", 3).unwrap();
        let ratio = shared.transfer_secs(IMAGE_BYTES)
            / alone.transfer_secs(IMAGE_BYTES);
        assert!((ratio - 3.0).abs() < 1e-9);
    }

    #[test]
    fn per_job_staging_is_seconds_not_minutes() {
        let (net, ov, ..) = setup(Cipher::Aes256Gcm);
        let p = StagingPath::resolve(&ov, &net, "front-end",
                                     "vrouter-aws", 1).unwrap();
        let s = p.per_job_staging_secs();
        assert!(s > 0.0 && s < 5.0, "{s}");
    }

    #[test]
    fn unreachable_node_is_an_error() {
        let (net, mut ov, ..) = setup(Cipher::Plain);
        ov.fail_central_point("front-end", SimTime(5.0)).unwrap();
        assert!(StagingPath::resolve(&ov, &net, "front-end",
                                     "vrouter-aws", 1).is_err());
    }
}
