//! Minimal CSV writing and record parsing (quoting-aware) for
//! bench/figure outputs and the metrics spill files.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// Quote one CSV field if it needs it (commas, quotes, newlines).
fn quote(field: &str) -> String {
    if field.contains([',', '"', '\n']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Format one CSV record (quoting-aware, no trailing newline). The
/// exact inverse of [`parse_row`] for newline-free fields.
pub fn format_row<S: AsRef<str>>(fields: &[S]) -> String {
    let quoted: Vec<String> =
        fields.iter().map(|f| quote(f.as_ref())).collect();
    quoted.join(",")
}

/// Parse one CSV record produced by [`format_row`] / [`Table::to_csv`].
/// Handles quoted fields with embedded commas and doubled quotes;
/// fields containing raw newlines are out of scope (the spill readers
/// are line-based).
pub fn parse_row(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => out.push(std::mem::take(&mut cur)),
                _ => cur.push(c),
            }
        }
    }
    out.push(cur);
    out
}

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the width does not match the header
    /// (a bug in the caller, not a runtime condition).
    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to CSV text.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", format_row(&self.header));
        for r in &self.rows {
            let _ = writeln!(s, "{}", format_row(r));
        }
        s
    }

    /// Write CSV to a path, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Render as an aligned text table (for terminal reports).
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let mut s = String::new();
        let fmt_row = |row: &[String], s: &mut String| {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, f)| format!("{:<w$}", f, w = widths[i]))
                .collect();
            let _ = writeln!(s, "{}", cells.join("  "));
        };
        fmt_row(&self.header, &mut s);
        let rule: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(s, "{}", rule.join("  "));
        for r in &self.rows {
            fmt_row(r, &mut s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_quoting() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push(vec!["1", "plain"]);
        t.push(vec!["x,y", "say \"hi\""]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn text_alignment() {
        let mut t = Table::new(vec!["name", "v"]);
        t.push(vec!["longer-name", "1"]);
        let txt = t.to_text();
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("---"));
    }

    #[test]
    fn format_parse_roundtrip() {
        let rows: Vec<Vec<&str>> = vec![
            vec!["plain", "fields", "only"],
            vec!["with,comma", "say \"hi\"", ""],
            vec!["", "", ""],
            vec!["a\"b,c\"d", "x"],
        ];
        for row in rows {
            let line = format_row(&row);
            let back = parse_row(&line);
            assert_eq!(back, row, "roundtrip of {line:?}");
        }
    }

    #[test]
    fn parse_row_splits_unquoted() {
        assert_eq!(parse_row("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(parse_row(""), vec![""]);
        assert_eq!(parse_row("a,,c"), vec!["a", "", "c"]);
        assert_eq!(parse_row("\"x,y\",z"), vec!["x,y", "z"]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("evhc_csv_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(vec!["x"]);
        t.push(vec!["1"]);
        t.write(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("x\n1"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
