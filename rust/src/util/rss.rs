//! Resident-set-size probe for the scale benches: reads
//! `/proc/self/status` (Linux). Memory telemetry is strictly
//! wall-clock-class data — reported, never digested, and the bench
//! gate treats it as warn-only — so `None` on non-Linux hosts (or a
//! procfs hiccup) degrades to "no RSS column", never to a failure.

use std::fs;

/// Parse a `/proc/self/status` line like `VmRSS:\t  123456 kB`.
fn field_kb(status: &str, key: &str) -> Option<u64> {
    for line in status.lines() {
        let Some(rest) = line.strip_prefix(key) else {
            continue;
        };
        let rest = rest.trim_start_matches(':').trim();
        let num = rest.split_whitespace().next()?;
        return num.parse::<u64>().ok();
    }
    None
}

/// Current resident set size in kB (`VmRSS`), if the platform exposes
/// procfs.
pub fn current_rss_kb() -> Option<u64> {
    let status = fs::read_to_string("/proc/self/status").ok()?;
    field_kb(&status, "VmRSS")
}

/// Peak resident set size in kB (`VmHWM` — the high-water mark the
/// kernel tracked for the whole process lifetime), if available.
pub fn peak_rss_kb() -> Option<u64> {
    let status = fs::read_to_string("/proc/self/status").ok()?;
    field_kb(&status, "VmHWM")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_status_fields() {
        let status = "Name:\tevhc\nVmPeak:\t  200000 kB\n\
                      VmRSS:\t   12345 kB\nVmHWM:\t   23456 kB\n";
        assert_eq!(field_kb(status, "VmRSS"), Some(12345));
        assert_eq!(field_kb(status, "VmHWM"), Some(23456));
        assert_eq!(field_kb(status, "VmSwap"), None);
    }

    #[test]
    fn live_probe_is_sane_when_present() {
        // On Linux both gauges exist and peak >= current > 0; elsewhere
        // the probe must simply return None rather than panic.
        if let (Some(cur), Some(peak)) =
            (current_rss_kb(), peak_rss_kb())
        {
            assert!(cur > 0);
            assert!(peak >= cur / 2, "peak={peak} cur={cur}");
        }
    }
}
