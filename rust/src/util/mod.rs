//! In-tree substrates for functionality normally pulled from crates.io.
//!
//! This build environment resolves only the `xla` crate's vendored
//! dependency tree, so clap/serde/criterion/proptest/rand are not
//! available. Everything the coordinator needs from them is implemented
//! here, scoped to what the project actually uses.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod logging;
pub mod plot;
pub mod prng;
pub mod proptest;
pub mod rss;
pub mod stats;
pub mod yaml;
