//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! Provides seeded generators over the crate's [`Prng`] and a runner that
//! reports the failing case number + seed so failures reproduce exactly.
//! Shrinking is deliberately out of scope — generators are kept small and
//! structured enough that the raw counterexample is readable.

use super::prng::Prng;

/// Number of cases per property (overridable via EVHC_PROPTEST_CASES).
pub fn default_cases() -> u32 {
    std::env::var("EVHC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator is any `Fn(&mut Prng) -> T`.
pub trait Gen<T>: Fn(&mut Prng) -> T {}
impl<T, F: Fn(&mut Prng) -> T> Gen<T> for F {}

/// Run `prop` against `cases` generated inputs. Panics with the seed and
/// case index on the first failure (where `prop` returns Err or panics).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Prng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check_n(name, default_cases(), gen, prop)
}

/// Like [`check`] with an explicit case count.
pub fn check_n<T: std::fmt::Debug>(
    name: &str,
    cases: u32,
    gen: impl Fn(&mut Prng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let base_seed = std::env::var("EVHC_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xEC3u64);
    for case in 0..cases {
        let mut rng = Prng::new(base_seed ^ (case as u64).wrapping_mul(
            0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (EVHC_PROPTEST_SEED={base_seed}):\n  input: {input:?}\n  \
                 reason: {msg}"
            );
        }
    }
}

/// Generator combinators.
pub mod gen {
    use super::Prng;

    pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Prng) -> usize {
        move |r| lo + r.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Prng) -> f64 {
        move |r| r.uniform(lo, hi)
    }

    pub fn bool_with(p: f64) -> impl Fn(&mut Prng) -> bool {
        move |r| r.chance(p)
    }

    pub fn vec_of<T>(
        item: impl Fn(&mut Prng) -> T,
        len: impl Fn(&mut Prng) -> usize,
    ) -> impl Fn(&mut Prng) -> Vec<T> {
        move |r| {
            let n = len(r);
            (0..n).map(|_| item(r)).collect()
        }
    }

    pub fn choice<T: Clone>(items: Vec<T>) -> impl Fn(&mut Prng) -> T {
        move |r| items[r.next_below(items.len() as u64) as usize].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", gen::vec_of(gen::usize_in(0, 100),
                                          gen::usize_in(0, 20)), |xs| {
            let fwd: usize = xs.iter().sum();
            let rev: usize = xs.iter().rev().sum();
            if fwd == rev { Ok(()) } else { Err("sum not commutative".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check_n("always-fails", 4, gen::usize_in(0, 9), |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", gen::usize_in(3, 7), |&x| {
            if (3..=7).contains(&x) { Ok(()) } else { Err(format!("{x}")) }
        });
    }
}
