//! Deterministic PRNG (xorshift64*), bit-for-bit identical to the Python
//! generator in `python/compile/model.py::_spectrogram_for`, so the Rust
//! workload generator and the JAX build path can golden-test each other's
//! synthetic clips and logits.

/// xorshift64* generator. Deliberately simple: the simulation needs
/// reproducibility and stream independence, not cryptographic quality.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Seed the generator. A zero state would be a fixed point, so it is
    /// nudged to a non-zero constant.
    pub fn new(seed: u64) -> Self {
        Prng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Seed derived the same way the Python side derives per-file streams:
    /// `file_id * 2654435761 + 1` (Knuth multiplicative hashing).
    pub fn for_stream(stream_id: u64) -> Self {
        Prng::new(stream_id.wrapping_mul(2654435761).wrapping_add(1))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f32 in [0, 1) with 24 bits of mantissa (matches Python).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f64 in [0, 1) with 53 bits of mantissa.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine at simulation quality.
        self.next_u64() % n
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with the given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal sample with the given *linear-domain* median and sigma
    /// (used for provisioning-latency distributions: heavy right tail,
    /// never negative).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent child stream (splitmix of the current state).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_stream_derivation() {
        // Golden values computed by the Python twin for file_id=0:
        // state = 0*2654435761+1 = 1 -> first next_f32 values.
        let mut p = Prng::for_stream(0);
        let a = p.next_f32();
        let b = p.next_f32();
        // Recompute the expectation inline (same algorithm).
        let mut state: u64 = 1;
        let mut step = || {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            (state.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32
                / (1u32 << 24) as f32
        };
        assert_eq!(a, step());
        assert_eq!(b, step());
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let xs: Vec<u64> = (0..8).map(|_| Prng::new(42).next_u64()).collect();
        assert!(xs.iter().all(|&x| x == xs[0]));
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let v = p.next_f64();
            assert!((0.0..1.0).contains(&v));
            let u = p.uniform(3.0, 9.0);
            assert!((3.0..9.0).contains(&u));
            let n = p.next_below(13);
            assert!(n < 13);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut p = Prng::new(1234);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_positive_with_right_tail() {
        let mut p = Prng::new(5);
        let samples: Vec<f64> =
            (0..10_000).map(|_| p.lognormal(60.0, 0.3)).collect();
        assert!(samples.iter().all(|&x| x > 0.0));
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[5000];
        assert!((median - 60.0).abs() < 3.0, "median={median}");
        // Right tail heavier than left.
        assert!(sorted[9999] - median > median - sorted[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_diverge() {
        let mut a = Prng::new(11);
        let mut b = a.fork();
        let av: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
