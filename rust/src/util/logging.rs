//! Minimal `log`-facade backend: leveled, timestamped stderr logger.
//!
//! The simulation records its own virtual-time traces through
//! [`crate::metrics`]; this logger only serves human-facing diagnostics.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). `verbosity`: 0=warn, 1=info, 2=debug,
/// 3+=trace. Honours `EVHC_LOG` (error|warn|info|debug|trace) if set.
pub fn init(verbosity: u8) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    Lazy::force(&START);
    let level = match std::env::var("EVHC_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => match verbosity {
            0 => LevelFilter::Warn,
            1 => LevelFilter::Info,
            2 => LevelFilter::Debug,
            _ => LevelFilter::Trace,
        },
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init(1);
        super::init(2); // must not panic on double install
        log::info!("logger alive");
    }
}
