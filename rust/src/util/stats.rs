//! Summary statistics for benches and reports.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Full summary of a sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p95={:.4} p99={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95,
            self.p99, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn summary_orders() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.n, 3);
    }
}
