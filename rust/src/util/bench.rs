//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with summary statistics, and a
//! uniform report format that `cargo bench` binaries (harness = false)
//! print alongside the tables regenerating the paper's figures.

use std::time::Instant;

use super::stats::Summary;

/// Measure `f` for `iters` iterations after `warmup` untimed runs.
/// Returns per-iteration seconds.
pub fn time_iters<F: FnMut()>(warmup: u32, iters: u32, mut f: F)
    -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

/// Measure and report one benchmark case.
pub fn bench_case<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F)
    -> Summary {
    let samples = time_iters(warmup, iters, f);
    let s = Summary::of(&samples);
    println!(
        "bench {name:<40} {:>10.3} ms/iter (p50 {:.3}, p95 {:.3}, n={})",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.n
    );
    s
}

/// Section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_requested_samples() {
        let xs = time_iters(1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn bench_case_summarizes() {
        let s = bench_case("noop", 0, 3, || {});
        assert_eq!(s.n, 3);
    }
}
