//! ASCII time-series plots for terminal reports — used by the CLI and
//! benches to render Figure 10/11-style charts without a plotting stack.

/// Render stacked horizontal bars: one row per series, bar length
/// proportional to value, annotated with the numeric value.
pub fn barchart(title: &str, rows: &[(String, f64)], width: usize)
    -> String {
    let max = rows.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(4);
    let mut out = format!("{title}\n");
    for (label, v) in rows {
        let filled = if max > 0.0 {
            ((v / max) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!(
            "  {label:<label_w$} |{}{}| {v:.2}\n",
            "█".repeat(filled),
            " ".repeat(width.saturating_sub(filled)),
        ));
    }
    out
}

/// Render a multi-series step chart over time buckets, one character
/// column per bucket, one row per series; cell is the series glyph when
/// its value > 0 at that bucket, scaled by intensity (.:*#@).
pub fn heatline(name: &str, values: &[f64]) -> String {
    let max = values.iter().cloned().fold(f64::MIN, f64::max);
    let glyph = |v: f64| -> char {
        if v <= 0.0 || max <= 0.0 {
            '·'
        } else {
            // level 1..=4 over (0, max]; the max value renders '@'.
            let level = (v / max * 4.0).ceil() as usize;
            [' ', '.', ':', '*', '@'][level.min(4)]
        }
    };
    let line: String = values.iter().map(|&v| glyph(v)).collect();
    format!("{name:>14} {line}")
}

/// Full Figure-11-style chart: series of (label, per-bucket counts),
/// plus a time axis in `bucket_secs` units.
pub fn state_chart(series: &[(&str, Vec<f64>)], bucket_secs: f64)
    -> String {
    let mut out = String::new();
    for (label, values) in series {
        out.push_str(&heatline(label, values));
        out.push('\n');
    }
    let n = series.first().map(|(_, v)| v.len()).unwrap_or(0);
    // Axis: a tick every 10 buckets.
    let mut axis = String::from("               ");
    let mut i = 0;
    while i < n {
        let label = format!("{:<10}", format_mins(i as f64 * bucket_secs));
        axis.push_str(&label[..label.len().min(10)]);
        i += 10;
    }
    out.push_str(&axis);
    out.push('\n');
    out
}

fn format_mins(secs: f64) -> String {
    format!("{}m", (secs / 60.0).round() as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barchart_scales_and_aligns() {
        let rows = vec![("used".to_string(), 10.0),
                        ("idle".to_string(), 5.0)];
        let chart = barchart("states", &rows, 20);
        let lines: Vec<&str> = chart.lines().collect();
        assert_eq!(lines.len(), 3);
        let used_bar = lines[1].matches('█').count();
        let idle_bar = lines[2].matches('█').count();
        assert_eq!(used_bar, 20);
        assert_eq!(idle_bar, 10);
        assert!(lines[1].contains("10.00"));
    }

    #[test]
    fn barchart_empty_and_zero_safe() {
        assert!(barchart("t", &[], 10).starts_with('t'));
        let chart = barchart("t", &[("a".to_string(), 0.0)], 10);
        assert!(!chart.contains('█'));
    }

    #[test]
    fn heatline_glyph_intensity() {
        let line = heatline("used", &[0.0, 1.0, 5.0]);
        assert!(line.contains('·'));
        assert!(line.contains('@'));
    }

    #[test]
    fn state_chart_has_axis() {
        let chart = state_chart(&[("used", vec![1.0; 25]),
                                  ("idle", vec![0.0; 25])], 120.0);
        assert!(chart.contains("used"));
        assert!(chart.contains("20m"), "{chart}");
        assert_eq!(chart.lines().count(), 3);
    }
}
