//! YAML-subset parser for TOSCA templates and config files.
//!
//! Supports the subset TOSCA simple-profile documents actually use:
//! indentation-nested mappings, block sequences (`- item`), scalars
//! (string / int / float / bool / null), inline comments (`#`), quoted
//! strings, and flow lists (`[a, b]`). Anchors, aliases, multi-line
//! scalars and flow mappings are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Context};

/// Parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    List(Vec<Yaml>),
    /// Insertion-ordered mapping (order matters for deterministic output).
    Map(Vec<(String, Yaml)>),
}

impl Yaml {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Yaml::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Float(f) => Some(*f),
            Yaml::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::List(l) => Some(l),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Yaml)]> {
        match self {
            Yaml::Map(m) => Some(m),
            _ => None,
        }
    }

    /// Map lookup by key.
    pub fn get(&self, key: &str) -> Option<&Yaml> {
        self.as_map()?.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Dotted-path lookup: `get_path("topology.node_templates.wn")`.
    pub fn get_path(&self, path: &str) -> Option<&Yaml> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Convenience: string at dotted path.
    pub fn str_at(&self, path: &str) -> Option<&str> {
        self.get_path(path)?.as_str()
    }

    /// Convenience: integer at dotted path.
    pub fn i64_at(&self, path: &str) -> Option<i64> {
        self.get_path(path)?.as_i64()
    }
}

impl fmt::Display for Yaml {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Yaml::Null => write!(f, "null"),
            Yaml::Bool(b) => write!(f, "{b}"),
            Yaml::Int(i) => write!(f, "{i}"),
            Yaml::Float(x) => write!(f, "{x}"),
            Yaml::Str(s) => write!(f, "{s}"),
            Yaml::List(l) => {
                write!(f, "[")?;
                for (i, v) in l.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Yaml::Map(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k}: {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// A significant (non-blank, non-comment) line.
struct Line {
    indent: usize,
    text: String,
    lineno: usize,
}

fn strip_comment(s: &str) -> &str {
    // A '#' starts a comment unless inside quotes.
    let mut in_s = false;
    let mut in_d = false;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            '#' if !in_s && !in_d => {
                // Require preceding whitespace or start-of-line per YAML.
                if i == 0 || s.as_bytes()[i - 1].is_ascii_whitespace() {
                    return &s[..i];
                }
            }
            _ => {}
        }
    }
    s
}

fn significant_lines(src: &str) -> anyhow::Result<Vec<Line>> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        if raw.trim_start().starts_with('#') {
            continue;
        }
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        if trimmed.contains('\t') {
            bail!("line {}: tabs are not allowed in YAML", idx + 1);
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line {
            indent,
            text: trimmed.trim_start().to_string(),
            lineno: idx + 1,
        });
    }
    Ok(out)
}

/// Parse a scalar token (already trimmed).
pub fn parse_scalar(s: &str) -> Yaml {
    let t = s.trim();
    if t.is_empty() || t == "~" || t == "null" {
        return Yaml::Null;
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Yaml::Str(t[1..t.len() - 1].to_string());
    }
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Yaml::List(vec![]);
        }
        return Yaml::List(
            split_flow_items(inner).iter().map(|i| parse_scalar(i)).collect(),
        );
    }
    match t {
        "true" | "True" => return Yaml::Bool(true),
        "false" | "False" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Yaml::Int(i);
    }
    if let Ok(f) = t.parse::<f64>() {
        return Yaml::Float(f);
    }
    Yaml::Str(t.to_string())
}

/// Split `a, b, [c, d]` at top-level commas.
fn split_flow_items(s: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '[' => {
                depth += 1;
                cur.push(c);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => {
                items.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    items
}

/// Split `key: value` at the first top-level colon (not inside quotes).
fn split_key_value(line: &str) -> Option<(&str, &str)> {
    let mut in_s = false;
    let mut in_d = false;
    for (i, c) in line.char_indices() {
        match c {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            ':' if !in_s && !in_d => {
                let rest = &line[i + 1..];
                if rest.is_empty() || rest.starts_with(' ') {
                    return Some((line[..i].trim(), rest.trim()));
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse a YAML document into a [`Yaml`] tree.
pub fn parse(src: &str) -> anyhow::Result<Yaml> {
    let lines = significant_lines(src)?;
    if lines.is_empty() {
        return Ok(Yaml::Null);
    }
    let mut pos = 0usize;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        bail!(
            "line {}: unexpected content (inconsistent indentation?)",
            lines[pos].lineno
        );
    }
    Ok(v)
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize)
    -> anyhow::Result<Yaml> {
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize)
    -> anyhow::Result<Yaml> {
    let mut items = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = &lines[*pos];
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let body = line.text[1..].trim().to_string();
        *pos += 1;
        if body.is_empty() {
            // Nested block follows.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Yaml::Null);
            }
        } else if let Some((k, v)) = split_key_value(&body) {
            // "- key: value" — item is a mapping whose first entry is on
            // the dash line; further keys are indented deeper.
            let mut map: Vec<(String, Yaml)> = Vec::new();
            let first_val = if v.is_empty() {
                if *pos < lines.len() && lines[*pos].indent > indent + 2 {
                    let ci = lines[*pos].indent;
                    parse_block(lines, pos, ci)?
                } else {
                    Yaml::Null
                }
            } else {
                parse_scalar(v)
            };
            map.push((k.to_string(), first_val));
            while *pos < lines.len() && lines[*pos].indent == indent + 2 {
                let l = &lines[*pos];
                let (k2, v2) = split_key_value(&l.text).with_context(|| {
                    format!("line {}: expected key: value", l.lineno)
                })?;
                *pos += 1;
                let val = if v2.is_empty() {
                    if *pos < lines.len() && lines[*pos].indent > indent + 2 {
                        let ci = lines[*pos].indent;
                        parse_block(lines, pos, ci)?
                    } else {
                        Yaml::Null
                    }
                } else {
                    parse_scalar(v2)
                };
                map.push((k2.to_string(), val));
            }
            items.push(Yaml::Map(map));
        } else {
            items.push(parse_scalar(&body));
        }
    }
    Ok(Yaml::List(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize)
    -> anyhow::Result<Yaml> {
    let mut map: Vec<(String, Yaml)> = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = &lines[*pos];
        if line.text.starts_with("- ") || line.text == "-" {
            break;
        }
        let (k, v) = split_key_value(&line.text).with_context(|| {
            format!("line {}: expected `key: value`", line.lineno)
        })?;
        if map.iter().any(|(existing, _)| existing == k) {
            bail!("line {}: duplicate key {k:?}", line.lineno);
        }
        *pos += 1;
        let value = if v.is_empty() {
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                parse_block(lines, pos, child_indent)?
            } else {
                Yaml::Null
            }
        } else {
            parse_scalar(v)
        };
        map.push((k.to_string(), value));
    }
    Ok(Yaml::Map(map))
}

/// Flatten a map into `BTreeMap<dotted.path, scalar-as-string>` — handy
/// for config diffing in tests.
pub fn flatten(y: &Yaml) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    fn rec(prefix: &str, y: &Yaml, out: &mut BTreeMap<String, String>) {
        match y {
            Yaml::Map(m) => {
                for (k, v) in m {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    rec(&p, v, out);
                }
            }
            Yaml::List(l) => {
                for (i, v) in l.iter().enumerate() {
                    rec(&format!("{prefix}[{i}]"), v, out);
                }
            }
            other => {
                out.insert(prefix.to_string(), other.to_string());
            }
        }
    }
    rec("", y, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse_scalar("42"), Yaml::Int(42));
        assert_eq!(parse_scalar("4.5"), Yaml::Float(4.5));
        assert_eq!(parse_scalar("true"), Yaml::Bool(true));
        assert_eq!(parse_scalar("null"), Yaml::Null);
        assert_eq!(parse_scalar("\"42\""), Yaml::Str("42".into()));
        assert_eq!(parse_scalar("'a b'"), Yaml::Str("a b".into()));
        assert_eq!(
            parse_scalar("[1, 2, x]"),
            Yaml::List(vec![Yaml::Int(1), Yaml::Int(2), Yaml::Str("x".into())])
        );
    }

    #[test]
    fn nested_mapping() {
        let doc = "\
a:
  b:
    c: 1
  d: two
e: 3.5
";
        let y = parse(doc).unwrap();
        assert_eq!(y.i64_at("a.b.c"), Some(1));
        assert_eq!(y.str_at("a.d"), Some("two"));
        assert_eq!(y.get_path("e").unwrap().as_f64(), Some(3.5));
    }

    #[test]
    fn block_sequence_of_scalars_and_maps() {
        let doc = "\
items:
  - 1
  - two
  - name: x
    size: 4
hosts:
  - host: a
  - host: b
";
        let y = parse(doc).unwrap();
        let items = y.get("items").unwrap().as_list().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[0], Yaml::Int(1));
        assert_eq!(items[2].get("size").unwrap().as_i64(), Some(4));
        let hosts = y.get("hosts").unwrap().as_list().unwrap();
        assert_eq!(hosts[1].str_at("host"), Some("b"));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = "\
# header
a: 1   # trailing

b: 'with # not comment'
";
        let y = parse(doc).unwrap();
        assert_eq!(y.i64_at("a"), Some(1));
        assert_eq!(y.str_at("b"), Some("with # not comment"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn tabs_rejected() {
        assert!(parse("a:\n\tb: 1\n").is_err());
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("# nothing\n").unwrap(), Yaml::Null);
    }

    #[test]
    fn colon_in_quoted_key_value() {
        let y = parse("url: \"http://x:80/\"\n").unwrap();
        assert_eq!(y.str_at("url"), Some("http://x:80/"));
    }

    #[test]
    fn tosca_like_document() {
        let doc = "\
tosca_definitions_version: tosca_simple_yaml_1_0
topology_template:
  inputs:
    wn_num:
      type: integer
      default: 5
  node_templates:
    lrms_front_end:
      type: tosca.nodes.indigo.LRMS.FrontEnd.Slurm
      properties:
        wn_ips: [10.0.1.2, 10.0.1.3]
    wn:
      type: tosca.nodes.indigo.LRMS.WorkerNode.Slurm
      capabilities:
        scalable:
          properties:
            count: 2
            max_instances: 5
";
        let y = parse(doc).unwrap();
        assert_eq!(
            y.i64_at("topology_template.inputs.wn_num.default"),
            Some(5)
        );
        assert_eq!(
            y.i64_at("topology_template.node_templates.wn.capabilities.scalable.properties.max_instances"),
            Some(5)
        );
        let ips = y
            .get_path("topology_template.node_templates.lrms_front_end.properties.wn_ips")
            .unwrap()
            .as_list()
            .unwrap();
        assert_eq!(ips.len(), 2);
    }

    #[test]
    fn flatten_paths() {
        let y = parse("a:\n  b: 1\nc:\n  - x\n  - y\n").unwrap();
        let f = flatten(&y);
        assert_eq!(f["a.b"], "1");
        assert_eq!(f["c[1]"], "y");
    }
}
