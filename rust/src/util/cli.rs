//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// One option specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(meta) => takes a value shown as <meta>.
    pub value: Option<&'static str>,
    pub default: Option<&'static str>,
}

/// Declarative command description.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, ..Default::default() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, value: None, default: None });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        meta: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, value: Some(meta), default });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Parse `args` (without the program/subcommand names themselves).
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Parsed> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();
        for spec in &self.opts {
            if let (Some(_), Some(d)) = (spec.value, spec.default) {
                values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                bail!("{}", self.help_text());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == key)
                    .with_context(|| format!(
                        "unknown option --{key}\n{}", self.help_text()))?;
                match spec.value {
                    None => {
                        if inline.is_some() {
                            bail!("flag --{key} takes no value");
                        }
                        flags.push(key.to_string());
                    }
                    Some(_) => {
                        let v = match inline {
                            Some(v) => v,
                            None => it
                                .next()
                                .with_context(|| format!(
                                    "option --{key} expects a value"))?
                                .clone(),
                        };
                        values.insert(key.to_string(), v);
                    }
                }
            } else {
                pos.push(a.clone());
            }
        }
        if pos.len() > self.positionals.len() {
            bail!(
                "unexpected positional argument {:?}\n{}",
                pos[self.positionals.len()],
                self.help_text()
            );
        }
        Ok(Parsed { values, flags, positionals: pos })
    }

    /// Generated usage/help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about,
                            self.name);
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.positionals.is_empty() {
            s.push_str("\n\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        s.push_str("\n\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = match o.value {
                Some(meta) => format!("--{} <{}>", o.name, meta),
                None => format!("--{}", o.name),
            };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {lhs:<28} {}{def}\n", o.help));
        }
        s.push_str("  --help                       print this help\n");
        s
    }
}

/// Parse result with typed accessors.
#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .with_context(|| format!("missing required option --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{name}={raw}: {e}"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("demo", "demo command")
            .flag("verbose", "more output")
            .opt("count", "N", Some("3"), "how many")
            .opt("name", "S", None, "a name")
            .positional("file", "input file")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_options_positionals() {
        let p = cmd()
            .parse(&sv(&["--verbose", "--count", "7", "--name=zed", "in.txt"]))
            .unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.get_parsed::<u32>("count").unwrap(), 7);
        assert_eq!(p.get("name"), Some("zed"));
        assert_eq!(p.positional(0), Some("in.txt"));
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(p.get_parsed::<u32>("count").unwrap(), 3);
        assert!(!p.flag("verbose"));
        assert_eq!(p.get("name"), None);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(cmd().parse(&sv(&["--bogus"])).is_err());
        assert!(cmd().parse(&sv(&["--count"])).is_err());
        assert!(cmd().parse(&sv(&["--verbose=1"])).is_err());
        assert!(cmd().parse(&sv(&["a", "b"])).is_err());
    }

    #[test]
    fn help_lists_everything() {
        let h = cmd().help_text();
        for needle in ["--verbose", "--count <N>", "[default: 3]", "<file>"] {
            assert!(h.contains(needle), "missing {needle} in help:\n{h}");
        }
    }
}
