//! Deterministic observability: causal spans, on-clock metrics and the
//! wall-clock engine profiler.
//!
//! The layer has three parts with one contract between them:
//!
//! * **Causal spans on the simulation clock** — [`TraceShard`] buffers
//!   job/node lifecycle spans and chaos/broker instant events per
//!   shard, exactly like the metrics `Recorder`: the control plane
//!   owns shard 0, every `SiteWorld` owns shard `site + 1`, and
//!   [`Trace::merge_shards`] restores the global causal order by the
//!   same `(time, shard, seq)` key the engines themselves merge by.
//!   The merged stream exports as Chrome trace-event JSON
//!   ([`Trace::to_chrome_json`], loadable in Perfetto / `chrome://
//!   tracing`) and as CSV ([`Trace::to_csv`]).
//! * **On-clock time-series metrics** — [`MetricsRegistry`] samples
//!   per-site gauges (queue depth, running/idle nodes, health score,
//!   open-ledger $/h burn, cumulative chaos counters) on the existing
//!   CluesTick grid, from the control shard only, and exports a
//!   long-format CSV ([`MetricsSeries::to_csv`]).
//! * **Wall-clock engine profiler** — [`EngineProfile`] (defined with
//!   the engines in `sim::shard`, re-exported here) attributes
//!   parallel-engine wall time to shard work vs control-barrier
//!   dispatch vs injector waiting.
//!
//! # The observability contract
//!
//! Sim-clock data (traces, metrics) is **purely passive**: recording
//! never draws randomness, never schedules an event and never feeds
//! back into a simulation decision, so enabling it cannot perturb
//! `RunReport::determinism_digest()` — and because every emission
//! point runs at a deterministic `(time, shard, seq)` position, the
//! merged trace and metrics streams are **byte-identical across the
//! Serial/Sharded/Stealing engines** (property-proven in
//! `tests/broker_policies.rs`). Wall-clock data (the profiler) is the
//! exact opposite — nondeterministic by nature — and therefore **never
//! enters a digest**; it lives only in `RunReport::profile` and the
//! `perf_profile` section of `BENCH_scale.json`.

use std::fmt::Write as _;

use crate::sim::SimTime;
use crate::util::csv::Table;

pub use crate::sim::shard::EngineProfile;

/// Observability knobs carried by `RunConfig`. Both default to off:
/// a default run records nothing and allocates nothing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// Record causal spans and instant events (sim-clock,
    /// deterministic, digest-neutral).
    pub trace: bool,
    /// Sample the CluesTick metrics grid (sim-clock, deterministic,
    /// digest-neutral).
    pub metrics: bool,
}

impl ObsConfig {
    /// Everything on — what the examples and property tests use.
    pub fn enabled() -> ObsConfig {
        ObsConfig { trace: true, metrics: true }
    }

    /// True if any sim-clock stream is recording.
    pub fn any(&self) -> bool {
        self.trace || self.metrics
    }
}

/// Chrome trace-event phase of one recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A complete span (`ph: "X"`): `start`..`start + dur_s`.
    Span,
    /// An instant (`ph: "i"`): a point at `at`.
    Instant,
}

/// One recorded trace event. `at` is the sim time the emitting handler
/// observed — the merge key; a span emitted retrospectively (e.g. a
/// job's queue wait, recorded when its completion report lands) keeps
/// its true `start` while merging at its emission time.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Emission time (merge key).
    pub at: SimTime,
    /// Span start (equals `at` for instants).
    pub start: SimTime,
    /// Span duration in sim seconds (0 for instants).
    pub dur_s: f64,
    pub phase: TracePhase,
    /// Category lane: `"job"`, `"node"`, `"chaos"`, `"broker"`,
    /// `"scenario"`.
    pub cat: &'static str,
    /// Event name, e.g. `"job.run"` or `"wan.drop"`.
    pub name: String,
    /// Preformatted detail (rendered under `args.detail`).
    pub detail: String,
}

/// Per-shard trace buffer. Mirrors the metrics `Recorder`: the control
/// plane records into shard 0, site `i` into shard `i + 1`, each from
/// its own event handlers only, so no lock is ever needed and the
/// per-shard push order is the shard's deterministic dispatch order.
///
/// Recording is passive by construction — the sink only ever appends
/// to its own buffer. Callers must guard detail-string formatting with
/// [`TraceShard::enabled`] so a disabled sink costs nothing.
#[derive(Debug)]
pub struct TraceShard {
    shard: u32,
    enabled: bool,
    events: Vec<TraceEvent>,
}

impl TraceShard {
    pub fn new(shard: u32, enabled: bool) -> TraceShard {
        TraceShard { shard, enabled, events: Vec::new() }
    }

    /// A permanently-off sink (what default runs carry).
    pub fn off(shard: u32) -> TraceShard {
        TraceShard::new(shard, false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn shard(&self) -> u32 {
        self.shard
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Record a complete span `[start, end]` emitted at `at`.
    pub fn span(&mut self, at: SimTime, cat: &'static str,
                name: impl Into<String>, start: SimTime, end: SimTime,
                detail: String) {
        if !self.enabled {
            return;
        }
        self.events.push(TraceEvent {
            at,
            start,
            dur_s: (end.0 - start.0).max(0.0),
            phase: TracePhase::Span,
            cat,
            name: name.into(),
            detail,
        });
    }

    /// Record an instant event at `at`.
    pub fn instant(&mut self, at: SimTime, cat: &'static str,
                   name: impl Into<String>, detail: String) {
        if !self.enabled {
            return;
        }
        self.events.push(TraceEvent {
            at,
            start: at,
            dur_s: 0.0,
            phase: TracePhase::Instant,
            cat,
            name: name.into(),
            detail,
        });
    }
}

/// The merged causal trace of one run: every shard's events restored
/// to the global `(time, shard, seq)` order — the same key the
/// engines merge events by, so the merged stream is identical however
/// the run was parallelized.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    /// `(source shard, event)` in merged order.
    pub events: Vec<(u32, TraceEvent)>,
}

impl Trace {
    /// Merge per-shard buffers exactly like `Recorder::merge_shards`:
    /// stable on `(emission time, shard index, per-shard seq)` with
    /// `total_cmp` on time, so the order never depends on float noise
    /// or map iteration.
    pub fn merge_shards(shards: Vec<TraceShard>) -> Trace {
        let mut keyed: Vec<(f64, u32, usize, TraceEvent)> = Vec::new();
        for sh in shards {
            let shard = sh.shard;
            for (k, ev) in sh.events.into_iter().enumerate() {
                keyed.push((ev.at.0, shard, k, ev));
            }
        }
        keyed.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
        });
        Trace {
            events: keyed.into_iter().map(|(_, s, _, e)| (s, e)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Export as Chrome trace-event JSON (the plain array format —
    /// loadable in Perfetto and `chrome://tracing`). Sim seconds map
    /// to trace microseconds; `pid` is the run, `tid` the shard
    /// (0 = control plane, `i + 1` = site `i`).
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::from("[");
        for (i, (shard, ev)) in self.events.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str("\n  ");
            let ts = ev.start.0 * 1e6;
            match ev.phase {
                TracePhase::Span => {
                    let _ = write!(
                        s,
                        "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"X\",\
                         \"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\
                         \"args\":{{\"detail\":{}}}}}",
                        json_str(&ev.name), ev.cat, ts, ev.dur_s * 1e6,
                        shard, json_str(&ev.detail)
                    );
                }
                TracePhase::Instant => {
                    let _ = write!(
                        s,
                        "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"i\",\
                         \"ts\":{},\"pid\":0,\"tid\":{},\"s\":\"t\",\
                         \"args\":{{\"detail\":{}}}}}",
                        json_str(&ev.name), ev.cat, ts, shard,
                        json_str(&ev.detail)
                    );
                }
            }
        }
        s.push_str("\n]\n");
        s
    }

    /// Export as CSV, one row per event in merged order.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(vec![
            "time_s", "shard", "phase", "cat", "name", "start_s",
            "dur_s", "detail",
        ]);
        for (shard, ev) in &self.events {
            t.push(vec![
                format!("{}", ev.at.0),
                format!("{shard}"),
                match ev.phase {
                    TracePhase::Span => "span".to_string(),
                    TracePhase::Instant => "instant".to_string(),
                },
                ev.cat.to_string(),
                ev.name.clone(),
                format!("{}", ev.start.0),
                format!("{}", ev.dur_s),
                ev.detail.clone(),
            ]);
        }
        t.to_csv()
    }
}

/// Escape a string as a JSON string literal.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Site index of cluster-wide metric rows (rendered as `"cluster"`).
pub const METRIC_SITE_CLUSTER: u32 = u32::MAX;

/// One long-format metric sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricSample {
    pub t: SimTime,
    /// Site index, or [`METRIC_SITE_CLUSTER`] for cluster-wide series.
    pub site: u32,
    pub metric: &'static str,
    pub value: f64,
}

/// On-clock gauge sampler. Owned and driven by the control plane only
/// (the CluesTick handler runs on the control shard, a global barrier,
/// so cross-site reads there are race-free and deterministic) — no
/// per-shard merge is needed.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    enabled: bool,
    samples: Vec<MetricSample>,
}

impl MetricsRegistry {
    pub fn new(enabled: bool) -> MetricsRegistry {
        MetricsRegistry { enabled, samples: Vec::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Record one per-site sample (no-op while disabled).
    pub fn sample(&mut self, t: SimTime, site: u32, metric: &'static str,
                  value: f64) {
        if self.enabled {
            self.samples.push(MetricSample { t, site, metric, value });
        }
    }

    /// Record one cluster-wide sample (no-op while disabled).
    pub fn sample_cluster(&mut self, t: SimTime, metric: &'static str,
                          value: f64) {
        self.sample(t, METRIC_SITE_CLUSTER, metric, value);
    }

    /// Freeze into the exportable series, naming sites for the CSV.
    pub fn into_series(self, site_names: Vec<String>) -> MetricsSeries {
        MetricsSeries { site_names, samples: self.samples }
    }
}

/// The frozen time-series of one run, exportable as long-format CSV.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSeries {
    pub site_names: Vec<String>,
    pub samples: Vec<MetricSample>,
}

impl MetricsSeries {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Long-format CSV: `time_s,site,metric,value` — one gauge sample
    /// per row, ready for a dataframe or gnuplot without reshaping.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(vec!["time_s", "site", "metric", "value"]);
        for s in &self.samples {
            let site = if s.site == METRIC_SITE_CLUSTER {
                "cluster".to_string()
            } else {
                self.site_names
                    .get(s.site as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("site-{}", s.site))
            };
            t.push(vec![
                format!("{}", s.t.0),
                site,
                s.metric.to_string(),
                format!("{}", s.value),
            ]);
        }
        t.to_csv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    #[test]
    fn disabled_sinks_record_nothing() {
        let mut tr = TraceShard::off(0);
        tr.span(t(1.0), "job", "job.run", t(0.0), t(1.0), String::new());
        tr.instant(t(2.0), "chaos", "wan.drop", String::new());
        assert!(tr.is_empty());
        let mut m = MetricsRegistry::new(false);
        m.sample(t(1.0), 0, "queue_depth", 3.0);
        assert!(m.is_empty());
    }

    #[test]
    fn merge_restores_time_shard_seq_order() {
        let mut control = TraceShard::new(0, true);
        let mut site = TraceShard::new(1, true);
        site.instant(t(1.0), "chaos", "wan.drop", "a".into());
        site.instant(t(1.0), "chaos", "wan.drop", "b".into());
        control.instant(t(1.0), "broker", "decision", String::new());
        control.instant(t(0.5), "node", "requested", String::new());
        let merged = Trace::merge_shards(vec![site, control]);
        let names: Vec<&str> =
            merged.events.iter().map(|(_, e)| e.name.as_str()).collect();
        // Time first, then shard (control=0 before site=1), then the
        // per-shard push order.
        assert_eq!(names,
                   vec!["requested", "decision", "wan.drop", "wan.drop"]);
        assert_eq!(merged.events[2].1.detail, "a");
        assert_eq!(merged.events[3].1.detail, "b");
    }

    #[test]
    fn chrome_json_is_well_formed_and_escaped() {
        let mut tr = TraceShard::new(2, true);
        tr.span(t(3.0), "job", "job.run", t(1.0), t(3.0),
                "job \"7\"\nnode n1".into());
        tr.instant(t(3.5), "chaos", "wan.drop", String::new());
        let json = Trace::merge_shards(vec![tr]).to_chrome_json();
        assert!(json.starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"tid\":2"));
        assert!(json.contains("\\\"7\\\"\\nnode"));
        // µs mapping: the span starts at 1 s = 1e6 µs, lasts 2e6 µs.
        assert!(json.contains("\"ts\":1000000"));
        assert!(json.contains("\"dur\":2000000"));
        // Parses under the crate's own JSON reader.
        let parsed = crate::api::json::parse(&json).expect("valid json");
        match parsed {
            crate::api::json::Json::Array(rows) => {
                assert_eq!(rows.len(), 2)
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn trace_csv_has_one_row_per_event() {
        let mut tr = TraceShard::new(0, true);
        tr.instant(t(1.0), "broker", "decision", "ranked=[0,1]".into());
        tr.span(t(2.0), "node", "node.boot", t(0.0), t(2.0),
                "wn-1".into());
        let csv = Trace::merge_shards(vec![tr]).to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("time_s,shard,phase,cat,name"));
        assert!(csv.contains("instant"));
        assert!(csv.contains("span"));
    }

    #[test]
    fn metrics_series_renders_long_format() {
        let mut m = MetricsRegistry::new(true);
        m.sample(t(60.0), 0, "queue_depth", 12.0);
        m.sample(t(60.0), 1, "health", 0.5);
        m.sample_cluster(t(60.0), "jobs_pending", 40.0);
        let series =
            m.into_series(vec!["CESNET".to_string(), "AWS".to_string()]);
        let csv = series.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.contains("60,CESNET,queue_depth,12"));
        assert!(csv.contains("60,AWS,health,0.5"));
        assert!(csv.contains("60,cluster,jobs_pending,40"));
    }
}
