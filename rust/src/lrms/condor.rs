//! HTCondor-flavoured LRMS plugin: matchmaking that spreads jobs across
//! the pool (breadth-first), demonstrating the CLUES plugin architecture
//! beyond SLURM.

use super::core::{BatchCore, Placement};
use super::{Assignment, Job, JobId, Lrms, NodeHealth, NodeId, NodeInfo,
            NodeNames, NodeStat};
use crate::sim::SimTime;

/// HTCondor-like pool (`condor_collector`+`negotiator` analogue).
#[derive(Debug)]
pub struct HtCondor {
    core: BatchCore,
}

impl HtCondor {
    pub fn new() -> HtCondor {
        HtCondor { core: BatchCore::new(Placement::SpreadMostFree) }
    }

    /// Share a cluster-wide interner so ids line up across subsystems.
    pub fn with_names(names: NodeNames) -> HtCondor {
        HtCondor {
            core: BatchCore::with_names(Placement::SpreadMostFree, names),
        }
    }
}

impl Default for HtCondor {
    fn default() -> Self {
        Self::new()
    }
}

impl Lrms for HtCondor {
    fn kind(&self) -> &'static str {
        "htcondor"
    }

    fn register_node(&mut self, name: &str, slots: u32, t: SimTime) {
        self.core.register_node(name, slots, t)
    }

    fn deregister_node(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        self.core.deregister_node(name, t)
    }

    fn set_node_health(&mut self, name: &str, health: NodeHealth, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        self.core.set_node_health(name, health, t)
    }

    fn submit(&mut self, name: &str, slots: u32, t: SimTime) -> JobId {
        self.core.submit(name, slots, t)
    }

    fn submit_batch(&mut self, count: u32, slots: u32, t: SimTime) {
        self.core.submit_batch(count, slots, t)
    }

    fn cancel(&mut self, id: JobId, t: SimTime) -> anyhow::Result<()> {
        self.core.cancel(id, t)
    }

    fn schedule(&mut self, t: SimTime) -> Vec<Assignment> {
        self.core.schedule(t)
    }

    fn on_job_finished(&mut self, id: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()> {
        self.core.on_job_finished(id, ok, t)
    }

    fn job(&self, id: JobId) -> Option<&Job> {
        self.core.job(id)
    }

    fn jobs(&self) -> Vec<&Job> {
        self.core.jobs()
    }

    fn nodes(&self) -> Vec<NodeInfo> {
        self.core.nodes()
    }

    fn node_id(&self, name: &str) -> Option<NodeId> {
        self.core.node_id(name)
    }

    fn node_name(&self, id: NodeId) -> Option<String> {
        self.core.node_name(id)
    }

    fn node_stat(&self, id: NodeId) -> Option<NodeStat> {
        self.core.node_stat(id)
    }

    fn node_stats(&self) -> Vec<NodeStat> {
        self.core.node_stats()
    }

    fn node_stats_into(&self, out: &mut Vec<NodeStat>) {
        self.core.node_stats_into(out)
    }

    fn pending(&self) -> usize {
        self.core.pending()
    }

    fn running(&self) -> usize {
        self.core.running()
    }

    fn free_slots(&self) -> u32 {
        self.core.free_slots()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spreads_breadth_first() {
        let mut c = HtCondor::new();
        c.register_node("e1", 2, SimTime(0.0));
        c.register_node("e2", 2, SimTime(0.0));
        c.submit("a", 1, SimTime(0.0));
        c.submit("b", 1, SimTime(0.0));
        let assigned = c.schedule(SimTime(0.0));
        let nodes: Vec<String> = assigned
            .iter()
            .map(|(_, n)| c.node_name(*n).unwrap())
            .collect();
        assert!(nodes.iter().any(|n| n == "e1")
                && nodes.iter().any(|n| n == "e2"),
                "{nodes:?}");
    }
}
