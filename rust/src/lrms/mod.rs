//! Local Resource Management System (LRMS) abstraction.
//!
//! The paper's cluster runs SLURM; CLUES supports several LRMS through
//! plugins (HTCondor, SGE, Mesos, Kubernetes, Nomad…). We model that
//! plugin architecture with the [`Lrms`] trait, a shared batch-system
//! core ([`core::BatchCore`]), and two concrete plugins: [`slurm::Slurm`]
//! (FIFO, depth-first packing) and [`condor::HtCondor`] (matchmaking,
//! breadth-first spreading).
//!
//! Node identity inside the scheduler is a dense interned [`NodeId`];
//! names appear only at the registration/reporting edges. Assignments
//! and [`Job::node`] carry ids — resolve through [`Lrms::node_name`]
//! when a human-readable name is needed.

pub mod condor;
pub mod core;
pub mod partition;
pub mod slurm;

pub use condor::HtCondor;
pub use partition::PartitionedLrms;
pub use slurm::Slurm;

pub use crate::ids::{NodeId, NodeNames};

use crate::sim::SimTime;

/// Cluster-wide job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Job lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Failed,
    Cancelled,
}

/// One batch job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    /// Slots consumed on its node (the paper's audio jobs take a whole
    /// 2-vCPU node, i.e. 1 node-slot).
    pub slots: u32,
    pub state: JobState,
    pub submitted_at: SimTime,
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    /// Node the job runs (or last ran) on.
    pub node: Option<NodeId>,
    /// Times the job was requeued after a node failure.
    pub requeues: u32,
}

/// Node health as seen by the LRMS controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeHealth {
    Up,
    /// Not responding (real failure or transient flap — the LRMS cannot
    /// tell the difference, which is exactly the paper's vnode-5 story).
    Down,
    /// Administratively draining (no new jobs).
    Drain,
}

/// Snapshot of one registered node (name-resolving; reporting edge).
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub id: NodeId,
    pub name: String,
    pub slots: u32,
    pub used_slots: u32,
    pub health: NodeHealth,
    pub registered_at: SimTime,
    /// Last instant the node transitioned to fully idle.
    pub idle_since: Option<SimTime>,
}

impl NodeInfo {
    pub fn is_idle(&self) -> bool {
        self.used_slots == 0 && self.health == NodeHealth::Up
    }
}

/// Allocation-light node snapshot (no `String`): what monitoring loops
/// (CLUES) iterate at scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeStat {
    pub id: NodeId,
    pub slots: u32,
    pub used_slots: u32,
    pub health: NodeHealth,
    pub registered_at: SimTime,
    pub idle_since: Option<SimTime>,
}

impl NodeStat {
    pub fn is_idle(&self) -> bool {
        self.used_slots == 0 && self.health == NodeHealth::Up
    }
}

/// Scheduling decision: job → node assignments made by one sweep.
pub type Assignment = (JobId, NodeId);

/// The LRMS plugin interface (what CLUES and the cluster façade consume).
pub trait Lrms {
    /// Plugin name ("slurm", "htcondor").
    fn kind(&self) -> &'static str;

    /// Add a node with `slots` job slots (WN joined the cluster).
    fn register_node(&mut self, name: &str, slots: u32, t: SimTime);

    /// Remove a node entirely (it was terminated). Running jobs on it are
    /// requeued. Returns requeued job ids.
    fn deregister_node(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>>;

    /// Update node health; `Down` requeues that node's running jobs.
    /// Returns requeued job ids.
    fn set_node_health(&mut self, name: &str, health: NodeHealth, t: SimTime)
        -> anyhow::Result<Vec<JobId>>;

    /// Submit a job; it starts Pending. A job occupies at least one
    /// slot — `slots` is clamped to ≥ 1 (zero-slot jobs would be
    /// invisible to the free-slot placement indexes).
    fn submit(&mut self, name: &str, slots: u32, t: SimTime) -> JobId;

    /// Submit `count` identical anonymous `slots`-wide jobs in one
    /// call — the workload-block fast path. The default delegates to
    /// [`Lrms::submit`] per job; the batch-core plugins override it
    /// with one bulk `BatchCore` call, so a 100k-job block is a single
    /// core call instead of 100k trait dispatches.
    fn submit_batch(&mut self, count: u32, slots: u32, t: SimTime) {
        for _ in 0..count {
            self.submit("", slots, t);
        }
    }

    /// Cancel a pending job.
    fn cancel(&mut self, id: JobId, t: SimTime) -> anyhow::Result<()>;

    /// One scheduling sweep: assign pending jobs to free slots.
    fn schedule(&mut self, t: SimTime) -> Vec<Assignment>;

    /// Mark a running job finished (ok) or failed.
    fn on_job_finished(&mut self, id: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()>;

    fn job(&self, id: JobId) -> Option<&Job>;
    fn jobs(&self) -> Vec<&Job>;
    fn nodes(&self) -> Vec<NodeInfo>;

    /// Id of a currently-registered node, if any.
    fn node_id(&self, name: &str) -> Option<NodeId>;

    /// Name of a currently-registered node, if any.
    fn node_name(&self, id: NodeId) -> Option<String>;

    /// O(1) single-node snapshot.
    fn node_stat(&self, id: NodeId) -> Option<NodeStat>;

    /// Allocation-light snapshots of every node (registration order).
    fn node_stats(&self) -> Vec<NodeStat>;

    /// Fill `out` with the same snapshots as [`Lrms::node_stats`],
    /// reusing its capacity — monitoring loops (the CLUES tick) pass a
    /// scratch buffer so a 10k-node tick allocates nothing at steady
    /// state. Implementations should override the default, which
    /// delegates to `node_stats` and only saves the outer allocation.
    fn node_stats_into(&self, out: &mut Vec<NodeStat>) {
        out.clear();
        out.extend(self.node_stats());
    }

    /// Pending-queue depth — the elasticity signal CLUES polls.
    fn pending(&self) -> usize;
    fn running(&self) -> usize;

    /// Total free Up slots right now.
    fn free_slots(&self) -> u32 {
        self.node_stats()
            .iter()
            .filter(|n| n.health == NodeHealth::Up)
            .map(|n| n.slots - n.used_slots)
            .sum()
    }
}

#[cfg(test)]
mod trait_tests {
    use super::*;

    /// Exercise both plugins through the trait object to ensure the
    /// plugin architecture actually abstracts them.
    fn exercise(mut l: Box<dyn Lrms>) {
        let t0 = SimTime(0.0);
        l.register_node("n1", 1, t0);
        l.register_node("n2", 1, t0);
        let a = l.submit("job-a", 1, t0);
        let b = l.submit("job-b", 1, t0);
        let c = l.submit("job-c", 1, t0);
        assert_eq!(l.pending(), 3);
        let assigned = l.schedule(SimTime(1.0));
        assert_eq!(assigned.len(), 2);
        assert_eq!(l.pending(), 1);
        assert_eq!(l.running(), 2);
        // Assignments resolve back to registered names.
        for (_, nid) in &assigned {
            let name = l.node_name(*nid).expect("assigned node has a name");
            assert!(name.starts_with('n'), "{name}");
            assert_eq!(l.node_id(&name), Some(*nid));
        }
        l.on_job_finished(a, true, SimTime(10.0)).unwrap();
        let again = l.schedule(SimTime(10.0));
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].0, c);
        l.on_job_finished(b, true, SimTime(11.0)).unwrap();
        l.on_job_finished(c, true, SimTime(12.0)).unwrap();
        assert_eq!(l.running(), 0);
        assert!(l.nodes().iter().all(|n| n.is_idle()));
        assert_eq!(l.free_slots(), 2);
    }

    #[test]
    fn slurm_through_trait() {
        exercise(Box::new(Slurm::new()));
    }

    #[test]
    fn condor_through_trait() {
        exercise(Box::new(HtCondor::new()));
    }
}
