//! SLURM-flavoured LRMS plugin: FIFO queue, depth-first node packing —
//! the batch system used in the paper's use case.

use super::core::{BatchCore, Placement};
use super::{Assignment, Job, JobId, Lrms, NodeHealth, NodeId, NodeInfo,
            NodeNames, NodeStat};
use crate::sim::SimTime;

/// SLURM-like controller (`slurmctld` analogue).
#[derive(Debug)]
pub struct Slurm {
    core: BatchCore,
}

impl Slurm {
    pub fn new() -> Slurm {
        Slurm { core: BatchCore::new(Placement::PackFirstFit) }
    }

    /// Share a cluster-wide interner so ids line up across subsystems.
    pub fn with_names(names: NodeNames) -> Slurm {
        Slurm { core: BatchCore::with_names(Placement::PackFirstFit, names) }
    }
}

impl Default for Slurm {
    fn default() -> Self {
        Self::new()
    }
}

impl Lrms for Slurm {
    fn kind(&self) -> &'static str {
        "slurm"
    }

    fn register_node(&mut self, name: &str, slots: u32, t: SimTime) {
        self.core.register_node(name, slots, t)
    }

    fn deregister_node(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        self.core.deregister_node(name, t)
    }

    fn set_node_health(&mut self, name: &str, health: NodeHealth, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        self.core.set_node_health(name, health, t)
    }

    fn submit(&mut self, name: &str, slots: u32, t: SimTime) -> JobId {
        self.core.submit(name, slots, t)
    }

    fn submit_batch(&mut self, count: u32, slots: u32, t: SimTime) {
        self.core.submit_batch(count, slots, t)
    }

    fn cancel(&mut self, id: JobId, t: SimTime) -> anyhow::Result<()> {
        self.core.cancel(id, t)
    }

    fn schedule(&mut self, t: SimTime) -> Vec<Assignment> {
        self.core.schedule(t)
    }

    fn on_job_finished(&mut self, id: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()> {
        self.core.on_job_finished(id, ok, t)
    }

    fn job(&self, id: JobId) -> Option<&Job> {
        self.core.job(id)
    }

    fn jobs(&self) -> Vec<&Job> {
        self.core.jobs()
    }

    fn nodes(&self) -> Vec<NodeInfo> {
        self.core.nodes()
    }

    fn node_id(&self, name: &str) -> Option<NodeId> {
        self.core.node_id(name)
    }

    fn node_name(&self, id: NodeId) -> Option<String> {
        self.core.node_name(id)
    }

    fn node_stat(&self, id: NodeId) -> Option<NodeStat> {
        self.core.node_stat(id)
    }

    fn node_stats(&self) -> Vec<NodeStat> {
        self.core.node_stats()
    }

    fn node_stats_into(&self, out: &mut Vec<NodeStat>) {
        self.core.node_stats_into(out)
    }

    fn pending(&self) -> usize {
        self.core.pending()
    }

    fn running(&self) -> usize {
        self.core.running()
    }

    fn free_slots(&self) -> u32 {
        self.core.free_slots()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_is_respected() {
        let mut s = Slurm::new();
        s.register_node("wn1", 1, SimTime(0.0));
        let ids: Vec<JobId> = (0..5)
            .map(|i| s.submit(&format!("j{i}"), 1, SimTime(i as f64)))
            .collect();
        let mut started = Vec::new();
        for step in 0..5 {
            let a = s.schedule(SimTime(10.0 + step as f64));
            assert_eq!(a.len(), 1);
            started.push(a[0].0);
            s.on_job_finished(a[0].0, true, SimTime(10.5 + step as f64))
                .unwrap();
        }
        assert_eq!(started, ids);
    }

    #[test]
    fn packs_depth_first() {
        let mut s = Slurm::new();
        s.register_node("wn1", 2, SimTime(0.0));
        s.register_node("wn2", 2, SimTime(0.0));
        s.submit("a", 1, SimTime(0.0));
        s.submit("b", 1, SimTime(0.0));
        let a = s.schedule(SimTime(0.0));
        assert!(a.iter().all(
            |(_, n)| s.node_name(*n).as_deref() == Some("wn1")));
    }
}
