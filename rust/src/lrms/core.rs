//! Shared batch-system core used by the SLURM and HTCondor plugins.
//!
//! The plugins differ in *placement policy* (which node a pending job is
//! matched to) and queue ordering; everything else — job/node state
//! machines, requeue-on-failure, idle tracking — is common and lives here.
//!
//! ## Scale architecture
//!
//! Node identity is a dense interned [`NodeId`]; nodes live in a `Vec`
//! indexed by id and jobs in a `Vec` indexed by [`JobId`], so the hot
//! path never hashes or clones a `String`. Placement questions are
//! answered from incrementally-maintained indexes:
//!
//! * `PackFirstFit` — a free-slot bucket list (`bucket[f]` = Up nodes
//!   with exactly `f` free slots, ordered by registration order); a pick
//!   scans the ≤ max-slots buckets and takes the oldest candidate.
//! * `SpreadMostFree` — an ordered set keyed `(free, newest-last)`; the
//!   max element is the pick, O(log n).
//!
//! The indexes are updated on every start/finish/health/power event, so
//! one scheduling sweep costs O(jobs placed · log nodes) instead of the
//! original O(queue · nodes) rescan, and the sweep itself pops placed
//! jobs off the queue front instead of rebuilding the whole queue (the
//! saturated-cluster case is O(1) per sweep). The original sweep
//! survives as the *naive reference scheduler*
//! ([`BatchCore::new_naive`]); a property test asserts the two produce
//! identical placements event-for-event on randomized scenarios, and
//! `benches/scale.rs` measures the gap at 10k-node/1M-job scale.

use std::cmp::Reverse;
use std::collections::{BTreeSet, VecDeque};

use anyhow::{bail, Context};

use super::{Assignment, Job, JobId, JobState, NodeHealth, NodeInfo,
            NodeStat};
use crate::ids::{NodeId, NodeNames};
use crate::sim::SimTime;

/// Node placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Fill nodes in registration order (SLURM-ish depth-first packing).
    PackFirstFit,
    /// Prefer the node with the most free slots (HTCondor-ish
    /// breadth-first matchmaking).
    SpreadMostFree,
}

#[derive(Debug)]
pub(super) struct NodeSlot {
    pub id: NodeId,
    pub slots: u32,
    pub used: u32,
    pub health: NodeHealth,
    pub registered_at: SimTime,
    pub idle_since: Option<SimTime>,
    /// Registration order (placement tiebreak).
    pub order: u64,
    /// Jobs currently executing here, in start order.
    pub running: Vec<JobId>,
}

/// The common engine.
#[derive(Debug)]
pub struct BatchCore {
    placement: Placement,
    /// false = naive reference scheduler (per-job full rescan).
    indexed: bool,
    names: NodeNames,
    /// All jobs ever submitted, indexed densely by `JobId`.
    jobs: Vec<Job>,
    /// Pending queue in submission order.
    queue: VecDeque<JobId>,
    /// Scratch buffer reused across sweeps (scanned-but-unplaced jobs).
    scratch: VecDeque<JobId>,
    /// Node table indexed by `NodeId` (`None` = unknown/deregistered).
    nodes: Vec<Option<NodeSlot>>,
    /// Live node ids in registration order — snapshot walks are a
    /// straight indexed sweep, no sort and no allocation.
    reg_order: Vec<NodeId>,
    /// PackFirstFit index: `bucket[f]` = Up nodes with `f` free slots.
    pack_buckets: Vec<BTreeSet<(u64, u32)>>,
    /// SpreadMostFree index: Up nodes keyed `(free, newest-last, id)`.
    spread_set: BTreeSet<(u32, Reverse<u64>, u32)>,
    /// Total free slots on Up nodes (maintained incrementally).
    free_up: u64,
    /// Jobs currently Running (maintained incrementally).
    running_count: usize,
    next_order: u64,
}

impl BatchCore {
    /// Indexed scheduler with a private interner.
    pub fn new(placement: Placement) -> BatchCore {
        BatchCore::build(placement, NodeNames::new(), true)
    }

    /// The original O(queue · nodes) reference scheduler, kept for
    /// equivalence testing and as the bench baseline.
    pub fn new_naive(placement: Placement) -> BatchCore {
        BatchCore::build(placement, NodeNames::new(), false)
    }

    /// Indexed scheduler sharing a cluster-wide interner.
    pub fn with_names(placement: Placement, names: NodeNames) -> BatchCore {
        BatchCore::build(placement, names, true)
    }

    fn build(placement: Placement, names: NodeNames, indexed: bool)
        -> BatchCore {
        BatchCore {
            placement,
            indexed,
            names,
            jobs: Vec::new(),
            queue: VecDeque::new(),
            scratch: VecDeque::new(),
            nodes: Vec::new(),
            reg_order: Vec::new(),
            pack_buckets: Vec::new(),
            spread_set: BTreeSet::new(),
            free_up: 0,
            running_count: 0,
            next_order: 0,
        }
    }

    /// Handle to the interner this core issues ids from.
    pub fn names(&self) -> NodeNames {
        self.names.clone()
    }

    fn slot(&self, id: NodeId) -> Option<&NodeSlot> {
        self.nodes.get(id.index()).and_then(|n| n.as_ref())
    }

    /// Id of a currently-registered node.
    pub fn node_id(&self, name: &str) -> Option<NodeId> {
        let id = self.names.get(name)?;
        self.slot(id).map(|_| id)
    }

    /// Name of a currently-registered node.
    pub fn node_name(&self, id: NodeId) -> Option<String> {
        self.slot(id).map(|_| self.names.name(id))
    }

    // -----------------------------------------------------------------
    // Index maintenance. Every mutation of a node's free-slot count or
    // health is bracketed `detach(i); <mutate>; attach(i)` so the
    // placement indexes and the free-slot counter never drift.
    // -----------------------------------------------------------------

    fn detach(&mut self, i: usize) {
        let (free, order) = match self.nodes[i].as_ref() {
            Some(n) if n.health == NodeHealth::Up => {
                (n.slots - n.used, n.order)
            }
            _ => return,
        };
        self.free_up -= free as u64;
        if self.indexed && free > 0 {
            match self.placement {
                Placement::PackFirstFit => {
                    self.pack_buckets[free as usize]
                        .remove(&(order, i as u32));
                }
                Placement::SpreadMostFree => {
                    self.spread_set.remove(&(free, Reverse(order), i as u32));
                }
            }
        }
    }

    fn attach(&mut self, i: usize) {
        let (free, order) = match self.nodes[i].as_ref() {
            Some(n) if n.health == NodeHealth::Up => {
                (n.slots - n.used, n.order)
            }
            _ => return,
        };
        self.free_up += free as u64;
        if self.indexed && free > 0 {
            match self.placement {
                Placement::PackFirstFit => {
                    let f = free as usize;
                    if self.pack_buckets.len() <= f {
                        self.pack_buckets.resize_with(f + 1, BTreeSet::new);
                    }
                    self.pack_buckets[f].insert((order, i as u32));
                }
                Placement::SpreadMostFree => {
                    self.spread_set.insert((free, Reverse(order), i as u32));
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // Node lifecycle
    // -----------------------------------------------------------------

    pub fn register_node(&mut self, name: &str, slots: u32, t: SimTime) {
        let id = self.names.intern(name);
        let i = id.index();
        if self.nodes.len() <= i {
            self.nodes.resize_with(i + 1, || None);
        }
        if let Some(n) = self.nodes[i].as_mut() {
            // Re-registration of a node that came back: mark Up.
            if n.health != NodeHealth::Up {
                n.health = NodeHealth::Up;
                self.attach(i);
            }
            return;
        }
        self.nodes[i] = Some(NodeSlot {
            id,
            slots,
            used: 0,
            health: NodeHealth::Up,
            registered_at: t,
            idle_since: Some(t),
            order: self.next_order,
            running: Vec::new(),
        });
        self.next_order += 1;
        // Fresh slot: the id cannot already be in reg_order (deregister
        // removed it), and new orders are monotone, so a push keeps the
        // list sorted by registration order.
        self.reg_order.push(id);
        self.attach(i);
    }

    pub fn deregister_node(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        let id = self
            .names
            .get(name)
            .filter(|&id| self.slot(id).is_some())
            .with_context(|| format!("no node {name:?}"))?;
        let i = id.index();
        let requeued = self.requeue_jobs_on_idx(i, t);
        self.detach(i);
        self.nodes[i] = None;
        self.reg_order.retain(|&n| n != id);
        Ok(requeued)
    }

    pub fn set_node_health(&mut self, name: &str, health: NodeHealth,
                           t: SimTime) -> anyhow::Result<Vec<JobId>> {
        let id = self
            .names
            .get(name)
            .filter(|&id| self.slot(id).is_some())
            .with_context(|| format!("no node {name:?}"))?;
        let i = id.index();
        let was = self.nodes[i].as_ref().expect("checked above").health;
        self.detach(i);
        self.nodes[i].as_mut().expect("checked above").health = health;
        self.attach(i);
        if health == NodeHealth::Down && was != NodeHealth::Down {
            return Ok(self.requeue_jobs_on_idx(i, t));
        }
        if health == NodeHealth::Up && was != NodeHealth::Up {
            let n = self.nodes[i].as_mut().expect("checked above");
            if n.used == 0 {
                // idle_since does not affect free slots: no re-index.
                n.idle_since = Some(t);
            }
        }
        Ok(Vec::new())
    }

    /// Push back every running job on node `i` into the front of the
    /// queue, preserving start order (SLURM requeues preempted/
    /// failed-node jobs ahead of new work).
    fn requeue_jobs_on_idx(&mut self, i: usize, t: SimTime) -> Vec<JobId> {
        self.detach(i);
        let drained = {
            let n = self.nodes[i].as_mut().expect("node exists");
            n.used = 0;
            n.idle_since = Some(t);
            std::mem::take(&mut n.running)
        };
        self.attach(i);
        let mut requeued = Vec::with_capacity(drained.len());
        for jid in drained {
            let job = &mut self.jobs[jid.0 as usize];
            if job.state == JobState::Running
                && job.node == Some(NodeId(i as u32))
            {
                job.state = JobState::Pending;
                job.node = None;
                job.started_at = None;
                job.requeues += 1;
                self.running_count -= 1;
                requeued.push(jid);
            }
        }
        // Front of queue, preserving relative order.
        for &jid in requeued.iter().rev() {
            self.queue.push_front(jid);
        }
        requeued
    }

    // -----------------------------------------------------------------
    // Job lifecycle
    // -----------------------------------------------------------------

    pub fn submit(&mut self, name: &str, slots: u32, t: SimTime) -> JobId {
        let id = JobId(self.jobs.len() as u64);
        self.jobs.push(Job {
            id,
            name: name.to_string(),
            // Zero-slot jobs would be invisible to the free-slot
            // indexes; a job occupies at least one slot.
            slots: slots.max(1),
            state: JobState::Pending,
            submitted_at: t,
            started_at: None,
            finished_at: None,
            node: None,
            requeues: 0,
        });
        self.queue.push_back(id);
        id
    }

    /// Bulk submit: `count` identical anonymous jobs in one call.
    /// Job-for-job equivalent to `count` × [`BatchCore::submit`] with
    /// empty names — ids are issued densely in submission order — but
    /// both tables are grown once up front.
    pub fn submit_batch(&mut self, count: u32, slots: u32, t: SimTime) {
        let slots = slots.max(1);
        let first = self.jobs.len() as u64;
        self.jobs.reserve(count as usize);
        self.queue.reserve(count as usize);
        for k in 0..count as u64 {
            let id = JobId(first + k);
            self.jobs.push(Job {
                id,
                name: String::new(),
                slots,
                state: JobState::Pending,
                submitted_at: t,
                started_at: None,
                finished_at: None,
                node: None,
                requeues: 0,
            });
            self.queue.push_back(id);
        }
    }

    pub fn cancel(&mut self, id: JobId, t: SimTime) -> anyhow::Result<()> {
        let job = self
            .jobs
            .get_mut(id.0 as usize)
            .with_context(|| format!("{id}"))?;
        if job.state != JobState::Pending {
            bail!("{id} is {:?}, only Pending jobs can be cancelled",
                  job.state);
        }
        job.state = JobState::Cancelled;
        job.finished_at = Some(t);
        self.queue.retain(|&q| q != id);
        Ok(())
    }

    /// Drain the entire pending queue in submission order, marking each
    /// job Cancelled, and return the drained ids. This is the bulk
    /// primitive behind partitioned spillover: a site slice that lost
    /// capacity empties its backlog with one call, keeps what still
    /// fits locally (resubmitted under fresh ids), and returns the rest
    /// to the dispatcher.
    pub fn drain_pending(&mut self, t: SimTime) -> Vec<JobId> {
        let mut out = Vec::with_capacity(self.queue.len());
        while let Some(id) = self.queue.pop_front() {
            let job = &mut self.jobs[id.0 as usize];
            if job.state == JobState::Pending {
                job.state = JobState::Cancelled;
                job.finished_at = Some(t);
                out.push(id);
            }
        }
        out
    }

    /// Total slots on Up nodes — the capacity ceiling a site slice can
    /// hold work against, independent of current occupancy.
    pub fn up_slots(&self) -> u64 {
        self.nodes
            .iter()
            .flatten()
            .filter(|n| n.health == NodeHealth::Up)
            .map(|n| n.slots as u64)
            .sum()
    }

    /// One scheduling sweep. Pops placed jobs off the queue front and
    /// stops the moment the cluster has no free slot left, so a
    /// saturated cluster costs O(1) per sweep and a completion event
    /// costs O(jobs placed · log nodes). Jobs the scan passes over
    /// (multi-slot jobs that fit nowhere right now) keep their queue
    /// position ahead of the unscanned tail.
    pub fn schedule(&mut self, t: SimTime) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut free: u64 = if self.indexed {
            self.free_up
        } else {
            // The reference scheduler recomputes the sum, as the
            // original implementation did.
            self.nodes
                .iter()
                .flatten()
                .filter(|n| n.health == NodeHealth::Up)
                .map(|n| (n.slots - n.used) as u64)
                .sum()
        };
        debug_assert_eq!(free, self.free_up, "free-slot counter drifted");
        debug_assert!(self.scratch.is_empty());
        while free > 0 {
            let Some(jid) = self.queue.pop_front() else { break };
            let slots = match self.jobs.get(jid.0 as usize) {
                Some(j) if j.state == JobState::Pending => j.slots,
                _ => continue,
            };
            let pick = if self.indexed {
                self.pick_indexed(slots)
            } else {
                self.pick_naive(slots)
            };
            match pick {
                Some(i) => {
                    self.start_job_on(i, jid, slots, t);
                    free -= slots as u64;
                    out.push((jid, NodeId(i)));
                }
                None => self.scratch.push_back(jid),
            }
        }
        // Unplaced-but-scanned jobs return to the front in order.
        while let Some(jid) = self.scratch.pop_back() {
            self.queue.push_front(jid);
        }
        out
    }

    /// Reference pick: full scan (placement-identical to the indexed
    /// pick — the property suite asserts this).
    fn pick_naive(&self, slots: u32) -> Option<u32> {
        let fits = |n: &&NodeSlot| {
            n.health == NodeHealth::Up && n.slots - n.used >= slots
        };
        match self.placement {
            Placement::PackFirstFit => self
                .nodes
                .iter()
                .flatten()
                .filter(fits)
                .min_by_key(|n| n.order)
                .map(|n| n.id.0),
            Placement::SpreadMostFree => self
                .nodes
                .iter()
                .flatten()
                .filter(fits)
                .max_by_key(|n| {
                    ((n.slots - n.used) as u64) << 32
                        | (u32::MAX as u64 - n.order.min(u32::MAX as u64))
                })
                .map(|n| n.id.0),
        }
    }

    /// Indexed pick: O(max-slots · log nodes) for pack, O(log nodes)
    /// for spread.
    fn pick_indexed(&self, slots: u32) -> Option<u32> {
        match self.placement {
            Placement::PackFirstFit => {
                let mut best: Option<(u64, u32)> = None;
                for f in (slots as usize)..self.pack_buckets.len() {
                    if let Some(&(order, idx)) = self.pack_buckets[f].first()
                    {
                        if best.map_or(true, |(bo, _)| order < bo) {
                            best = Some((order, idx));
                        }
                    }
                }
                best.map(|(_, idx)| idx)
            }
            Placement::SpreadMostFree => {
                match self.spread_set.iter().next_back() {
                    Some(&(free, _, idx)) if free >= slots => Some(idx),
                    _ => None,
                }
            }
        }
    }

    fn start_job_on(&mut self, i: u32, jid: JobId, slots: u32, t: SimTime) {
        let iu = i as usize;
        self.detach(iu);
        {
            let n = self.nodes[iu].as_mut().expect("picked node exists");
            n.used += slots;
            n.idle_since = None;
            n.running.push(jid);
        }
        self.attach(iu);
        let job = &mut self.jobs[jid.0 as usize];
        job.state = JobState::Running;
        job.node = Some(NodeId(i));
        job.started_at = Some(t);
        self.running_count += 1;
    }

    pub fn on_job_finished(&mut self, id: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()> {
        let job = self
            .jobs
            .get_mut(id.0 as usize)
            .with_context(|| format!("{id}"))?;
        if job.state != JobState::Running {
            bail!("{id} is {:?}, not Running", job.state);
        }
        job.state = if ok { JobState::Completed } else { JobState::Failed };
        job.finished_at = Some(t);
        let node = job.node;
        let slots = job.slots;
        self.running_count -= 1;
        if let Some(nid) = node {
            let i = nid.index();
            if self.nodes.get(i).map_or(false, |n| n.is_some()) {
                self.detach(i);
                let n = self.nodes[i].as_mut().expect("checked above");
                n.used = n.used.saturating_sub(slots);
                if let Some(pos) =
                    n.running.iter().position(|&r| r == id)
                {
                    // Order-preserving removal: the running list is the
                    // requeue priority order (start order). The list is
                    // bounded by the node's slot count, so this is O(1)
                    // in practice.
                    n.running.remove(pos);
                }
                if n.used == 0 {
                    n.idle_since = Some(t);
                }
                self.attach(i);
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Read access
    // -----------------------------------------------------------------

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(id.0 as usize)
    }

    pub fn jobs(&self) -> Vec<&Job> {
        // Dense storage is already in id order.
        self.jobs.iter().collect()
    }

    /// Snapshots in registration order (name-resolving; edge paths).
    pub fn nodes(&self) -> Vec<NodeInfo> {
        self.reg_order
            .iter()
            .filter_map(|&id| self.slot(id))
            .map(|n| NodeInfo {
                id: n.id,
                name: self.names.name(n.id),
                slots: n.slots,
                used_slots: n.used,
                health: n.health,
                registered_at: n.registered_at,
                idle_since: n.idle_since,
            })
            .collect()
    }

    /// Allocation-light snapshots in registration order (hot paths:
    /// no `String` per node).
    pub fn node_stats(&self) -> Vec<NodeStat> {
        let mut out = Vec::with_capacity(self.reg_order.len());
        self.node_stats_into(&mut out);
        out
    }

    /// Fill `out` with snapshots in registration order, reusing its
    /// capacity — the CLUES tick passes a scratch buffer, so a
    /// 10k-node tick performs zero allocations here.
    pub fn node_stats_into(&self, out: &mut Vec<NodeStat>) {
        out.clear();
        for &id in &self.reg_order {
            if let Some(n) = self.slot(id) {
                out.push(NodeStat {
                    id: n.id,
                    slots: n.slots,
                    used_slots: n.used,
                    health: n.health,
                    registered_at: n.registered_at,
                    idle_since: n.idle_since,
                });
            }
        }
    }

    /// O(1) single-node snapshot.
    pub fn node_stat(&self, id: NodeId) -> Option<NodeStat> {
        self.slot(id).map(|n| NodeStat {
            id: n.id,
            slots: n.slots,
            used_slots: n.used,
            health: n.health,
            registered_at: n.registered_at,
            idle_since: n.idle_since,
        })
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running_count
    }

    /// Total free Up slots right now, O(1).
    pub fn free_slots(&self) -> u32 {
        self.free_up as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn name_of(c: &BatchCore, id: NodeId) -> String {
        c.node_name(id).expect("assigned node exists")
    }

    #[test]
    fn pack_vs_spread_placement() {
        // Two nodes with 2 slots each; two 1-slot jobs.
        let mut pack = BatchCore::new(Placement::PackFirstFit);
        let mut spread = BatchCore::new(Placement::SpreadMostFree);
        for core in [&mut pack, &mut spread] {
            core.register_node("n1", 2, t(0.0));
            core.register_node("n2", 2, t(0.0));
            core.submit("a", 1, t(0.0));
            core.submit("b", 1, t(0.0));
        }
        let pa = pack.schedule(t(1.0));
        assert_eq!(name_of(&pack, pa[0].1), "n1");
        assert_eq!(name_of(&pack, pa[1].1), "n1"); // packs onto first node
        let sa = spread.schedule(t(1.0));
        assert_eq!(name_of(&spread, sa[0].1), "n1");
        assert_eq!(name_of(&spread, sa[1].1), "n2"); // spreads across
    }

    #[test]
    fn requeue_on_node_down_preserves_priority() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        let b = c.submit("b", 1, t(0.0));
        c.schedule(t(1.0));
        assert_eq!(c.job(a).unwrap().state, JobState::Running);
        let requeued = c.set_node_health("n1", NodeHealth::Down, t(5.0))
            .unwrap();
        assert_eq!(requeued, vec![a]);
        assert_eq!(c.job(a).unwrap().requeues, 1);
        // a must run again before b once a node is available.
        c.register_node("n2", 1, t(6.0));
        let assigned = c.schedule(t(6.0));
        assert_eq!(assigned.len(), 1);
        assert_eq!(assigned[0].0, a);
        assert_eq!(name_of(&c, assigned[0].1), "n2");
        assert_eq!(c.job(b).unwrap().state, JobState::Pending);
    }

    #[test]
    fn down_node_receives_no_work() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 4, t(0.0));
        c.set_node_health("n1", NodeHealth::Down, t(0.0)).unwrap();
        c.submit("a", 1, t(0.0));
        assert!(c.schedule(t(1.0)).is_empty());
        // Back up: work flows again.
        c.set_node_health("n1", NodeHealth::Up, t(2.0)).unwrap();
        assert_eq!(c.schedule(t(2.0)).len(), 1);
    }

    #[test]
    fn drain_blocks_new_but_keeps_running() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 2, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(0.0));
        let requeued =
            c.set_node_health("n1", NodeHealth::Drain, t(1.0)).unwrap();
        assert!(requeued.is_empty());
        assert_eq!(c.job(a).unwrap().state, JobState::Running);
        c.submit("b", 1, t(1.0));
        assert!(c.schedule(t(1.0)).is_empty());
    }

    #[test]
    fn idle_since_tracking() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        assert_eq!(c.nodes()[0].idle_since, Some(t(0.0)));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(5.0));
        assert_eq!(c.nodes()[0].idle_since, None);
        c.on_job_finished(a, true, t(30.0)).unwrap();
        assert_eq!(c.nodes()[0].idle_since, Some(t(30.0)));
    }

    #[test]
    fn cancel_only_pending() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        let b = c.submit("b", 1, t(0.0));
        c.schedule(t(0.0));
        assert!(c.cancel(a, t(1.0)).is_err()); // running
        c.cancel(b, t(1.0)).unwrap();
        assert_eq!(c.job(b).unwrap().state, JobState::Cancelled);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn multi_slot_jobs_wait_for_room() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 2, t(0.0));
        let small = c.submit("small", 1, t(0.0));
        let big = c.submit("big", 2, t(0.0));
        let assigned = c.schedule(t(0.0));
        assert_eq!(assigned.len(), 1); // big doesn't fit next to small
        c.on_job_finished(small, true, t(10.0)).unwrap();
        let assigned = c.schedule(t(10.0));
        assert_eq!(assigned.len(), 1);
        assert_eq!(assigned[0].0, big);
        assert_eq!(name_of(&c, assigned[0].1), "n1");
    }

    #[test]
    fn deregister_requeues_and_removes() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(0.0));
        let rq = c.deregister_node("n1", t(1.0)).unwrap();
        assert_eq!(rq, vec![a]);
        assert!(c.nodes().is_empty());
        assert_eq!(c.pending(), 1);
        assert!(c.deregister_node("n1", t(2.0)).is_err());
    }

    #[test]
    fn reregistration_revives_node() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        c.set_node_health("n1", NodeHealth::Down, t(1.0)).unwrap();
        c.register_node("n1", 1, t(2.0));
        assert_eq!(c.nodes()[0].health, NodeHealth::Up);
        assert_eq!(c.nodes().len(), 1);
    }

    #[test]
    fn free_slot_counter_tracks_every_transition() {
        let mut c = BatchCore::new(Placement::SpreadMostFree);
        c.register_node("n1", 2, t(0.0));
        c.register_node("n2", 3, t(0.0));
        assert_eq!(c.free_slots(), 5);
        let a = c.submit("a", 2, t(0.0));
        c.schedule(t(0.0));
        assert_eq!(c.free_slots(), 3);
        c.set_node_health("n1", NodeHealth::Drain, t(1.0)).unwrap();
        // n1 (3 free after the spread pick took n2? no: spread picks the
        // most-free node n2) — recount from snapshots to be explicit.
        let by_hand: u32 = c
            .nodes()
            .iter()
            .filter(|n| n.health == NodeHealth::Up)
            .map(|n| n.slots - n.used_slots)
            .sum();
        assert_eq!(c.free_slots(), by_hand);
        c.on_job_finished(a, true, t(2.0)).unwrap();
        let by_hand: u32 = c
            .nodes()
            .iter()
            .filter(|n| n.health == NodeHealth::Up)
            .map(|n| n.slots - n.used_slots)
            .sum();
        assert_eq!(c.free_slots(), by_hand);
        c.deregister_node("n2", t(3.0)).unwrap();
        c.set_node_health("n1", NodeHealth::Up, t(3.0)).unwrap();
        assert_eq!(c.free_slots(), 2);
    }

    #[test]
    fn indexed_and_naive_agree_on_a_small_scenario() {
        for placement in [Placement::PackFirstFit,
                          Placement::SpreadMostFree] {
            let mut a = BatchCore::new(placement);
            let mut b = BatchCore::new_naive(placement);
            for c in [&mut a, &mut b] {
                c.register_node("n1", 2, t(0.0));
                c.register_node("n2", 1, t(0.0));
                c.register_node("n3", 3, t(0.0));
                for i in 0..8u32 {
                    c.submit(&format!("j{i}"), 1 + (i % 2), t(0.0));
                }
            }
            let pa = a.schedule(t(1.0));
            let pb = b.schedule(t(1.0));
            assert_eq!(pa, pb, "{placement:?}");
            // Finish the first assignment and compare the next sweep.
            a.on_job_finished(pa[0].0, true, t(2.0)).unwrap();
            b.on_job_finished(pb[0].0, true, t(2.0)).unwrap();
            assert_eq!(a.schedule(t(3.0)), b.schedule(t(3.0)),
                       "{placement:?}");
        }
    }

    #[test]
    fn node_stats_into_reuses_buffer_in_registration_order() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("b", 1, t(0.0));
        c.register_node("a", 2, t(1.0));
        c.register_node("c", 3, t(2.0));
        let mut buf = Vec::new();
        c.node_stats_into(&mut buf);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[0].id, c.node_id("b").unwrap());
        assert_eq!(buf[2].id, c.node_id("c").unwrap());
        // Deregistration drops the node from the sweep; re-registration
        // appends at the end (new registration order).
        c.deregister_node("b", t(3.0)).unwrap();
        c.node_stats_into(&mut buf);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[0].id, c.node_id("a").unwrap());
        c.register_node("b", 1, t(4.0));
        c.node_stats_into(&mut buf);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[2].id, c.node_id("b").unwrap());
        assert_eq!(buf, c.node_stats());
        // Revival (Down -> re-register) keeps the original order.
        c.set_node_health("a", NodeHealth::Down, t(5.0)).unwrap();
        c.register_node("a", 2, t(6.0));
        c.node_stats_into(&mut buf);
        assert_eq!(buf[0].id, c.node_id("a").unwrap());
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn submit_batch_equivalent_to_repeated_submit() {
        for placement in [Placement::PackFirstFit,
                          Placement::SpreadMostFree] {
            let mut a = BatchCore::new(placement);
            let mut b = BatchCore::new(placement);
            for c in [&mut a, &mut b] {
                c.register_node("n1", 2, t(0.0));
                c.register_node("n2", 3, t(0.0));
            }
            a.submit_batch(7, 1, t(1.0));
            a.submit_batch(3, 2, t(2.0));
            for _ in 0..7 {
                b.submit("", 1, t(1.0));
            }
            for _ in 0..3 {
                b.submit("", 2, t(2.0));
            }
            assert_eq!(a.pending(), b.pending());
            // Same ids, same placements, same queue order.
            let pa = a.schedule(t(3.0));
            let pb = b.schedule(t(3.0));
            assert_eq!(pa, pb, "{placement:?}");
            a.on_job_finished(pa[0].0, true, t(4.0)).unwrap();
            b.on_job_finished(pb[0].0, true, t(4.0)).unwrap();
            assert_eq!(a.schedule(t(5.0)), b.schedule(t(5.0)));
            // Zero-slot batch jobs are clamped like plain submits.
            a.submit_batch(1, 0, t(6.0));
            let id = b.submit("", 0, t(6.0));
            assert_eq!(a.job(id).unwrap().slots, b.job(id).unwrap().slots);
        }
    }

    #[test]
    fn node_id_lookup_respects_registration() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        assert!(c.node_id("n1").is_none());
        c.register_node("n1", 1, t(0.0));
        let id = c.node_id("n1").unwrap();
        assert_eq!(c.node_name(id).as_deref(), Some("n1"));
        assert_eq!(c.node_stat(id).unwrap().slots, 1);
        c.deregister_node("n1", t(1.0)).unwrap();
        assert!(c.node_id("n1").is_none());
        assert!(c.node_stat(id).is_none());
    }

    #[test]
    fn drain_pending_empties_queue_in_order() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        c.submit_batch(4, 1, t(1.0));
        assert_eq!(c.up_slots(), 1);
        let placed = c.schedule(t(1.0));
        assert_eq!(placed.len(), 1);
        let drained = c.drain_pending(t(2.0));
        assert_eq!(drained, vec![JobId(1), JobId(2), JobId(3)]);
        assert_eq!(c.pending(), 0);
        for id in drained {
            assert_eq!(c.job(id).unwrap().state, JobState::Cancelled);
            assert_eq!(c.job(id).unwrap().finished_at, Some(t(2.0)));
        }
        // The running job is untouched, and the drained queue does not
        // disturb subsequent scheduling.
        assert_eq!(c.running(), 1);
        assert!(c.drain_pending(t(3.0)).is_empty());
        c.on_job_finished(placed[0].0, true, t(4.0)).unwrap();
        let next = c.submit("", 1, t(5.0));
        assert_eq!(c.schedule(t(5.0)), vec![(next, placed[0].1)]);
        // Down capacity leaves up_slots.
        c.set_node_health("n1", NodeHealth::Down, t(6.0)).unwrap();
        assert_eq!(c.up_slots(), 0);
    }
}
