//! Shared batch-system core used by the SLURM and HTCondor plugins.
//!
//! The plugins differ in *placement policy* (which node a pending job is
//! matched to) and queue ordering; everything else — job/node state
//! machines, requeue-on-failure, idle tracking — is common and lives here.

use std::collections::HashMap;

use anyhow::{bail, Context};

use super::{Assignment, Job, JobId, JobState, NodeHealth, NodeInfo};
use crate::sim::SimTime;

/// Node placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Fill nodes in registration order (SLURM-ish depth-first packing).
    PackFirstFit,
    /// Prefer the node with the most free slots (HTCondor-ish
    /// breadth-first matchmaking).
    SpreadMostFree,
}

#[derive(Debug)]
pub(super) struct NodeSlot {
    pub name: String,
    pub slots: u32,
    pub used: u32,
    pub health: NodeHealth,
    pub registered_at: SimTime,
    pub idle_since: Option<SimTime>,
    /// Registration order (placement tiebreak).
    pub order: u64,
}

/// The common engine.
#[derive(Debug)]
pub struct BatchCore {
    placement: Placement,
    jobs: HashMap<JobId, Job>,
    /// Pending queue in submission order.
    queue: Vec<JobId>,
    nodes: Vec<NodeSlot>,
    next_job: u64,
    next_order: u64,
}

impl BatchCore {
    pub fn new(placement: Placement) -> BatchCore {
        BatchCore {
            placement,
            jobs: HashMap::new(),
            queue: Vec::new(),
            nodes: Vec::new(),
            next_job: 0,
            next_order: 0,
        }
    }

    pub fn register_node(&mut self, name: &str, slots: u32, t: SimTime) {
        if self.nodes.iter().any(|n| n.name == name) {
            // Re-registration of a node that came back: mark Up.
            if let Some(n) = self.nodes.iter_mut().find(|n| n.name == name) {
                n.health = NodeHealth::Up;
            }
            return;
        }
        self.nodes.push(NodeSlot {
            name: name.to_string(),
            slots,
            used: 0,
            health: NodeHealth::Up,
            registered_at: t,
            idle_since: Some(t),
            order: self.next_order,
        });
        self.next_order += 1;
    }

    pub fn deregister_node(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        let idx = self
            .nodes
            .iter()
            .position(|n| n.name == name)
            .with_context(|| format!("no node {name:?}"))?;
        let requeued = self.requeue_jobs_on(name, t);
        self.nodes.remove(idx);
        Ok(requeued)
    }

    pub fn set_node_health(&mut self, name: &str, health: NodeHealth,
                           t: SimTime) -> anyhow::Result<Vec<JobId>> {
        let node = self
            .nodes
            .iter_mut()
            .find(|n| n.name == name)
            .with_context(|| format!("no node {name:?}"))?;
        let was = node.health;
        node.health = health;
        if health == NodeHealth::Down && was != NodeHealth::Down {
            return Ok(self.requeue_jobs_on(name, t));
        }
        if health == NodeHealth::Up && was != NodeHealth::Up {
            let node = self.nodes.iter_mut().find(|n| n.name == name)
                .expect("node vanished");
            if node.used == 0 {
                node.idle_since = Some(t);
            }
        }
        Ok(Vec::new())
    }

    /// Push back every running job on `name` into the front of the queue
    /// (SLURM requeues preempted/failed-node jobs ahead of new work).
    fn requeue_jobs_on(&mut self, name: &str, t: SimTime) -> Vec<JobId> {
        let mut requeued = Vec::new();
        for job in self.jobs.values_mut() {
            if job.state == JobState::Running
                && job.node.as_deref() == Some(name)
            {
                job.state = JobState::Pending;
                job.node = None;
                job.started_at = None;
                job.requeues += 1;
                requeued.push(job.id);
            }
        }
        if let Some(n) = self.nodes.iter_mut().find(|n| n.name == name) {
            n.used = 0;
            n.idle_since = Some(t);
        }
        // Front of queue, preserving relative order.
        let mut newq = requeued.clone();
        newq.extend(self.queue.iter().copied());
        self.queue = newq;
        requeued
    }

    pub fn submit(&mut self, name: &str, slots: u32, t: SimTime) -> JobId {
        let id = JobId(self.next_job);
        self.next_job += 1;
        self.jobs.insert(id, Job {
            id,
            name: name.to_string(),
            slots,
            state: JobState::Pending,
            submitted_at: t,
            started_at: None,
            finished_at: None,
            node: None,
            requeues: 0,
        });
        self.queue.push(id);
        id
    }

    pub fn cancel(&mut self, id: JobId, t: SimTime) -> anyhow::Result<()> {
        let job = self.jobs.get_mut(&id).with_context(|| format!("{id}"))?;
        if job.state != JobState::Pending {
            bail!("{id} is {:?}, only Pending jobs can be cancelled",
                  job.state);
        }
        job.state = JobState::Cancelled;
        job.finished_at = Some(t);
        self.queue.retain(|&q| q != id);
        Ok(())
    }

    /// One scheduling sweep. Exits early once the cluster has no free
    /// slot left: with thousands of queued jobs and one free node, the
    /// naive sweep rescans the whole queue per dispatch and dominated the
    /// full-scale replay profile (EXPERIMENTS §Perf L3).
    pub fn schedule(&mut self, t: SimTime) -> Vec<Assignment> {
        let mut out = Vec::new();
        let mut remaining: Vec<JobId> = Vec::new();
        let mut free: u32 = self
            .nodes
            .iter()
            .filter(|n| n.health == NodeHealth::Up)
            .map(|n| n.slots - n.used)
            .sum();
        let queue = std::mem::take(&mut self.queue);
        let mut it = queue.into_iter();
        for jid in it.by_ref() {
            if free == 0 {
                remaining.push(jid);
                break;
            }
            let slots = match self.jobs.get(&jid) {
                Some(j) if j.state == JobState::Pending => j.slots,
                _ => continue,
            };
            // Pick a node per the placement policy.
            let mut candidates: Vec<&mut NodeSlot> = self
                .nodes
                .iter_mut()
                .filter(|n| {
                    n.health == NodeHealth::Up && n.slots - n.used >= slots
                })
                .collect();
            let pick = match self.placement {
                Placement::PackFirstFit => candidates
                    .iter_mut()
                    .min_by_key(|n| n.order),
                Placement::SpreadMostFree => candidates
                    .iter_mut()
                    .max_by_key(|n| ((n.slots - n.used) as u64) << 32
                        | (u32::MAX as u64 - n.order.min(u32::MAX as u64))),
            };
            match pick {
                Some(node) => {
                    node.used += slots;
                    node.idle_since = None;
                    let name = node.name.clone();
                    let job = self.jobs.get_mut(&jid).expect("job exists");
                    job.state = JobState::Running;
                    job.node = Some(name.clone());
                    job.started_at = Some(t);
                    free -= slots;
                    out.push((jid, name));
                }
                None => remaining.push(jid),
            }
        }
        // Anything after the early exit keeps its queue position.
        remaining.extend(it);
        self.queue = remaining;
        out
    }

    pub fn on_job_finished(&mut self, id: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()> {
        let job = self.jobs.get_mut(&id).with_context(|| format!("{id}"))?;
        if job.state != JobState::Running {
            bail!("{id} is {:?}, not Running", job.state);
        }
        job.state = if ok { JobState::Completed } else { JobState::Failed };
        job.finished_at = Some(t);
        let node_name = job.node.clone();
        if let Some(name) = node_name {
            if let Some(n) = self.nodes.iter_mut().find(|n| n.name == name) {
                n.used = n.used.saturating_sub(job.slots);
                if n.used == 0 {
                    n.idle_since = Some(t);
                }
            }
        }
        Ok(())
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn jobs(&self) -> Vec<&Job> {
        let mut v: Vec<&Job> = self.jobs.values().collect();
        v.sort_by_key(|j| j.id);
        v
    }

    pub fn nodes(&self) -> Vec<NodeInfo> {
        self.nodes
            .iter()
            .map(|n| NodeInfo {
                name: n.name.clone(),
                slots: n.slots,
                used_slots: n.used,
                health: n.health,
                registered_at: n.registered_at,
                idle_since: n.idle_since,
            })
            .collect()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    #[test]
    fn pack_vs_spread_placement() {
        // Two nodes with 2 slots each; two 1-slot jobs.
        let mut pack = BatchCore::new(Placement::PackFirstFit);
        let mut spread = BatchCore::new(Placement::SpreadMostFree);
        for core in [&mut pack, &mut spread] {
            core.register_node("n1", 2, t(0.0));
            core.register_node("n2", 2, t(0.0));
            core.submit("a", 1, t(0.0));
            core.submit("b", 1, t(0.0));
        }
        let pa = pack.schedule(t(1.0));
        assert_eq!(pa[0].1, "n1");
        assert_eq!(pa[1].1, "n1"); // packs onto the first node
        let sa = spread.schedule(t(1.0));
        assert_eq!(sa[0].1, "n1");
        assert_eq!(sa[1].1, "n2"); // spreads across nodes
    }

    #[test]
    fn requeue_on_node_down_preserves_priority() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        let b = c.submit("b", 1, t(0.0));
        c.schedule(t(1.0));
        assert_eq!(c.job(a).unwrap().state, JobState::Running);
        let requeued = c.set_node_health("n1", NodeHealth::Down, t(5.0))
            .unwrap();
        assert_eq!(requeued, vec![a]);
        assert_eq!(c.job(a).unwrap().requeues, 1);
        // a must run again before b once a node is available.
        c.register_node("n2", 1, t(6.0));
        let assigned = c.schedule(t(6.0));
        assert_eq!(assigned, vec![(a, "n2".to_string())]);
        assert_eq!(c.job(b).unwrap().state, JobState::Pending);
    }

    #[test]
    fn down_node_receives_no_work() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 4, t(0.0));
        c.set_node_health("n1", NodeHealth::Down, t(0.0)).unwrap();
        c.submit("a", 1, t(0.0));
        assert!(c.schedule(t(1.0)).is_empty());
        // Back up: work flows again.
        c.set_node_health("n1", NodeHealth::Up, t(2.0)).unwrap();
        assert_eq!(c.schedule(t(2.0)).len(), 1);
    }

    #[test]
    fn drain_blocks_new_but_keeps_running() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 2, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(0.0));
        let requeued =
            c.set_node_health("n1", NodeHealth::Drain, t(1.0)).unwrap();
        assert!(requeued.is_empty());
        assert_eq!(c.job(a).unwrap().state, JobState::Running);
        c.submit("b", 1, t(1.0));
        assert!(c.schedule(t(1.0)).is_empty());
    }

    #[test]
    fn idle_since_tracking() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        assert_eq!(c.nodes()[0].idle_since, Some(t(0.0)));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(5.0));
        assert_eq!(c.nodes()[0].idle_since, None);
        c.on_job_finished(a, true, t(30.0)).unwrap();
        assert_eq!(c.nodes()[0].idle_since, Some(t(30.0)));
    }

    #[test]
    fn cancel_only_pending() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        let b = c.submit("b", 1, t(0.0));
        c.schedule(t(0.0));
        assert!(c.cancel(a, t(1.0)).is_err()); // running
        c.cancel(b, t(1.0)).unwrap();
        assert_eq!(c.job(b).unwrap().state, JobState::Cancelled);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn multi_slot_jobs_wait_for_room() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 2, t(0.0));
        let small = c.submit("small", 1, t(0.0));
        let big = c.submit("big", 2, t(0.0));
        let assigned = c.schedule(t(0.0));
        assert_eq!(assigned.len(), 1); // big doesn't fit next to small
        c.on_job_finished(small, true, t(10.0)).unwrap();
        let assigned = c.schedule(t(10.0));
        assert_eq!(assigned, vec![(big, "n1".to_string())]);
    }

    #[test]
    fn deregister_requeues_and_removes() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        let a = c.submit("a", 1, t(0.0));
        c.schedule(t(0.0));
        let rq = c.deregister_node("n1", t(1.0)).unwrap();
        assert_eq!(rq, vec![a]);
        assert!(c.nodes().is_empty());
        assert_eq!(c.pending(), 1);
        assert!(c.deregister_node("n1", t(2.0)).is_err());
    }

    #[test]
    fn reregistration_revives_node() {
        let mut c = BatchCore::new(Placement::PackFirstFit);
        c.register_node("n1", 1, t(0.0));
        c.set_node_health("n1", NodeHealth::Down, t(1.0)).unwrap();
        c.register_node("n1", 1, t(2.0));
        assert_eq!(c.nodes()[0].health, NodeHealth::Up);
        assert_eq!(c.nodes().len(), 1);
    }
}
