//! Multi-partition (multi-queue) batch scheduling — the paper's §5
//! future work: "integration of both CPU and GPU based resources within
//! the same virtual cluster entity pooled from multiple cloud sites and
//! made available to users via different batch queues".
//!
//! [`PartitionedLrms`] composes any number of inner [`Lrms`] plugins, one
//! per partition (SLURM partitions / HTCondor accounting groups), with a
//! single submit/schedule surface. Nodes register into exactly one
//! partition; jobs target a partition by name.

use std::collections::HashMap;

use anyhow::{bail, Context};

use super::{Assignment, Job, JobId, Lrms, NodeHealth, NodeInfo};
use crate::sim::SimTime;

/// A named partition wrapping its own LRMS scheduler instance.
pub struct Partition {
    pub name: String,
    pub lrms: Box<dyn Lrms>,
}

/// Multi-queue façade.
pub struct PartitionedLrms {
    partitions: Vec<Partition>,
    /// Global job id → (partition index, inner job id).
    jobs: HashMap<u64, (usize, JobId)>,
    /// Per-partition reverse map: inner job ids are dense (the core
    /// assigns them sequentially), so `global_of_inner[pi][inner]` is
    /// the global id — a scheduling sweep reverse-maps each assignment
    /// in O(1) instead of scanning every job ever submitted.
    global_of_inner: Vec<Vec<JobId>>,
    /// node name → partition index (names are cluster-unique).
    nodes: HashMap<String, usize>,
    next_job: u64,
}

impl PartitionedLrms {
    pub fn new() -> PartitionedLrms {
        PartitionedLrms {
            partitions: Vec::new(),
            jobs: HashMap::new(),
            global_of_inner: Vec::new(),
            nodes: HashMap::new(),
            next_job: 0,
        }
    }

    /// Add a partition backed by `lrms` (e.g. Slurm::new()).
    pub fn add_partition(&mut self, name: &str, lrms: Box<dyn Lrms>)
        -> anyhow::Result<()> {
        if self.partitions.iter().any(|p| p.name == name) {
            bail!("partition {name:?} already exists");
        }
        self.partitions.push(Partition { name: name.to_string(), lrms });
        self.global_of_inner.push(Vec::new());
        Ok(())
    }

    fn partition_idx(&self, name: &str) -> anyhow::Result<usize> {
        self.partitions
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("no partition {name:?}"))
    }

    pub fn partition_names(&self) -> Vec<&str> {
        self.partitions.iter().map(|p| p.name.as_str()).collect()
    }

    /// Register a node into a partition.
    pub fn register_node(&mut self, partition: &str, node: &str,
                         slots: u32, t: SimTime) -> anyhow::Result<()> {
        let idx = self.partition_idx(partition)?;
        if let Some(&existing) = self.nodes.get(node) {
            if existing != idx {
                bail!("node {node:?} already registered in partition \
                       {:?}", self.partitions[existing].name);
            }
        }
        self.partitions[idx].lrms.register_node(node, slots, t);
        self.nodes.insert(node.to_string(), idx);
        Ok(())
    }

    pub fn deregister_node(&mut self, node: &str, t: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        let idx = *self
            .nodes
            .get(node)
            .with_context(|| format!("unknown node {node:?}"))?;
        let requeued = self.partitions[idx].lrms.deregister_node(node, t)?;
        self.nodes.remove(node);
        Ok(requeued)
    }

    pub fn set_node_health(&mut self, node: &str, health: NodeHealth,
                           t: SimTime) -> anyhow::Result<Vec<JobId>> {
        let idx = *self
            .nodes
            .get(node)
            .with_context(|| format!("unknown node {node:?}"))?;
        self.partitions[idx].lrms.set_node_health(node, health, t)
    }

    /// Submit a job to a partition; returns a *global* job id.
    pub fn submit(&mut self, partition: &str, name: &str, slots: u32,
                  t: SimTime) -> anyhow::Result<JobId> {
        let idx = self.partition_idx(partition)?;
        let inner = self.partitions[idx].lrms.submit(name, slots, t);
        let gid = JobId(self.next_job);
        self.jobs.insert(self.next_job, (idx, inner));
        debug_assert_eq!(inner.0 as usize, self.global_of_inner[idx].len(),
                         "inner job ids must be dense per partition");
        self.global_of_inner[idx].push(gid);
        self.next_job += 1;
        Ok(gid)
    }

    /// One sweep over every partition. Returns (global id, node name).
    pub fn schedule(&mut self, t: SimTime) -> Vec<(JobId, String)> {
        let mut out = Vec::new();
        for (pi, p) in self.partitions.iter_mut().enumerate() {
            for (inner, nid) in p.lrms.schedule(t) {
                let gid = self.global_of_inner[pi][inner.0 as usize];
                let node = p
                    .lrms
                    .node_name(nid)
                    .expect("assigned node must be registered");
                out.push((gid, node));
            }
        }
        out
    }

    pub fn on_job_finished(&mut self, gid: JobId, ok: bool, t: SimTime)
        -> anyhow::Result<()> {
        let &(pi, inner) = self
            .jobs
            .get(&gid.0)
            .with_context(|| format!("unknown job {gid}"))?;
        self.partitions[pi].lrms.on_job_finished(inner, ok, t)
    }

    pub fn job(&self, gid: JobId) -> Option<&Job> {
        let &(pi, inner) = self.jobs.get(&gid.0)?;
        self.partitions[pi].lrms.job(inner)
    }

    /// Pending depth per partition — the per-queue elasticity signal, so
    /// CLUES can scale CPU and GPU pools independently.
    pub fn pending_per_partition(&self) -> Vec<(&str, usize)> {
        self.partitions
            .iter()
            .map(|p| (p.name.as_str(), p.lrms.pending()))
            .collect()
    }

    pub fn nodes_in(&self, partition: &str) -> Vec<NodeInfo> {
        match self.partition_idx(partition) {
            Ok(idx) => self.partitions[idx].lrms.nodes(),
            Err(_) => Vec::new(),
        }
    }

    /// Total assignments view for callers that do not care about queues.
    pub fn all_nodes(&self) -> Vec<(String, NodeInfo)> {
        self.partitions
            .iter()
            .flat_map(|p| {
                p.lrms
                    .nodes()
                    .into_iter()
                    .map(move |n| (p.name.clone(), n))
            })
            .collect()
    }
}

impl Default for PartitionedLrms {
    fn default() -> Self {
        Self::new()
    }
}

/// Type alias documenting intent at call sites.
pub type Queue<'a> = (&'a str, usize);

#[allow(unused)]
fn _assert_object_safe(_: &dyn Lrms) {}

#[allow(unused)]
type _AssignmentAlias = Assignment;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lrms::{HtCondor, Slurm};

    fn cpu_gpu() -> PartitionedLrms {
        let mut p = PartitionedLrms::new();
        p.add_partition("cpu", Box::new(Slurm::new())).unwrap();
        p.add_partition("gpu", Box::new(Slurm::new())).unwrap();
        p.register_node("cpu", "cpu-1", 2, SimTime(0.0)).unwrap();
        p.register_node("cpu", "cpu-2", 2, SimTime(0.0)).unwrap();
        p.register_node("gpu", "gpu-1", 1, SimTime(0.0)).unwrap();
        p
    }

    #[test]
    fn jobs_route_to_their_partition() {
        let mut p = cpu_gpu();
        let a = p.submit("cpu", "preproc", 1, SimTime(0.0)).unwrap();
        let b = p.submit("gpu", "train", 1, SimTime(0.0)).unwrap();
        let assigned = p.schedule(SimTime(1.0));
        let node_of = |id: JobId| assigned.iter()
            .find(|(j, _)| *j == id).map(|(_, n)| n.clone()).unwrap();
        assert!(node_of(a).starts_with("cpu-"));
        assert_eq!(node_of(b), "gpu-1");
    }

    #[test]
    fn gpu_queue_backlogs_independently() {
        let mut p = cpu_gpu();
        for i in 0..5 {
            p.submit("gpu", &format!("g{i}"), 1, SimTime(0.0)).unwrap();
        }
        p.submit("cpu", "c0", 1, SimTime(0.0)).unwrap();
        p.schedule(SimTime(1.0));
        let pending: HashMap<&str, usize> =
            p.pending_per_partition().into_iter().collect();
        assert_eq!(pending["gpu"], 4); // 1 slot, 5 jobs
        assert_eq!(pending["cpu"], 0);
    }

    #[test]
    fn node_names_unique_across_partitions() {
        let mut p = cpu_gpu();
        assert!(p.register_node("gpu", "cpu-1", 1, SimTime(0.0)).is_err());
        // Re-register into the same partition is fine (revival).
        p.register_node("cpu", "cpu-1", 2, SimTime(1.0)).unwrap();
    }

    #[test]
    fn mixed_plugin_partitions() {
        let mut p = PartitionedLrms::new();
        p.add_partition("batch", Box::new(Slurm::new())).unwrap();
        p.add_partition("htc", Box::new(HtCondor::new())).unwrap();
        p.register_node("batch", "b1", 1, SimTime(0.0)).unwrap();
        p.register_node("htc", "h1", 1, SimTime(0.0)).unwrap();
        let a = p.submit("batch", "x", 1, SimTime(0.0)).unwrap();
        let b = p.submit("htc", "y", 1, SimTime(0.0)).unwrap();
        assert_eq!(p.schedule(SimTime(1.0)).len(), 2);
        p.on_job_finished(a, true, SimTime(5.0)).unwrap();
        p.on_job_finished(b, true, SimTime(5.0)).unwrap();
        assert_eq!(p.job(a).unwrap().state, crate::lrms::JobState::Completed);
    }

    #[test]
    fn unknown_partition_rejected() {
        let mut p = cpu_gpu();
        assert!(p.submit("tpu", "z", 1, SimTime(0.0)).is_err());
        assert!(p.add_partition("cpu", Box::new(Slurm::new())).is_err());
    }

    #[test]
    fn health_and_deregistration_via_global_names() {
        let mut p = cpu_gpu();
        let a = p.submit("gpu", "g", 1, SimTime(0.0)).unwrap();
        p.schedule(SimTime(0.0));
        let requeued = p.set_node_health("gpu-1", NodeHealth::Down,
                                         SimTime(1.0)).unwrap();
        assert_eq!(requeued.len(), 1);
        assert_eq!(p.job(a).unwrap().state, crate::lrms::JobState::Pending);
        p.deregister_node("gpu-1", SimTime(2.0)).unwrap();
        assert!(p.nodes_in("gpu").is_empty());
        assert_eq!(p.all_nodes().len(), 2);
    }
}
