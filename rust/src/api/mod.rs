//! REST API of the PaaS Orchestrator (§3.2: "users can interact with the
//! PaaS Orchestrator via its REST API, also using the *orchent*
//! command-line interface").
//!
//! A dependency-free HTTP/1.1 server over `std::net` (tokio is not
//! available offline): one thread per connection, an in-memory deployment
//! store, and hand-rolled JSON rendering. Endpoints:
//!
//! ```text
//! GET    /templates              list built-in TOSCA templates
//! GET    /deployments            list deployments
//! POST   /deployments            body = TOSCA YAML → deploy + run
//! GET    /deployments/{id}       one deployment's summary
//! DELETE /deployments/{id}       undeploy (forget)
//! GET    /health                 liveness probe
//! ```

pub mod http;
pub mod json;

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::{HybridCluster, RunConfig};

use http::{read_request, Request};
use json::Json;

/// Stored outcome of one deployment request.
#[derive(Debug, Clone)]
pub struct DeploymentRecord {
    pub id: u64,
    pub template_name: String,
    pub status: String,
    pub jobs_completed: u32,
    pub makespan_secs: f64,
    pub cost_usd: f64,
    pub sites: Vec<String>,
}

impl DeploymentRecord {
    fn to_json(&self) -> Json {
        Json::Object(vec![
            ("id".into(), Json::Num(self.id as f64)),
            ("template".into(), Json::Str(self.template_name.clone())),
            ("status".into(), Json::Str(self.status.clone())),
            ("jobs_completed".into(),
             Json::Num(self.jobs_completed as f64)),
            ("makespan_secs".into(), Json::Num(self.makespan_secs)),
            ("cost_usd".into(), Json::Num(self.cost_usd)),
            ("sites".into(), Json::Array(
                self.sites.iter().cloned().map(Json::Str).collect())),
        ])
    }
}

#[derive(Default)]
struct Store {
    deployments: BTreeMap<u64, DeploymentRecord>,
}

/// Handle to a running API server.
pub struct ApiServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ApiServer {
    /// Bind (`"127.0.0.1:0"` for an ephemeral port) and serve in
    /// background threads until [`ApiServer::stop`] or drop.
    pub fn start(bind: &str) -> anyhow::Result<ApiServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let store = Arc::new(Mutex::new(Store::default()));
        let next_id = Arc::new(AtomicU64::new(1));

        let sd = shutdown.clone();
        let handle = std::thread::spawn(move || {
            while !sd.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let store = store.clone();
                        let next_id = next_id.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &store, &next_id);
                        });
                    }
                    Err(ref e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ApiServer { addr, shutdown, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ApiServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn respond(stream: &mut TcpStream, status: u16, body: &Json)
    -> std::io::Result<()> {
    let text = body.render();
    let reason = match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{text}",
        text.len()
    )
}

fn err_json(msg: &str) -> Json {
    Json::Object(vec![("error".into(), Json::Str(msg.into()))])
}

fn handle_conn(mut stream: TcpStream, store: &Mutex<Store>,
               next_id: &AtomicU64) -> anyhow::Result<()> {
    let req: Request = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = respond(&mut stream, 400, &err_json(&e.to_string()));
            return Ok(());
        }
    };
    let segments: Vec<&str> =
        req.path.trim_matches('/').split('/').collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["health"]) => {
            respond(&mut stream, 200, &Json::Object(vec![(
                "status".into(), Json::Str("up".into()))]))?;
        }
        ("GET", ["templates"]) => {
            let list = Json::Array(
                ["slurm", "htcondor"]
                    .iter()
                    .map(|n| {
                        let t = crate::tosca::builtin(n).expect("builtin");
                        Json::Object(vec![
                            ("name".into(), Json::Str(n.to_string())),
                            ("display_name".into(), Json::Str(t.name)),
                            ("lrms".into(),
                             Json::Str(t.lrms.name().into())),
                            ("max_workers".into(),
                             Json::Num(t.scalable.max_instances as f64)),
                        ])
                    })
                    .collect(),
            );
            respond(&mut stream, 200, &list)?;
        }
        ("GET", ["deployments"]) => {
            let store = store.lock().unwrap();
            let list = Json::Array(
                store.deployments.values().map(|d| d.to_json()).collect());
            respond(&mut stream, 200, &list)?;
        }
        ("POST", ["deployments"]) => {
            match deploy_from_body(&req.body, next_id) {
                Ok(rec) => {
                    let json = rec.to_json();
                    store.lock().unwrap().deployments.insert(rec.id, rec);
                    respond(&mut stream, 201, &json)?;
                }
                Err(e) => {
                    respond(&mut stream, 400,
                            &err_json(&format!("{e:#}")))?;
                }
            }
        }
        ("GET", ["deployments", id]) => {
            let id: u64 = id.parse().unwrap_or(0);
            let store = store.lock().unwrap();
            match store.deployments.get(&id) {
                Some(d) => respond(&mut stream, 200, &d.to_json())?,
                None => respond(&mut stream, 404,
                                &err_json("no such deployment"))?,
            }
        }
        ("DELETE", ["deployments", id]) => {
            let id: u64 = id.parse().unwrap_or(0);
            let mut store = store.lock().unwrap();
            match store.deployments.remove(&id) {
                Some(_) => respond(&mut stream, 200, &Json::Object(vec![(
                    "deleted".into(), Json::Num(id as f64))]))?,
                None => respond(&mut stream, 404,
                                &err_json("no such deployment"))?,
            }
        }
        _ => {
            respond(&mut stream, 405, &err_json("unsupported route"))?;
        }
    }
    Ok(())
}

/// Parse the TOSCA body, run the deployment simulation, record results.
fn deploy_from_body(body: &str, next_id: &AtomicU64)
    -> anyhow::Result<DeploymentRecord> {
    let template = if body.trim().is_empty() {
        crate::tosca::builtin("slurm")?
    } else {
        crate::tosca::parse(body)?
    };
    let mut cfg = RunConfig::paper_usecase(0.02, 99);
    cfg.template = template.clone();
    let report = HybridCluster::new(cfg)?.run()?;
    let mut sites: Vec<String> =
        report.per_vm.iter().map(|r| r.site.clone()).collect();
    sites.sort();
    sites.dedup();
    Ok(DeploymentRecord {
        id: next_id.fetch_add(1, Ordering::SeqCst),
        template_name: template.name,
        status: "CREATE_COMPLETE".into(),
        jobs_completed: report.jobs_completed,
        makespan_secs: report.makespan.0,
        cost_usd: report.total_cost_usd,
        sites,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn request(addr: std::net::SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 = buf
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (status, body)
    }

    fn get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
        request(addr, &format!(
            "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n"))
    }

    #[test]
    fn health_and_templates() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let (code, body) = get(srv.addr, "/health");
        assert_eq!(code, 200);
        assert!(body.contains("\"up\""));
        let (code, body) = get(srv.addr, "/templates");
        assert_eq!(code, 200);
        assert!(body.contains("SLURM Elastic cluster"), "{body}");
        assert!(body.contains("htcondor"));
        srv.stop();
    }

    #[test]
    fn deployment_lifecycle_over_http() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        // Create (empty body → default template).
        let (code, body) = request(srv.addr,
            "POST /deployments HTTP/1.1\r\nHost: x\r\nContent-Length: 0\
             \r\nConnection: close\r\n\r\n");
        assert_eq!(code, 201, "{body}");
        assert!(body.contains("CREATE_COMPLETE"), "{body}");
        assert!(body.contains("\"id\":1"), "{body}");

        let (code, body) = get(srv.addr, "/deployments/1");
        assert_eq!(code, 200);
        assert!(body.contains("jobs_completed"));

        let (code, body) = get(srv.addr, "/deployments");
        assert_eq!(code, 200);
        assert!(body.starts_with('['), "{body}");

        let (code, _) = request(srv.addr,
            "DELETE /deployments/1 HTTP/1.1\r\nHost: x\r\nConnection: \
             close\r\n\r\n");
        assert_eq!(code, 200);
        let (code, _) = get(srv.addr, "/deployments/1");
        assert_eq!(code, 404);
        srv.stop();
    }

    #[test]
    fn posting_tosca_body_uses_it() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let tosca = crate::tosca::HTCONDOR_ELASTIC_TEMPLATE;
        let raw = format!(
            "POST /deployments HTTP/1.1\r\nHost: x\r\nContent-Length: {}\
             \r\nConnection: close\r\n\r\n{tosca}",
            tosca.len());
        let (code, body) = request(srv.addr, &raw);
        assert_eq!(code, 201, "{body}");
        assert!(body.contains("HTCondor Elastic cluster"), "{body}");
        srv.stop();
    }

    #[test]
    fn malformed_tosca_is_400() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let bad = "not: tosca\n";
        let raw = format!(
            "POST /deployments HTTP/1.1\r\nHost: x\r\nContent-Length: {}\
             \r\nConnection: close\r\n\r\n{bad}", bad.len());
        let (code, body) = request(srv.addr, &raw);
        assert_eq!(code, 400);
        assert!(body.contains("error"));
        srv.stop();
    }

    #[test]
    fn unknown_route_is_405() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let (code, _) = get(srv.addr, "/nope");
        assert_eq!(code, 405);
        srv.stop();
    }
}
