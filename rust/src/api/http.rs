//! Minimal HTTP/1.1 request parsing for the API server (std::net only).

use std::io::Read;
use std::net::TcpStream;

use anyhow::{bail, Context};

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Maximum accepted body (TOSCA templates are small).
const MAX_BODY: usize = 1 << 20;

/// Read and parse one request from the stream.
pub fn read_request(stream: &mut TcpStream) -> anyhow::Result<Request> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    // Read until end of headers.
    let header_end = loop {
        let n = stream.read(&mut tmp).context("reading request")?;
        if n == 0 {
            bail!("connection closed before headers complete");
        }
        buf.extend_from_slice(&tmp[..n]);
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos + 4;
        }
        if buf.len() > MAX_BODY {
            bail!("headers too large");
        }
    };

    let head = std::str::from_utf8(&buf[..header_end])
        .context("headers not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().context("empty request")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path_full = parts.next().context("missing path")?.to_string();
    let path = path_full.split('?').next().unwrap_or("/").to_string();

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    // Body per Content-Length.
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    if content_length > MAX_BODY {
        bail!("body too large ({content_length} bytes)");
    }
    let mut body_bytes = buf[header_end..].to_vec();
    while body_bytes.len() < content_length {
        let n = stream.read(&mut tmp).context("reading body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body_bytes.extend_from_slice(&tmp[..n]);
    }
    body_bytes.truncate(content_length);
    let body = String::from_utf8(body_bytes).context("body not UTF-8")?;

    Ok(Request { method, path, headers, body })
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"abc\r\n\r\nxyz", b"\r\n\r\n"), Some(3));
        assert_eq!(find_subslice(b"abc", b"\r\n\r\n"), None);
    }

    // Request parsing over real sockets is covered by api::tests.
}
