//! Hand-rolled JSON rendering + a small value parser (serde is not
//! available offline). The server only needs rendering; the parser exists
//! so tests and the orchent-style client can inspect responses.

use anyhow::bail;

/// JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    /// Render to compact JSON text.
    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Str(s) => escape(s),
            Json::Array(items) => {
                let inner: Vec<String> =
                    items.iter().map(|i| i.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Object(fields) => {
                let inner: Vec<String> = fields
                    .iter()
                    .map(|(k, v)| format!("{}:{}", escape(k), v.render()))
                    .collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse JSON text (full value grammar; no exotic escapes beyond \uXXXX).
pub fn parse(src: &str) -> anyhow::Result<Json> {
    let bytes: Vec<char> = src.chars().collect();
    let mut pos = 0usize;
    let v = parse_value(&bytes, &mut pos)?;
    skip_ws(&bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[char], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_whitespace() {
        *pos += 1;
    }
}

fn parse_value(b: &[char], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some('n') => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        Some('t') => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        Some('f') => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        Some('"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some('[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&']') {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some(']') => {
                        *pos += 1;
                        return Ok(Json::Array(items));
                    }
                    other => bail!("expected , or ] got {other:?}"),
                }
            }
        }
        Some('{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&'}') {
                *pos += 1;
                return Ok(Json::Object(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&':') {
                    bail!("expected : after key {key:?}");
                }
                *pos += 1;
                let value = parse_value(b, pos)?;
                fields.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some('}') => {
                        *pos += 1;
                        return Ok(Json::Object(fields));
                    }
                    other => bail!("expected , or }} got {other:?}"),
                }
            }
        }
        Some(c) if *c == '-' || c.is_ascii_digit() => {
            let start = *pos;
            *pos += 1;
            while *pos < b.len()
                && (b[*pos].is_ascii_digit()
                    || matches!(b[*pos], '.' | 'e' | 'E' | '+' | '-'))
            {
                *pos += 1;
            }
            let text: String = b[start..*pos].iter().collect();
            Ok(Json::Num(text.parse()?))
        }
        Some(c) => bail!("unexpected character {c:?}"),
    }
}

fn expect(b: &[char], pos: &mut usize, word: &str) -> anyhow::Result<()> {
    for w in word.chars() {
        if b.get(*pos) != Some(&w) {
            bail!("expected {word:?}");
        }
        *pos += 1;
    }
    Ok(())
}

fn parse_string(b: &[char], pos: &mut usize) -> anyhow::Result<String> {
    if b.get(*pos) != Some(&'"') {
        bail!("expected string");
    }
    *pos += 1;
    let mut out = String::new();
    while let Some(&c) = b.get(*pos) {
        *pos += 1;
        match c {
            '"' => return Ok(out),
            '\\' => {
                let esc = b.get(*pos).copied()
                    .ok_or_else(|| anyhow::anyhow!("dangling escape"))?;
                *pos += 1;
                match esc {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    '/' => out.push('/'),
                    'n' => out.push('\n'),
                    'r' => out.push('\r'),
                    't' => out.push('\t'),
                    'u' => {
                        let hex: String =
                            b[*pos..(*pos + 4).min(b.len())].iter()
                                .collect();
                        if hex.len() != 4 {
                            bail!("short \\u escape");
                        }
                        *pos += 4;
                        let code = u32::from_str_radix(&hex, 16)?;
                        out.push(char::from_u32(code)
                            .unwrap_or('\u{FFFD}'));
                    }
                    other => bail!("unknown escape \\{other}"),
                }
            }
            c => out.push(c),
        }
    }
    bail!("unterminated string")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_roundtrip() {
        let v = Json::Object(vec![
            ("id".into(), Json::Num(3.0)),
            ("name".into(), Json::Str("fr\"ont\nend".into())),
            ("sites".into(), Json::Array(vec![
                Json::Str("CESNET".into()),
                Json::Str("AWS".into()),
            ])),
            ("up".into(), Json::Bool(true)),
            ("none".into(), Json::Null),
            ("ratio".into(), Json::Num(0.66)),
        ]);
        let text = v.render();
        let back = parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(back.get("sites").unwrap(),
                   &Json::Array(vec![Json::Str("CESNET".into()),
                                     Json::Str("AWS".into())]));
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse("\"a\\u0041b\"").unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }
}
