//! IaaS cloud-site simulator.
//!
//! Stands in for the paper's real back-ends (CESNET MetaCentrum OpenStack
//! and AWS EC2 us-east-2): instance catalogs, quotas, private networks,
//! public-IP scarcity, VM lifecycle latencies, per-second/per-hour
//! billing, and failure injection. The Infrastructure Manager talks to
//! sites exclusively through [`CloudSite`]'s methods, mirroring the
//! provider-API surface the real IM wraps via Apache Libcloud.

pub mod failure;
pub mod network;
pub mod pricing;
pub mod vm;

use std::collections::HashMap;

use anyhow::{bail, Context};

use crate::netsim::NetId;
use crate::sim::SimTime;
use crate::util::prng::Prng;

pub use failure::{FailureModel, InjectionPlan, TransientDown};
pub use network::{ip_to_string, NetworkId, NetworkManager};
pub use pricing::{Granularity, Ledger, Price};
pub use vm::{Vm, VmId, VmState};

/// Cloud management framework flavour (affects which IM connector is
/// "used"; behaviourally identical in the simulator apart from feature
/// flags like private-network support).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provider {
    OpenStack,
    Aws,
    OpenNebula,
}

/// One instance type in a site's catalog.
#[derive(Debug, Clone)]
pub struct InstanceType {
    pub name: String,
    pub vcpus: u32,
    pub mem_gb: f64,
    pub price: Price,
}

/// Resource quotas enforced per deployment user.
#[derive(Debug, Clone)]
pub struct Quota {
    pub max_vms: usize,
    pub max_vcpus: u32,
    pub max_public_ips: usize,
}

/// Latency model for provider control-plane operations (seconds).
#[derive(Debug, Clone)]
pub struct OpLatency {
    /// Median VM request→running time; log-normal sigma alongside.
    pub vm_boot_median: f64,
    pub vm_boot_sigma: f64,
    pub network_create: f64,
    pub terminate: f64,
}

/// Static description of a site.
#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub name: String,
    pub provider: Provider,
    pub region: String,
    pub instance_types: Vec<InstanceType>,
    pub quota: Quota,
    pub op_latency: OpLatency,
    pub failure: FailureModel,
    /// Whether users may create private L2 networks (challenge v in §1;
    /// sites without it force stand-alone-node deployments, §3.5.4).
    pub supports_private_networks: bool,
    /// Monitored availability in [0,1] (input to orchestrator ranking).
    pub availability: f64,
}

impl SiteSpec {
    /// CESNET MetaCentrum Cloud (OpenStack) as used in the paper's §4.
    /// Quota sized so only the FE + 2 WNs fit — the paper's step 2.
    pub fn cesnet_metacentrum() -> SiteSpec {
        SiteSpec {
            name: "CESNET-MCC".into(),
            provider: Provider::OpenStack,
            region: "prague".into(),
            instance_types: vec![
                InstanceType {
                    name: "standard.medium".into(),
                    vcpus: 2,
                    mem_gb: 4.0,
                    price: Price::free(),
                },
                InstanceType {
                    name: "standard.small".into(),
                    vcpus: 1,
                    mem_gb: 2.0,
                    price: Price::free(),
                },
            ],
            quota: Quota { max_vms: 3, max_vcpus: 6, max_public_ips: 1 },
            op_latency: OpLatency {
                vm_boot_median: 95.0,
                vm_boot_sigma: 0.20,
                network_create: 8.0,
                terminate: 60.0,
            },
            failure: FailureModel::none(),
            supports_private_networks: true,
            availability: 0.97,
        }
    }

    /// AWS us-east-2 (Ohio) as used in the paper's §4: t2.medium WNs
    /// billed per second, t2.micro for the site vRouter.
    pub fn aws_us_east_2() -> SiteSpec {
        SiteSpec {
            name: "AWS".into(),
            provider: Provider::Aws,
            region: "us-east-2".into(),
            instance_types: vec![
                InstanceType {
                    name: "t2.medium".into(),
                    vcpus: 2,
                    mem_gb: 4.0,
                    price: Price {
                        usd_per_hour: 0.0464,
                        granularity: Granularity::PerSecond,
                    },
                },
                InstanceType {
                    name: "t2.micro".into(),
                    vcpus: 1,
                    mem_gb: 1.0,
                    price: Price {
                        usd_per_hour: 0.0116,
                        granularity: Granularity::PerSecond,
                    },
                },
            ],
            quota: Quota { max_vms: 20, max_vcpus: 40, max_public_ips: 5 },
            op_latency: OpLatency {
                vm_boot_median: 140.0,
                vm_boot_sigma: 0.25,
                network_create: 12.0,
                // Full decommission (drain + EC2 terminate + dereg).
                // Five of these serialized behind the workflow engine are
                // the paper's "twenty extra minutes ... to power off".
                terminate: 160.0,
            },
            failure: FailureModel::none(),
            supports_private_networks: true,
            availability: 0.999,
        }
    }

    /// The instance type a cluster provisions for one worker with the
    /// given requirements: the smallest satisfying catalog entry,
    /// falling back to the first entry. Shared by the deployment path
    /// and the broker's price table so the price a policy ranks by is
    /// the price the ledger bills.
    pub fn worker_instance_type(&self, cpus: u32, mem_gb: f64)
        -> &InstanceType {
        self.instance_types
            .iter()
            .filter(|t| t.vcpus >= cpus && t.mem_gb >= mem_gb)
            .min_by(|a, b| a.vcpus.cmp(&b.vcpus))
            .unwrap_or(&self.instance_types[0])
    }

    /// AWS us-east-2 spot capacity: the same catalog at a ~70% discount,
    /// but carrying a preemption hazard — the signal the broker's
    /// `SpotAware` policy weighs a site by.
    pub fn aws_spot_us_east_2() -> SiteSpec {
        let mut s = SiteSpec::aws_us_east_2();
        s.name = "AWS-spot".into();
        for t in &mut s.instance_types {
            t.price.usd_per_hour *= 0.3;
        }
        s.failure.preempt_rate_per_hour = 0.05;
        s
    }

    /// A generic OpenNebula research site (for multi-site benches).
    pub fn opennebula(name: &str) -> SiteSpec {
        SiteSpec {
            name: name.into(),
            provider: Provider::OpenNebula,
            region: "eu".into(),
            instance_types: vec![InstanceType {
                name: "medium".into(),
                vcpus: 2,
                mem_gb: 4.0,
                price: Price::free(),
            }],
            quota: Quota { max_vms: 8, max_vcpus: 16, max_public_ips: 2 },
            op_latency: OpLatency {
                vm_boot_median: 110.0,
                vm_boot_sigma: 0.3,
                network_create: 10.0,
                terminate: 30.0,
            },
            failure: FailureModel::none(),
            supports_private_networks: true,
            availability: 0.95,
        }
    }
}

/// A VM creation request, as issued by the Infrastructure Manager.
#[derive(Debug, Clone)]
pub struct VmRequest {
    pub name: String,
    pub instance_type: String,
    pub network: Option<NetworkId>,
    pub public_ip: bool,
}

/// Outcome of a VM request: the id plus how long until it is Running
/// (or fails, per `will_fail`).
#[derive(Debug, Clone)]
pub struct VmTicket {
    pub vm: VmId,
    pub boot_secs: f64,
    pub will_fail: bool,
}

/// Live state of one cloud site.
pub struct CloudSite {
    pub spec: SiteSpec,
    /// Index used for subnet carving and netsim location mapping.
    pub site_index: u8,
    pub net_id: NetId,
    pub networks: NetworkManager,
    vms: HashMap<VmId, Vm>,
    next_vm: u64,
    pub ledger: Ledger,
    /// Multiplier applied to list prices of entries opened from now on
    /// (scenario-driven price spikes; 1.0 = list price).
    price_factor: f64,
    rng: Prng,
}

/// Identity `AsRef` so APIs generic over "anything that carries a
/// cloud site" (the elasticity broker) accept plain site vectors and
/// wrapper worlds (e.g. the cluster's `SiteWorld`) alike.
impl AsRef<CloudSite> for CloudSite {
    fn as_ref(&self) -> &CloudSite {
        self
    }
}

impl CloudSite {
    pub fn new(spec: SiteSpec, site_index: u8, net_id: NetId, seed: u64)
        -> CloudSite {
        let quota_ips = spec.quota.max_public_ips;
        CloudSite {
            spec,
            site_index,
            net_id,
            networks: NetworkManager::new(site_index, quota_ips),
            vms: HashMap::new(),
            next_vm: 0,
            ledger: Ledger::default(),
            price_factor: 1.0,
            rng: Prng::new(seed ^ 0xC10D),
        }
    }

    /// Current price multiplier (1.0 = list price).
    pub fn price_factor(&self) -> f64 {
        self.price_factor
    }

    /// Set the multiplier applied to VMs launched from now on. Entries
    /// already open keep the rate they were opened at, mirroring how
    /// on-demand price changes bind at launch time.
    pub fn set_price_factor(&mut self, factor: f64) {
        self.price_factor = factor.max(0.0);
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    fn instance_type(&self, name: &str) -> anyhow::Result<&InstanceType> {
        self.spec
            .instance_types
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!(
                "site {}: unknown instance type {name:?}", self.spec.name))
    }

    /// vCPUs currently counted against quota (alive or pending VMs).
    pub fn used_vcpus(&self) -> u32 {
        self.vms
            .values()
            .filter(|v| !matches!(v.state,
                VmState::Terminated | VmState::Failed))
            .map(|v| {
                self.instance_type(&v.instance_type)
                    .map(|t| t.vcpus)
                    .unwrap_or(0)
            })
            .sum()
    }

    /// VMs currently counted against quota.
    pub fn used_vms(&self) -> usize {
        self.vms
            .values()
            .filter(|v| !matches!(v.state,
                VmState::Terminated | VmState::Failed))
            .count()
    }

    /// Create a private network; returns (id, creation latency seconds).
    pub fn create_network(&mut self, name: &str)
        -> anyhow::Result<(NetworkId, f64)> {
        if !self.spec.supports_private_networks {
            bail!("site {} does not support user-created private networks",
                  self.spec.name);
        }
        let id = self.networks.create_network(name)?;
        Ok((id, self.spec.op_latency.network_create))
    }

    /// Request a VM. Checks quota, allocates addresses, opens billing,
    /// and samples the boot latency (and whether the boot will fail).
    /// The caller (IM) schedules `complete_boot` after `boot_secs`.
    pub fn request_vm(&mut self, req: &VmRequest, t: SimTime)
        -> anyhow::Result<VmTicket> {
        let itype = self.instance_type(&req.instance_type)?.clone();
        if self.used_vms() + 1 > self.spec.quota.max_vms {
            bail!("site {}: VM quota exceeded ({} max)", self.spec.name,
                  self.spec.quota.max_vms);
        }
        if self.used_vcpus() + itype.vcpus > self.spec.quota.max_vcpus {
            bail!("site {}: vCPU quota exceeded ({} max)", self.spec.name,
                  self.spec.quota.max_vcpus);
        }

        let id = VmId(self.next_vm);
        self.next_vm += 1;
        let mut vm = Vm::new(id, &req.name, &req.instance_type, t);

        if let Some(netid) = req.network {
            let net = self
                .networks
                .get_mut(netid)
                .with_context(|| format!("no network {netid:?}"))?;
            vm.private_ip = Some(net.allocate()?);
            vm.network = Some(netid);
        }
        if req.public_ip {
            vm.public_ip = Some(self.networks.public_pool.allocate()?);
        }

        vm.transition(VmState::Booting, t)?;
        let price = Price {
            usd_per_hour: itype.price.usd_per_hour * self.price_factor,
            granularity: itype.price.granularity,
        };
        self.ledger.open(&req.name, &req.instance_type, &price, t);

        let boot_secs = self.rng.lognormal(
            self.spec.op_latency.vm_boot_median,
            self.spec.op_latency.vm_boot_sigma,
        );
        let will_fail = self.spec.failure.boot_fails(&mut self.rng);
        self.vms.insert(id, vm);
        Ok(VmTicket { vm: id, boot_secs, will_fail })
    }

    /// Finish booting: Running on success, Failed (billing closed) if the
    /// ticket said the boot would fail.
    pub fn complete_boot(&mut self, id: VmId, failed: bool, t: SimTime)
        -> anyhow::Result<VmState> {
        let vm = self.vm_mut(id)?;
        if failed {
            vm.transition(VmState::Failed, t)?;
            let name = vm.name.clone();
            self.release_addresses(id)?;
            self.ledger.close(&name, t);
            Ok(VmState::Failed)
        } else {
            vm.transition(VmState::Running, t)?;
            Ok(VmState::Running)
        }
    }

    /// Begin termination; returns the provider-side latency. The caller
    /// schedules `complete_termination` after it.
    pub fn terminate_vm(&mut self, id: VmId, t: SimTime)
        -> anyhow::Result<f64> {
        let vm = self.vm_mut(id)?;
        vm.transition(VmState::Terminating, t)?;
        Ok(self.spec.op_latency.terminate)
    }

    /// Finish termination: close billing, release addresses. A VM
    /// whose billing already ended (it crashed and was then cleaned up
    /// via Failed → Terminating) keeps its original close — names are
    /// reused across incarnations, so a second by-name ledger close
    /// here would pop a *successor* VM's open entry.
    pub fn complete_termination(&mut self, id: VmId, t: SimTime)
        -> anyhow::Result<()> {
        let vm = self.vm_mut(id)?;
        let billing_already_ended = vm.billing_end.is_some();
        vm.transition(VmState::Terminated, t)?;
        let name = vm.name.clone();
        self.release_addresses(id)?;
        if !billing_already_ended {
            self.ledger.close(&name, t);
        }
        Ok(())
    }

    /// Hard-crash a running VM (failure injection).
    pub fn crash_vm(&mut self, id: VmId, t: SimTime) -> anyhow::Result<()> {
        let vm = self.vm_mut(id)?;
        vm.transition(VmState::Failed, t)?;
        let name = vm.name.clone();
        self.release_addresses(id)?;
        self.ledger.close(&name, t);
        Ok(())
    }

    fn release_addresses(&mut self, id: VmId) -> anyhow::Result<()> {
        let (private_ip, public_ip, network) = {
            let vm = self.vm_mut(id)?;
            let out = (vm.private_ip, vm.public_ip, vm.network);
            vm.private_ip = None;
            vm.public_ip = None;
            out
        };
        if let (Some(ip), Some(netid)) = (private_ip, network) {
            if let Some(net) = self.networks.get_mut(netid) {
                net.release(ip);
            }
        }
        if let Some(ip) = public_ip {
            self.networks.public_pool.release(ip);
        }
        Ok(())
    }

    pub fn vm(&self, id: VmId) -> anyhow::Result<&Vm> {
        self.vms.get(&id).with_context(|| format!("no VM {id:?}"))
    }

    fn vm_mut(&mut self, id: VmId) -> anyhow::Result<&mut Vm> {
        self.vms.get_mut(&id).with_context(|| format!("no VM {id:?}"))
    }

    pub fn vms(&self) -> impl Iterator<Item = &Vm> {
        self.vms.values()
    }

    /// Total site cost as of `t`.
    pub fn total_cost(&self, t: SimTime) -> f64 {
        self.ledger.total_cost(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aws() -> CloudSite {
        CloudSite::new(SiteSpec::aws_us_east_2(), 1, NetId(1), 42)
    }

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn req(name: &str, net: Option<NetworkId>, public: bool) -> VmRequest {
        VmRequest {
            name: name.into(),
            instance_type: "t2.medium".into(),
            network: net,
            public_ip: public,
        }
    }

    #[test]
    fn full_vm_lifecycle_with_network() {
        let mut s = aws();
        let (net, lat) = s.create_network("dep-net").unwrap();
        assert!(lat > 0.0);
        let ticket = s.request_vm(&req("wn1", Some(net), false), t(0.0))
            .unwrap();
        assert!(ticket.boot_secs > 30.0 && ticket.boot_secs < 600.0,
                "{}", ticket.boot_secs);
        assert!(!ticket.will_fail);
        let st = s.complete_boot(ticket.vm, ticket.will_fail,
                                 t(ticket.boot_secs)).unwrap();
        assert_eq!(st, VmState::Running);
        let vm = s.vm(ticket.vm).unwrap();
        assert!(vm.private_ip.is_some());
        assert!(vm.public_ip.is_none());

        let term = s.terminate_vm(ticket.vm, t(1000.0)).unwrap();
        s.complete_termination(ticket.vm, t(1000.0 + term)).unwrap();
        assert_eq!(s.used_vms(), 0);
        assert_eq!(s.networks.get(net).unwrap().allocated_count(), 0);
        assert!(s.total_cost(t(2000.0)) > 0.0);
    }

    #[test]
    fn vm_quota_enforced() {
        let mut s = CloudSite::new(SiteSpec::cesnet_metacentrum(), 0,
                                   NetId(0), 1);
        let r = VmRequest {
            name: "n".into(),
            instance_type: "standard.medium".into(),
            network: None,
            public_ip: false,
        };
        for i in 0..3 {
            let mut ri = r.clone();
            ri.name = format!("n{i}");
            s.request_vm(&ri, t(0.0)).unwrap();
        }
        // CESNET quota is 3 VMs — the paper's on-prem ceiling.
        assert!(s.request_vm(&r, t(0.0)).is_err());
    }

    #[test]
    fn public_ip_quota_enforced() {
        let mut s = CloudSite::new(SiteSpec::cesnet_metacentrum(), 0,
                                   NetId(0), 1);
        let mk = |name: &str, public| VmRequest {
            name: name.into(),
            instance_type: "standard.small".into(),
            network: None,
            public_ip: public,
        };
        s.request_vm(&mk("fe", true), t(0.0)).unwrap();
        // Only 1 public IP at CESNET (challenge iv in §1).
        assert!(s.request_vm(&mk("fe2", true), t(0.0)).is_err());
        // But private-only VMs still fit.
        s.request_vm(&mk("wn", false), t(0.0)).unwrap();
    }

    #[test]
    fn boot_failure_closes_billing_and_releases() {
        let mut s = aws();
        s.spec.failure = FailureModel { boot_failure_prob: 1.0,
                                        ..FailureModel::none() };
        let (net, _) = s.create_network("n").unwrap();
        let ticket = s.request_vm(&req("doomed", Some(net), true), t(0.0))
            .unwrap();
        assert!(ticket.will_fail);
        let st = s.complete_boot(ticket.vm, true, t(60.0)).unwrap();
        assert_eq!(st, VmState::Failed);
        assert_eq!(s.used_vms(), 0);
        assert_eq!(s.networks.public_pool.in_use(), 0);
        // Billing covers the 60 failed seconds only.
        let cost = s.total_cost(t(7200.0));
        let expect = 0.0464 * 60.0 / 3600.0;
        assert!((cost - expect).abs() < 1e-9, "{cost}");
    }

    #[test]
    fn crash_releases_resources() {
        let mut s = aws();
        let ticket = s.request_vm(&req("wn", None, false), t(0.0)).unwrap();
        s.complete_boot(ticket.vm, false, t(100.0)).unwrap();
        s.crash_vm(ticket.vm, t(200.0)).unwrap();
        assert_eq!(s.used_vms(), 0);
        assert_eq!(s.vm(ticket.vm).unwrap().state, VmState::Failed);
    }

    #[test]
    fn price_spike_applies_to_new_launches_only() {
        let mut s = aws();
        let a = s.request_vm(&req("before", None, false), t(0.0)).unwrap();
        s.complete_boot(a.vm, false, t(10.0)).unwrap();
        s.set_price_factor(3.0);
        let b = s.request_vm(&req("during", None, false), t(0.0)).unwrap();
        s.complete_boot(b.vm, false, t(10.0)).unwrap();
        // Open rate = list + 3x list.
        let rate = s.ledger.open_rate_usd_per_hour();
        assert!((rate - 0.0464 * 4.0).abs() < 1e-9, "{rate}");
        s.set_price_factor(1.0);
        let c = s.request_vm(&req("after", None, false), t(0.0)).unwrap();
        s.complete_boot(c.vm, false, t(10.0)).unwrap();
        assert!((s.ledger.open_rate_usd_per_hour() - 0.0464 * 5.0).abs()
                < 1e-9);
    }

    #[test]
    fn crashed_then_terminated_vm_closes_billing_once() {
        let mut s = aws();
        let a = s.request_vm(&req("wn", None, false), t(0.0)).unwrap();
        s.complete_boot(a.vm, false, t(10.0)).unwrap();
        s.crash_vm(a.vm, t(100.0)).unwrap();
        // The name is reused by a successor while cleanup of the
        // crashed VM is still in flight.
        let b = s.request_vm(&req("wn", None, false), t(150.0)).unwrap();
        s.complete_boot(b.vm, false, t(160.0)).unwrap();
        let secs = s.terminate_vm(a.vm, t(200.0)).unwrap(); // cleanup
        s.complete_termination(a.vm, t(200.0 + secs)).unwrap();
        // The successor's ledger entry must still be open and billing —
        // the crashed VM's close happened at the crash, not here.
        assert!(s.ledger.open_rate_usd_per_hour() > 0.0);
        assert_eq!(s.vm(b.vm).unwrap().state, VmState::Running);
    }

    #[test]
    fn spot_spec_is_discounted_and_hazardous() {
        let od = SiteSpec::aws_us_east_2();
        let spot = SiteSpec::aws_spot_us_east_2();
        assert_eq!(spot.name, "AWS-spot");
        assert!(spot.instance_types[0].price.usd_per_hour
                < od.instance_types[0].price.usd_per_hour);
        assert!(spot.failure.preempt_rate_per_hour > 0.0);
        assert_eq!(od.failure.preempt_rate_per_hour, 0.0);
    }

    #[test]
    fn unknown_instance_type_rejected() {
        let mut s = aws();
        let r = VmRequest {
            name: "x".into(),
            instance_type: "p5.48xlarge".into(),
            network: None,
            public_ip: false,
        };
        assert!(s.request_vm(&r, t(0.0)).is_err());
    }

    #[test]
    fn boot_latency_is_lognormal_around_median() {
        let mut s = aws();
        let mut secs = Vec::new();
        for i in 0..40 {
            let ticket = s
                .request_vm(&req(&format!("v{i}"), None, false), t(0.0))
                .unwrap();
            secs.push(ticket.boot_secs);
            // Free quota again.
            s.complete_boot(ticket.vm, false, t(1.0)).unwrap();
            s.crash_vm(ticket.vm, t(2.0)).unwrap();
        }
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = secs[20];
        assert!((median - 140.0).abs() < 40.0, "median={median}");
    }
}
