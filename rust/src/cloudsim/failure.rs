//! Failure injection: boot failures, running-VM crashes, and transient
//! "falsely reported down" glitches (the paper's vnode-5 incident, where
//! SLURM briefly saw a healthy node as *off* and CLUES power-cycled it).

use crate::sim::SimTime;
use crate::util::prng::Prng;

/// Stochastic failure knobs for a site.
#[derive(Debug, Clone)]
pub struct FailureModel {
    /// Probability a VM request never reaches Running.
    pub boot_failure_prob: f64,
    /// Poisson rate of a running VM crashing, events per VM-hour.
    pub crash_rate_per_hour: f64,
    /// Probability that a *monitor reading* of a healthy node reports it
    /// down (transient flap), per reading.
    pub transient_down_prob: f64,
    /// Duration of a transient flap, seconds.
    pub transient_down_secs: f64,
    /// Poisson rate of the provider *preempting* a running VM (spot /
    /// opportunistic capacity reclaim), events per VM-hour. This is the
    /// hazard signal the broker's `SpotAware` policy weighs a site by.
    pub preempt_rate_per_hour: f64,
    /// Steady-state probability that one site → control WAN message is
    /// lost (on top of any scripted `WanFaultPlan` windows). Must stay
    /// below 1.0; the chaos layer's retransmissions recover the loss.
    pub message_loss_prob: f64,
    /// Ack timeout seeding the site's retransmission backoff for
    /// dropped reliable messages, seconds.
    pub ack_timeout_s: f64,
}

impl FailureModel {
    /// No failures (default for unit tests).
    pub fn none() -> FailureModel {
        FailureModel {
            boot_failure_prob: 0.0,
            crash_rate_per_hour: 0.0,
            transient_down_prob: 0.0,
            transient_down_secs: 0.0,
            preempt_rate_per_hour: 0.0,
            message_loss_prob: 0.0,
            ack_timeout_s: 120.0,
        }
    }

    /// Mild real-world rates.
    pub fn realistic() -> FailureModel {
        FailureModel {
            boot_failure_prob: 0.01,
            crash_rate_per_hour: 0.002,
            transient_down_prob: 0.002,
            transient_down_secs: 240.0,
            preempt_rate_per_hour: 0.0,
            message_loss_prob: 0.001,
            ack_timeout_s: 120.0,
        }
    }

    pub fn boot_fails(&self, rng: &mut Prng) -> bool {
        self.boot_failure_prob > 0.0 && rng.chance(self.boot_failure_prob)
    }

    /// Sample time-to-crash for a VM entering Running (None = never).
    pub fn sample_crash_in(&self, rng: &mut Prng) -> Option<f64> {
        if self.crash_rate_per_hour <= 0.0 {
            return None;
        }
        Some(rng.exponential(3600.0 / self.crash_rate_per_hour))
    }

    /// Sample time-to-preemption for a VM entering Running (None =
    /// never — the site has no spot reclaim).
    pub fn sample_preempt_in(&self, rng: &mut Prng) -> Option<f64> {
        if self.preempt_rate_per_hour <= 0.0 {
            return None;
        }
        Some(rng.exponential(3600.0 / self.preempt_rate_per_hour))
    }
}

/// A scripted transient-down injection: node `node_name` is reported off
/// by the LRMS monitor during [start, start+duration) even though the VM
/// is healthy. Used to replay the vnode-5 incident deterministically.
#[derive(Debug, Clone)]
pub struct TransientDown {
    pub node_name: String,
    pub start: SimTime,
    pub duration_secs: f64,
}

impl TransientDown {
    pub fn active_at(&self, t: SimTime) -> bool {
        t.0 >= self.start.0 && t.0 < self.start.0 + self.duration_secs
    }
}

/// Deterministic injection plan for a simulation run.
#[derive(Debug, Clone, Default)]
pub struct InjectionPlan {
    pub transient_downs: Vec<TransientDown>,
}

impl InjectionPlan {
    /// Is `node` falsely reported down at time `t`?
    pub fn node_reported_down(&self, node: &str, t: SimTime) -> bool {
        self.transient_downs
            .iter()
            .any(|d| d.node_name == node && d.active_at(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fails() {
        let m = FailureModel::none();
        let mut rng = Prng::new(1);
        for _ in 0..1000 {
            assert!(!m.boot_fails(&mut rng));
        }
        assert!(m.sample_crash_in(&mut rng).is_none());
    }

    #[test]
    fn boot_failure_rate_approximates_probability() {
        let m = FailureModel { boot_failure_prob: 0.2,
                               ..FailureModel::none() };
        let mut rng = Prng::new(2);
        let fails = (0..10_000).filter(|_| m.boot_fails(&mut rng)).count();
        assert!((fails as f64 / 10_000.0 - 0.2).abs() < 0.02, "{fails}");
    }

    #[test]
    fn preempt_sampling_mean_and_default_off() {
        let off = FailureModel::none();
        let mut rng = Prng::new(7);
        assert!(off.sample_preempt_in(&mut rng).is_none());
        let m = FailureModel { preempt_rate_per_hour: 2.0,
                               ..FailureModel::none() };
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| m.sample_preempt_in(&mut rng).unwrap())
            .sum::<f64>() / n as f64;
        assert!((mean - 1800.0).abs() < 60.0, "mean={mean}");
    }

    #[test]
    fn crash_sampling_mean() {
        let m = FailureModel { crash_rate_per_hour: 1.0,
                               ..FailureModel::none() };
        let mut rng = Prng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| m.sample_crash_in(&mut rng).unwrap())
            .sum::<f64>() / n as f64;
        assert!((mean - 3600.0).abs() < 100.0, "mean={mean}");
    }

    #[test]
    fn transient_window() {
        let d = TransientDown {
            node_name: "vnode-5".into(),
            start: SimTime(100.0),
            duration_secs: 60.0,
        };
        assert!(!d.active_at(SimTime(99.9)));
        assert!(d.active_at(SimTime(100.0)));
        assert!(d.active_at(SimTime(159.9)));
        assert!(!d.active_at(SimTime(160.0)));
    }

    #[test]
    fn plan_matches_by_name() {
        let plan = InjectionPlan {
            transient_downs: vec![TransientDown {
                node_name: "vnode-5".into(),
                start: SimTime(10.0),
                duration_secs: 5.0,
            }],
        };
        assert!(plan.node_reported_down("vnode-5", SimTime(12.0)));
        assert!(!plan.node_reported_down("vnode-4", SimTime(12.0)));
        assert!(!plan.node_reported_down("vnode-5", SimTime(20.0)));
    }
}
