//! Virtual-machine lifecycle state machine.

use crate::sim::SimTime;

/// Site-local VM identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VmId(pub u64);

impl std::fmt::Display for VmId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "vm-{}", self.0)
    }
}

/// Lifecycle states. Transitions are enforced by [`Vm::transition`]:
///
/// ```text
/// Requested -> Booting -> Running -> Terminating -> Terminated
///      \           \          \-> Failed
///       \           \-> Failed
///        \-> Failed  (quota race / placement error)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VmState {
    Requested,
    Booting,
    Running,
    Terminating,
    Terminated,
    Failed,
}

impl VmState {
    /// Is the VM incurring cost in this state?
    pub fn billable(self) -> bool {
        matches!(self, VmState::Booting | VmState::Running
                 | VmState::Terminating)
    }

    fn can_go(self, next: VmState) -> bool {
        use VmState::*;
        matches!(
            (self, next),
            (Requested, Booting)
                | (Booting, Running)
                | (Running, Terminating)
                | (Terminating, Terminated)
                | (Requested, Failed)
                | (Booting, Failed)
                | (Running, Failed)
                | (Failed, Terminating) // cleanup of a failed VM
        )
    }
}

/// One simulated VM.
#[derive(Debug, Clone)]
pub struct Vm {
    pub id: VmId,
    /// Deployment-level name, e.g. "vnode-3" or "front-end".
    pub name: String,
    pub instance_type: String,
    pub state: VmState,
    pub requested_at: SimTime,
    /// Billing start (set on Booting — providers bill from launch).
    pub billing_start: Option<SimTime>,
    /// Billing end (set on Terminated / Failed).
    pub billing_end: Option<SimTime>,
    /// Private IP within its site network.
    pub private_ip: Option<u32>,
    /// Public IP if one was allocated.
    pub public_ip: Option<u32>,
    /// Site-local network the VM is attached to.
    pub network: Option<super::network::NetworkId>,
    pub state_log: Vec<(SimTime, VmState)>,
}

impl Vm {
    pub fn new(id: VmId, name: &str, instance_type: &str, t: SimTime) -> Vm {
        Vm {
            id,
            name: name.to_string(),
            instance_type: instance_type.to_string(),
            state: VmState::Requested,
            requested_at: t,
            billing_start: None,
            billing_end: None,
            private_ip: None,
            public_ip: None,
            network: None,
            state_log: vec![(t, VmState::Requested)],
        }
    }

    /// Apply a lifecycle transition, maintaining billing timestamps.
    pub fn transition(&mut self, next: VmState, t: SimTime)
        -> anyhow::Result<()> {
        if !self.state.can_go(next) {
            anyhow::bail!(
                "{}: illegal transition {:?} -> {:?}", self.name, self.state,
                next
            );
        }
        if next == VmState::Booting && self.billing_start.is_none() {
            self.billing_start = Some(t);
        }
        if matches!(next, VmState::Terminated | VmState::Failed)
            && self.billing_end.is_none()
        {
            self.billing_end = Some(t);
        }
        self.state = next;
        self.state_log.push((t, next));
        Ok(())
    }

    /// Billable seconds as of time `t` (or the full period if ended).
    pub fn billable_secs(&self, now: SimTime) -> f64 {
        match self.billing_start {
            None => 0.0,
            Some(s) => {
                let end = self.billing_end.map(|e| e.0).unwrap_or(now.0);
                (end - s.0).max(0.0)
            }
        }
    }

    pub fn is_alive(&self) -> bool {
        matches!(self.state, VmState::Booting | VmState::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    #[test]
    fn happy_path_lifecycle() {
        let mut vm = Vm::new(VmId(1), "wn1", "t2.medium", t(0.0));
        vm.transition(VmState::Booting, t(1.0)).unwrap();
        vm.transition(VmState::Running, t(120.0)).unwrap();
        vm.transition(VmState::Terminating, t(500.0)).unwrap();
        vm.transition(VmState::Terminated, t(530.0)).unwrap();
        assert_eq!(vm.billable_secs(t(1000.0)), 529.0);
        assert_eq!(vm.state_log.len(), 5);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut vm = Vm::new(VmId(1), "x", "t", t(0.0));
        assert!(vm.transition(VmState::Running, t(1.0)).is_err());
        vm.transition(VmState::Booting, t(1.0)).unwrap();
        assert!(vm.transition(VmState::Terminated, t(2.0)).is_err());
        assert!(vm.transition(VmState::Requested, t(2.0)).is_err());
    }

    #[test]
    fn failure_ends_billing() {
        let mut vm = Vm::new(VmId(2), "y", "t", t(0.0));
        vm.transition(VmState::Booting, t(10.0)).unwrap();
        vm.transition(VmState::Running, t(100.0)).unwrap();
        vm.transition(VmState::Failed, t(200.0)).unwrap();
        assert_eq!(vm.billable_secs(t(999.0)), 190.0);
        assert!(!vm.is_alive());
        // Failed VMs can still be cleaned up.
        vm.transition(VmState::Terminating, t(210.0)).unwrap();
    }

    #[test]
    fn ongoing_billing_tracks_now() {
        let mut vm = Vm::new(VmId(3), "z", "t", t(0.0));
        vm.transition(VmState::Booting, t(5.0)).unwrap();
        assert_eq!(vm.billable_secs(t(65.0)), 60.0);
    }
}
