//! Billing: instance-type price books and per-deployment cost ledgers.
//!
//! AWS-style sites bill per second (the paper picked t2.medium precisely
//! because it is "billed by the second"); OpenStack research clouds are
//! modelled as zero-cost (grant-funded) but still tracked in VM-hours.

use std::collections::HashMap;

use crate::sim::SimTime;

/// Billing granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    PerSecond,
    /// Rounded up to whole hours per billing period.
    PerHour,
}

/// Price entry for one instance type.
#[derive(Debug, Clone)]
pub struct Price {
    pub usd_per_hour: f64,
    pub granularity: Granularity,
}

impl Price {
    pub fn free() -> Price {
        Price { usd_per_hour: 0.0, granularity: Granularity::PerHour }
    }

    /// Cost of a billable period of `secs` seconds.
    pub fn cost(&self, secs: f64) -> f64 {
        if secs <= 0.0 {
            return 0.0;
        }
        match self.granularity {
            Granularity::PerSecond => self.usd_per_hour * secs / 3600.0,
            Granularity::PerHour => {
                self.usd_per_hour * (secs / 3600.0).ceil()
            }
        }
    }
}

/// One finished (or ongoing) billable VM period.
#[derive(Debug, Clone)]
pub struct LedgerEntry {
    pub vm_name: String,
    pub instance_type: String,
    pub start: SimTime,
    pub end: Option<SimTime>,
    pub usd_per_hour: f64,
    pub granularity: Granularity,
}

impl LedgerEntry {
    pub fn secs(&self, now: SimTime) -> f64 {
        let end = self.end.map(|e| e.0).unwrap_or(now.0);
        (end - self.start.0).max(0.0)
    }

    pub fn cost(&self, now: SimTime) -> f64 {
        Price {
            usd_per_hour: self.usd_per_hour,
            granularity: self.granularity,
        }
        .cost(self.secs(now))
    }
}

/// Site-level cost ledger.
///
/// Open entries are indexed by VM name, so closing one — the hot
/// operation during a spot-preemption wave, where one event closes many
/// VMs — is O(1) instead of a reverse scan over the whole history.
/// `entries` stays public read-only history; mutate it only through
/// [`Ledger::open`]/[`Ledger::close`] or the index desynchronizes.
#[derive(Debug, Default)]
pub struct Ledger {
    pub entries: Vec<LedgerEntry>,
    /// vm name → indexes of open entries (stack; most recent last).
    open_by_name: HashMap<String, Vec<usize>>,
    /// Sum of `usd_per_hour` across open entries (live burn rate).
    open_rate: f64,
}

impl Ledger {
    pub fn open(&mut self, vm_name: &str, instance_type: &str, price: &Price,
                start: SimTime) {
        let idx = self.entries.len();
        self.entries.push(LedgerEntry {
            vm_name: vm_name.to_string(),
            instance_type: instance_type.to_string(),
            start,
            end: None,
            usd_per_hour: price.usd_per_hour,
            granularity: price.granularity,
        });
        self.open_by_name
            .entry(vm_name.to_string())
            .or_default()
            .push(idx);
        self.open_rate += price.usd_per_hour;
    }

    /// Close the most recent open entry for `vm_name` (no-op if none).
    /// O(1): the open-entry index replaces the old reverse scan.
    pub fn close(&mut self, vm_name: &str, end: SimTime) {
        let Some(stack) = self.open_by_name.get_mut(vm_name) else {
            return;
        };
        let Some(idx) = stack.pop() else { return };
        if stack.is_empty() {
            self.open_by_name.remove(vm_name);
        }
        let e = &mut self.entries[idx];
        e.end = Some(end);
        self.open_rate -= e.usd_per_hour;
    }

    /// $/hour currently burning across all open entries — the live
    /// cost-rate signal the elasticity broker consumes per site.
    pub fn open_rate_usd_per_hour(&self) -> f64 {
        self.open_rate
    }

    /// Number of currently open (still billing) entries.
    pub fn open_count(&self) -> usize {
        self.open_by_name.values().map(|v| v.len()).sum()
    }

    pub fn total_cost(&self, now: SimTime) -> f64 {
        self.entries.iter().map(|e| e.cost(now)).sum()
    }

    pub fn total_vm_hours(&self, now: SimTime) -> f64 {
        self.entries.iter().map(|e| e.secs(now)).sum::<f64>() / 3600.0
    }

    /// Per-VM (name, hours, cost) rows for the cost table bench.
    pub fn per_vm(&self, now: SimTime) -> Vec<(String, f64, f64)> {
        self.entries
            .iter()
            .map(|e| (e.vm_name.clone(), e.secs(now) / 3600.0, e.cost(now)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_second_billing() {
        let p = Price { usd_per_hour: 0.0464,
                        granularity: Granularity::PerSecond };
        // t2.medium for 90 minutes
        let c = p.cost(5400.0);
        assert!((c - 0.0696).abs() < 1e-9, "{c}");
    }

    #[test]
    fn per_hour_rounds_up() {
        let p = Price { usd_per_hour: 1.0,
                        granularity: Granularity::PerHour };
        assert_eq!(p.cost(1.0), 1.0);
        assert_eq!(p.cost(3600.0), 1.0);
        assert_eq!(p.cost(3601.0), 2.0);
        assert_eq!(p.cost(0.0), 0.0);
    }

    #[test]
    fn ledger_open_close_totals() {
        let mut l = Ledger::default();
        let p = Price { usd_per_hour: 0.0464,
                        granularity: Granularity::PerSecond };
        l.open("vnode-3", "t2.medium", &p, SimTime(0.0));
        l.open("vnode-4", "t2.medium", &p, SimTime(100.0));
        l.close("vnode-3", SimTime(3600.0));
        let now = SimTime(3700.0);
        assert!((l.total_vm_hours(now) - (3600.0 + 3600.0) / 3600.0).abs()
                < 1e-9);
        let per_vm = l.per_vm(now);
        assert_eq!(per_vm.len(), 2);
        assert_eq!(per_vm[0].0, "vnode-3");
    }

    #[test]
    fn close_unknown_is_noop() {
        let mut l = Ledger::default();
        l.close("ghost", SimTime(1.0));
        assert_eq!(l.entries.len(), 0);
        assert_eq!(l.open_count(), 0);
        assert_eq!(l.open_rate_usd_per_hour(), 0.0);
    }

    #[test]
    fn open_index_survives_name_reuse_and_tracks_rate() {
        // vnode names are reused across incarnations; each close must
        // hit the most recent open entry, exactly like the old reverse
        // scan did.
        let mut l = Ledger::default();
        let p1 = Price { usd_per_hour: 1.0,
                         granularity: Granularity::PerSecond };
        let p2 = Price { usd_per_hour: 2.0,
                         granularity: Granularity::PerSecond };
        l.open("vnode-5", "t2.medium", &p1, SimTime(0.0));
        l.close("vnode-5", SimTime(100.0));
        l.open("vnode-5", "t2.medium", &p2, SimTime(200.0));
        assert_eq!(l.open_count(), 1);
        assert!((l.open_rate_usd_per_hour() - 2.0).abs() < 1e-12);
        // Double-open (pathological but allowed): close pops LIFO.
        l.open("vnode-5", "t2.medium", &p1, SimTime(300.0));
        assert_eq!(l.open_count(), 2);
        l.close("vnode-5", SimTime(400.0));
        assert_eq!(l.entries[2].end, Some(SimTime(400.0)));
        assert_eq!(l.entries[1].end, None);
        l.close("vnode-5", SimTime(500.0));
        assert_eq!(l.entries[1].end, Some(SimTime(500.0)));
        assert_eq!(l.open_count(), 0);
        assert!(l.open_rate_usd_per_hour().abs() < 1e-12);
        // Everything closed: a further close is a no-op.
        l.close("vnode-5", SimTime(600.0));
        assert_eq!(l.entries.len(), 3);
    }

    #[test]
    fn paper_cost_shape() {
        // ~14.7 VM-hours of t2.medium + 6 h of a t2.micro vRouter ≈ $0.75
        let med = Price { usd_per_hour: 0.0464,
                          granularity: Granularity::PerSecond };
        let micro = Price { usd_per_hour: 0.0116,
                            granularity: Granularity::PerSecond };
        let wn_secs = (5.0 * 3600.0 + 31.0 * 60.0)
            + (4.0 * 3600.0 + 45.0 * 60.0)
            + (4.0 * 3600.0 + 25.0 * 60.0);
        let total = med.cost(wn_secs) + micro.cost(6.0 * 3600.0);
        assert!((total - 0.75).abs() < 0.03, "total={total}");
    }
}
