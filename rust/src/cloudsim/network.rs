//! Site-local virtual networking: private L2 networks with DHCP-style
//! address allocation and a (scarce) public IPv4 pool.
//!
//! The paper emphasises minimising public-IPv4 usage (challenge iv in §1):
//! only the front-end / vRouter CP needs one. The pool here enforces that
//! scarcity so benches can show deployments fail when over-requesting.

use std::collections::HashMap;

use anyhow::bail;

/// Site-local private network identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetworkId(pub u64);

/// Render an IPv4 address stored as u32.
pub fn ip_to_string(ip: u32) -> String {
    format!(
        "{}.{}.{}.{}",
        (ip >> 24) & 0xFF,
        (ip >> 16) & 0xFF,
        (ip >> 8) & 0xFF,
        ip & 0xFF
    )
}

/// A user-created private L2 network (one per deployment per site).
#[derive(Debug, Clone)]
pub struct PrivateNetwork {
    pub id: NetworkId,
    pub name: String,
    /// Network base address (e.g. 10.e.d.0 for a /24).
    pub cidr_base: u32,
    pub prefix_len: u8,
    next_host: u32,
    allocated: Vec<u32>,
}

impl PrivateNetwork {
    pub fn new(id: NetworkId, name: &str, cidr_base: u32, prefix_len: u8)
        -> PrivateNetwork {
        PrivateNetwork {
            id,
            name: name.to_string(),
            cidr_base,
            prefix_len,
            // .0 is the network address, .1 is reserved for the gateway
            // (the vRouter / front-end per the paper's Figure 1).
            next_host: 2,
            allocated: Vec::new(),
        }
    }

    pub fn capacity(&self) -> u32 {
        (1u32 << (32 - self.prefix_len)) - 3 // network, gateway, broadcast
    }

    /// The gateway address (held by the local vRouter or the FE).
    pub fn gateway_ip(&self) -> u32 {
        self.cidr_base + 1
    }

    /// DHCP-style allocation of the next free host address.
    pub fn allocate(&mut self) -> anyhow::Result<u32> {
        if self.allocated.len() as u32 >= self.capacity() {
            bail!("network {} exhausted ({} hosts)", self.name,
                  self.capacity());
        }
        let ip = self.cidr_base + self.next_host;
        self.next_host += 1;
        self.allocated.push(ip);
        Ok(ip)
    }

    pub fn release(&mut self, ip: u32) {
        self.allocated.retain(|&a| a != ip);
    }

    pub fn allocated_count(&self) -> usize {
        self.allocated.len()
    }

    pub fn cidr(&self) -> String {
        format!("{}/{}", ip_to_string(self.cidr_base), self.prefix_len)
    }

    pub fn contains(&self, ip: u32) -> bool {
        let mask = !0u32 << (32 - self.prefix_len);
        (ip & mask) == self.cidr_base
    }
}

/// Finite pool of public IPv4 addresses (floating IPs).
#[derive(Debug, Clone)]
pub struct PublicIpPool {
    base: u32,
    quota: usize,
    in_use: Vec<u32>,
    next: u32,
}

impl PublicIpPool {
    pub fn new(base: u32, quota: usize) -> PublicIpPool {
        PublicIpPool { base, quota, in_use: Vec::new(), next: 0 }
    }

    pub fn allocate(&mut self) -> anyhow::Result<u32> {
        if self.in_use.len() >= self.quota {
            bail!("public IPv4 quota exhausted ({} in use)", self.quota);
        }
        let ip = self.base + self.next;
        self.next += 1;
        self.in_use.push(ip);
        Ok(ip)
    }

    pub fn release(&mut self, ip: u32) {
        self.in_use.retain(|&a| a != ip);
    }

    pub fn available(&self) -> usize {
        self.quota - self.in_use.len()
    }

    pub fn in_use(&self) -> usize {
        self.in_use.len()
    }
}

/// Manager for all networks in a site; hands out non-overlapping /24s
/// from 10.X.0.0/16 where X is the site index (so subnets are unique
/// across the whole hybrid deployment, as the vRouter CP requires when
/// assigning ranges to clients).
#[derive(Debug)]
pub struct NetworkManager {
    site_index: u8,
    networks: HashMap<NetworkId, PrivateNetwork>,
    next_id: u64,
    next_subnet: u8,
    pub public_pool: PublicIpPool,
}

impl NetworkManager {
    pub fn new(site_index: u8, public_ip_quota: usize) -> NetworkManager {
        // Public pool base: 198.51.N.0 (TEST-NET-2) per site.
        let pub_base = (198u32 << 24) | (51 << 16) | ((site_index as u32) << 8);
        NetworkManager {
            site_index,
            networks: HashMap::new(),
            next_id: 0,
            next_subnet: 0,
            public_pool: PublicIpPool::new(pub_base, public_ip_quota),
        }
    }

    /// Create a fresh private /24.
    pub fn create_network(&mut self, name: &str)
        -> anyhow::Result<NetworkId> {
        if self.next_subnet == 255 {
            bail!("site {}: subnet space exhausted", self.site_index);
        }
        let id = NetworkId(self.next_id);
        self.next_id += 1;
        let base = (10u32 << 24)
            | ((self.site_index as u32) << 16)
            | ((self.next_subnet as u32) << 8);
        self.next_subnet += 1;
        self.networks.insert(id, PrivateNetwork::new(id, name, base, 24));
        Ok(id)
    }

    pub fn get(&self, id: NetworkId) -> Option<&PrivateNetwork> {
        self.networks.get(&id)
    }

    pub fn get_mut(&mut self, id: NetworkId) -> Option<&mut PrivateNetwork> {
        self.networks.get_mut(&id)
    }

    pub fn delete_network(&mut self, id: NetworkId) -> anyhow::Result<()> {
        match self.networks.get(&id) {
            None => bail!("no such network {id:?}"),
            Some(n) if n.allocated_count() > 0 => {
                bail!("network {} still has {} attached addresses",
                      n.name, n.allocated_count())
            }
            Some(_) => {
                self.networks.remove(&id);
                Ok(())
            }
        }
    }

    pub fn count(&self) -> usize {
        self.networks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ip_rendering() {
        assert_eq!(ip_to_string((10 << 24) | (1 << 16) | (2 << 8) | 3),
                   "10.1.2.3");
    }

    #[test]
    fn private_network_allocation() {
        let mut n = PrivateNetwork::new(NetworkId(0), "net0",
                                        (10 << 24) | (1 << 16), 24);
        assert_eq!(n.cidr(), "10.1.0.0/24");
        assert_eq!(ip_to_string(n.gateway_ip()), "10.1.0.1");
        let a = n.allocate().unwrap();
        let b = n.allocate().unwrap();
        assert_eq!(ip_to_string(a), "10.1.0.2");
        assert_eq!(ip_to_string(b), "10.1.0.3");
        assert!(n.contains(a));
        assert!(!n.contains((10 << 24) | (2 << 16) | 5));
        n.release(a);
        assert_eq!(n.allocated_count(), 1);
    }

    #[test]
    fn network_exhaustion() {
        let mut n = PrivateNetwork::new(NetworkId(0), "tiny",
                                        (10 << 24) | (9 << 16), 30);
        // /30 => 4 addresses - 3 reserved = 1 host
        assert_eq!(n.capacity(), 1);
        n.allocate().unwrap();
        assert!(n.allocate().is_err());
    }

    #[test]
    fn public_pool_quota() {
        let mut p = PublicIpPool::new(198 << 24, 2);
        let a = p.allocate().unwrap();
        let _b = p.allocate().unwrap();
        assert!(p.allocate().is_err());
        assert_eq!(p.available(), 0);
        p.release(a);
        assert_eq!(p.available(), 1);
        p.allocate().unwrap();
    }

    #[test]
    fn manager_hands_out_disjoint_subnets() {
        let mut m = NetworkManager::new(3, 1);
        let a = m.create_network("a").unwrap();
        let b = m.create_network("b").unwrap();
        let na = m.get(a).unwrap().cidr();
        let nb = m.get(b).unwrap().cidr();
        assert_eq!(na, "10.3.0.0/24");
        assert_eq!(nb, "10.3.1.0/24");
        assert_eq!(m.count(), 2);
    }

    #[test]
    fn delete_requires_empty() {
        let mut m = NetworkManager::new(0, 1);
        let id = m.create_network("x").unwrap();
        m.get_mut(id).unwrap().allocate().unwrap();
        assert!(m.delete_network(id).is_err());
        let ip = m.get(id).unwrap().cidr_base + 2;
        m.get_mut(id).unwrap().release(ip);
        m.delete_network(id).unwrap();
        assert_eq!(m.count(), 0);
    }
}
