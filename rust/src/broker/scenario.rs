//! Scripted elasticity scenarios: spot-preemption waves, whole-site
//! outages and price spikes.
//!
//! A [`ScenarioPlan`] is a deterministic list of timed events, with
//! times **relative to the workload t0** (the moment the initial
//! cluster is up) — the same convention as
//! [`crate::cloudsim::InjectionPlan`]. The cluster world maps each
//! entry onto site-sharded simulation events at `begin_workload`, so
//! scenario traffic replays under the sharded engine's deterministic
//! `(time, shard, seq)` merge and two runs of the same plan produce
//! byte-identical recorder output.

use crate::sim::SimTime;

/// One scripted scenario event.
#[derive(Debug, Clone)]
pub enum ScenarioEvent {
    /// Spot capacity reclaim at `site`: up to `count` running workers
    /// are preempted (0 = every running worker there). Their jobs
    /// requeue and the run report tracks how many recover.
    SpotWave { site: usize, at: SimTime, count: u32 },
    /// Whole-site outage: every non-front-end VM at `site` dies and the
    /// broker refuses the site until the window closes.
    SiteOutage { site: usize, at: SimTime, duration_secs: f64 },
    /// Price spike: VMs launched at `site` during the window bill at
    /// `factor` × list price (already-running VMs keep their rate).
    PriceSpike { site: usize, at: SimTime, duration_secs: f64,
                 factor: f64 },
    /// WAN partition: the control plane loses contact with `site` for
    /// the window. VMs there keep running, but every report and command
    /// crossing the boundary is dropped, the site's vRouter goes down
    /// on the overlay, and the broker avoids the site while it lasts.
    /// Unlike `SiteOutage`, nothing dies — recovery is a matter of the
    /// control plane's retransmissions and circuit breaker.
    WanPartition { site: usize, at: SimTime, duration_secs: f64 },
    /// Correlated regional outage: one backbone failure partitions
    /// every listed site at once for the window. Semantically a
    /// [`ScenarioEvent::WanPartition`] per member site sharing one
    /// clock — the cluster world resolves it exactly that way, so
    /// cross-engine byte-identity is untouched by the correlation.
    RegionalOutage { sites: Vec<usize>, at: SimTime,
                     duration_secs: f64 },
}

impl ScenarioEvent {
    /// Every site the event targets (a single-element slice for the
    /// per-site variants).
    pub fn target_sites(&self) -> &[usize] {
        match self {
            ScenarioEvent::SpotWave { site, .. }
            | ScenarioEvent::SiteOutage { site, .. }
            | ScenarioEvent::PriceSpike { site, .. }
            | ScenarioEvent::WanPartition { site, .. } => {
                std::slice::from_ref(site)
            }
            ScenarioEvent::RegionalOutage { sites, .. } => sites,
        }
    }
}

/// A deterministic scenario: timed events relative to workload t0.
#[derive(Debug, Clone, Default)]
pub struct ScenarioPlan {
    pub events: Vec<ScenarioEvent>,
}

impl ScenarioPlan {
    pub fn new() -> ScenarioPlan {
        ScenarioPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder: preempt up to `count` workers (0 = all) at `site`,
    /// `at_secs` after workload t0.
    pub fn spot_wave(mut self, site: usize, at_secs: f64, count: u32)
        -> ScenarioPlan {
        self.events.push(ScenarioEvent::SpotWave {
            site,
            at: SimTime(at_secs),
            count,
        });
        self
    }

    /// Builder: take `site` dark for `duration_secs`, starting
    /// `at_secs` after workload t0.
    pub fn site_outage(mut self, site: usize, at_secs: f64,
                       duration_secs: f64) -> ScenarioPlan {
        self.events.push(ScenarioEvent::SiteOutage {
            site,
            at: SimTime(at_secs),
            duration_secs,
        });
        self
    }

    /// Builder: multiply `site`'s launch prices by `factor` for
    /// `duration_secs`, starting `at_secs` after workload t0.
    pub fn price_spike(mut self, site: usize, at_secs: f64,
                       duration_secs: f64, factor: f64) -> ScenarioPlan {
        self.events.push(ScenarioEvent::PriceSpike {
            site,
            at: SimTime(at_secs),
            duration_secs,
            factor,
        });
        self
    }

    /// Builder: cut `site` off from the control plane for
    /// `duration_secs`, starting `at_secs` after workload t0.
    pub fn wan_partition(mut self, site: usize, at_secs: f64,
                         duration_secs: f64) -> ScenarioPlan {
        self.events.push(ScenarioEvent::WanPartition {
            site,
            at: SimTime(at_secs),
            duration_secs,
        });
        self
    }

    /// Builder: one regional backbone failure cuts every listed site
    /// off from the control plane for `duration_secs`, starting
    /// `at_secs` after workload t0.
    pub fn regional_outage(mut self, sites: &[usize], at_secs: f64,
                           duration_secs: f64) -> ScenarioPlan {
        self.events.push(ScenarioEvent::RegionalOutage {
            sites: sites.to_vec(),
            at: SimTime(at_secs),
            duration_secs,
        });
        self
    }

    /// Build-time sanity: every event must target existing sites with
    /// finite, non-negative timing, and regional outages must list at
    /// least one distinct site. Front-end targeting of WAN partitions
    /// (regional or not) is checked later, once the front end is
    /// placed.
    pub fn validate(&self, n_sites: usize) -> anyhow::Result<()> {
        for (i, ev) in self.events.iter().enumerate() {
            let targets = ev.target_sites();
            for (j, &s) in targets.iter().enumerate() {
                if s >= n_sites {
                    anyhow::bail!(
                        "scenario event {i} targets site {s} but the \
                         world has only {n_sites} sites");
                }
                if targets[..j].contains(&s) {
                    anyhow::bail!(
                        "scenario event {i}: regional outage lists site \
                         {s} twice");
                }
            }
            let (at, duration) = match ev {
                ScenarioEvent::SpotWave { at, .. } => (at.0, 0.0),
                ScenarioEvent::SiteOutage { at, duration_secs, .. }
                | ScenarioEvent::WanPartition { at, duration_secs, .. }
                | ScenarioEvent::RegionalOutage { at, duration_secs, .. }
                => (at.0, *duration_secs),
                ScenarioEvent::PriceSpike { at, duration_secs, factor, .. }
                => {
                    if !factor.is_finite() || *factor <= 0.0 {
                        anyhow::bail!("scenario event {i}: price factor \
                                       {factor} must be finite and \
                                       positive");
                    }
                    (at.0, *duration_secs)
                }
            };
            if let ScenarioEvent::RegionalOutage { sites, .. } = ev {
                if sites.is_empty() {
                    anyhow::bail!("scenario event {i}: regional outage \
                                   lists no member sites");
                }
            }
            if !at.is_finite() || at < 0.0 {
                anyhow::bail!("scenario event {i}: start {at} must be a \
                               finite non-negative offset");
            }
            if !duration.is_finite() || duration < 0.0 {
                anyhow::bail!("scenario event {i}: duration {duration} \
                               must be finite and non-negative");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_accumulate_in_order() {
        let plan = ScenarioPlan::new()
            .spot_wave(1, 600.0, 0)
            .site_outage(2, 1200.0, 900.0)
            .price_spike(1, 300.0, 600.0, 4.0);
        assert_eq!(plan.events.len(), 3);
        assert!(!plan.is_empty());
        assert_eq!(plan.events[0].target_sites(), &[1]);
        assert_eq!(plan.events[1].target_sites(), &[2]);
        match &plan.events[2] {
            ScenarioEvent::PriceSpike { site, at, duration_secs, factor }
            => {
                assert_eq!(*site, 1);
                assert_eq!(at.0, 300.0);
                assert_eq!(*duration_secs, 600.0);
                assert_eq!(*factor, 4.0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(ScenarioPlan::new().is_empty());
    }

    #[test]
    fn wan_partition_builder_and_validation() {
        let plan = ScenarioPlan::new().wan_partition(2, 900.0, 600.0);
        assert_eq!(plan.events[0].target_sites(), &[2]);
        assert!(plan.validate(3).is_ok());
        // Out-of-range site, negative start, infinite duration and a
        // non-positive price factor are all rejected with clear errors.
        assert!(plan.validate(2).is_err());
        assert!(ScenarioPlan::new()
            .spot_wave(0, -1.0, 0)
            .validate(1)
            .is_err());
        assert!(ScenarioPlan::new()
            .site_outage(0, 10.0, f64::INFINITY)
            .validate(1)
            .is_err());
        assert!(ScenarioPlan::new()
            .price_spike(0, 10.0, 60.0, 0.0)
            .validate(1)
            .is_err());
    }

    #[test]
    fn regional_outage_builder_and_validation() {
        let plan = ScenarioPlan::new().regional_outage(&[1, 2], 900.0,
                                                       600.0);
        assert_eq!(plan.events[0].target_sites(), &[1, 2]);
        assert!(plan.validate(3).is_ok());
        // Any out-of-range member fails the whole plan.
        assert!(plan.validate(2).is_err());
        // Empty and duplicate member lists are plan bugs.
        assert!(ScenarioPlan::new()
            .regional_outage(&[], 0.0, 60.0)
            .validate(3)
            .is_err());
        assert!(ScenarioPlan::new()
            .regional_outage(&[1, 1], 0.0, 60.0)
            .validate(3)
            .is_err());
        assert!(ScenarioPlan::new()
            .regional_outage(&[1], -1.0, 60.0)
            .validate(3)
            .is_err());
        assert!(ScenarioPlan::new()
            .regional_outage(&[1], 0.0, f64::INFINITY)
            .validate(3)
            .is_err());
    }
}
