//! Multi-site elasticity broker: the grow/shrink-to-*which-site*
//! decision behind CLUES power-on requests.
//!
//! The legacy path (`orchestrator::select_site`) was a single static
//! SLA-rank sweep that re-cloned site names on every call and ignored
//! every live economic signal the simulator already tracks. The broker
//! owns that decision instead:
//!
//! * **Identity** — site names are interned once into dense
//!   [`SiteId`]s (mirroring the node interner of [`crate::ids`]); the
//!   immutable [`SiteTable`] pre-resolves SLAs, name tie-break ranks,
//!   worker price points and preemption hazards, so a placement
//!   decision hashes and clones no `String`s.
//! * **Signals** — each decision samples live [`SiteSignals`] per site:
//!   quota headroom, availability (the spec's monitored baseline,
//!   forced to 0 while a scenario outage is active; wiring the live
//!   [`crate::orchestrator::Monitor`] window in is future work), the
//!   ledger's open $/hour burn rate, the LRMS queue depth, WAN latency
//!   from the front-end through the vRouter overlay, and the site's
//!   spot-preemption hazard.
//! * **Policy** — a pluggable [`PlacementPolicy`] ranks the eligible
//!   sites. [`SlaRank`] reproduces the legacy selector exactly
//!   (property-proven in `tests/broker_policies.rs`); [`CostMin`],
//!   [`LatencyMin`] and [`SpotAware`] trade cost, distance and
//!   preemption risk. Eligibility itself (availability floor, SLA
//!   zero-instance exclusion, VM/vCPU quota, SLA headroom) is shared by
//!   every policy and identical to the legacy checks.
//! * **Scenarios** — [`scenario::ScenarioPlan`] scripts spot-preemption
//!   waves, whole-site outages, price spikes and WAN partitions; the
//!   cluster world replays them as control-plane events (reclaims touch
//!   the LRMS and broker, and the control plane owns cross-site
//!   effects), so scenario runs stay byte-identical across the serial
//!   and parallel engines of [`crate::sim::shard`].
//! * **Quarantine** — the control plane's circuit breaker (see
//!   `cluster::faults`) marks a silent site quarantined via
//!   [`ElasticityBroker::set_quarantine`]; the broker then treats it
//!   exactly like an outage (availability forced to 0) until the
//!   breaker closes. The flag is separate from the scenario outage
//!   flag so an `OutageEnd` event cannot clear an active quarantine.
//! * **Health** — the control plane distills each site's live chaos
//!   telemetry (retransmission rate, provisioning retries, recent
//!   quarantine time) into an exponentially-decayed health score in
//!   `[0, 1]` and publishes it via
//!   [`ElasticityBroker::set_health`]; [`SiteSignals::health`] carries
//!   it to the policies. [`policy::HealthAware`] demotes degrading
//!   sites by whole SLA-priority steps *before* the breaker trips;
//!   the score defaults to exactly 1.0 (and every health penalty to
//!   exactly 0.0), so fault-free decisions are bit-identical to the
//!   health-blind policies.
//!
//! * **Workload routing** — under partitioned dispatch (see
//!   `cluster::dispatch`) the broker also ranks sites for *job-block*
//!   routing via [`ElasticityBroker::route_candidates`]: the same
//!   policy scoring and the same availability gate (outages and
//!   quarantines force availability to 0), but without the
//!   VM-provisioning eligibility checks — routing places queue blocks
//!   on capacity that already exists.
//!
//! The front-end placement always uses the SLA ranking (the front end
//! is the cluster's fixed point — the paper deploys it at the home
//! site); the configured policy governs the elastic workers.

pub mod policy;
pub mod scenario;

pub use policy::{CostMin, HealthAware, LatencyMin, PlacementPolicy,
                 PolicyKind, Score, SlaRank, SpotAware};
pub use scenario::{ScenarioEvent, ScenarioPlan};

use crate::cloudsim::CloudSite;
use crate::ids::{SiteId, SiteNames};
use crate::netsim::Network;
use crate::orchestrator::sla::{ResolvedSlas, Sla, MIN_AVAILABILITY};
use crate::sim::SimTime;

/// Immutable per-site facts, resolved once at construction. Policies
/// read it through accessors; nothing here allocates per decision.
pub struct SiteTable {
    names: SiteNames,
    slas: ResolvedSlas,
    /// Interned id of each site, indexed by site-vector position.
    /// Usually `site_ids[i] == SiteId(i)`; sites sharing a name share
    /// an id (and therefore an SLA), exactly like the legacy by-name
    /// lookup.
    site_ids: Vec<SiteId>,
    /// Rank of each site's name in ascending order — the deterministic
    /// final tie-break, precomputed so ranking never compares strings.
    name_ranks: Vec<u32>,
    /// $/hour of the instance type the cluster would provision for one
    /// worker at each site (the smallest type satisfying the template).
    worker_prices: Vec<f64>,
    /// Spot-preemption hazard (events per VM-hour) per site.
    hazards: Vec<f64>,
    /// One-way WAN latency from the front-end site (0 until the FE is
    /// placed, then 0 for the FE site itself).
    latency_from_fe: Vec<f64>,
}

impl SiteTable {
    pub fn sla_priority(&self, site: usize) -> Option<u32> {
        self.slas.get(self.site_ids[site]).map(|(p, _)| p)
    }

    pub fn name_rank(&self, site: usize) -> u32 {
        self.name_ranks[site]
    }

    pub fn worker_price(&self, site: usize) -> f64 {
        self.worker_prices[site]
    }

    pub fn hazard(&self, site: usize) -> f64 {
        self.hazards[site]
    }

    pub fn latency_from_fe(&self, site: usize) -> f64 {
        self.latency_from_fe[site]
    }

    /// Interner handle (ids are issued in site-vector order).
    pub fn names(&self) -> SiteNames {
        self.names.clone()
    }
}

/// Live signals for one site, sampled at decision time. `Copy`, id
/// indexed, no `String`s — the per-call cost of a decision is a sweep
/// of plain arithmetic over the site vector.
#[derive(Debug, Clone, Copy)]
pub struct SiteSignals {
    /// Site availability: the spec's monitored baseline, 0.0 while a
    /// scenario outage is active.
    pub availability: f64,
    /// VM quota headroom.
    pub free_vms: u32,
    /// vCPU quota headroom.
    pub free_vcpus: u32,
    /// Instances the SLA still allows (None = no SLA ceiling).
    pub sla_headroom: Option<u32>,
    /// Worker $/hour at this site right now (list × price factor).
    pub effective_price: f64,
    /// $/hour currently burning in the site's ledger (open entries).
    pub cost_rate: f64,
    /// One-way WAN latency from the front-end site, seconds.
    pub latency_to_fe: f64,
    /// Spot-preemption hazard, events per VM-hour.
    pub hazard_per_hour: f64,
    /// LRMS pending-queue depth at decision time (cluster-wide).
    pub queue_depth: u32,
    /// A scenario outage is in effect.
    pub outage: bool,
    /// The control plane's circuit breaker has the site quarantined.
    pub quarantined: bool,
    /// Exponentially-decayed health score in `[0, 1]` distilled by the
    /// control plane from the site's chaos telemetry (retransmission
    /// rate, provisioning retries, recent quarantine time). Exactly
    /// 1.0 when the site is healthy or chaos is disabled.
    pub health: f64,
}

/// The elasticity broker.
pub struct ElasticityBroker {
    table: SiteTable,
    policy: Box<dyn PlacementPolicy>,
    /// Scenario state: outage flag per site.
    outage: Vec<bool>,
    /// Circuit-breaker state: quarantine flag per site. Kept separate
    /// from `outage` so scenario `OutageEnd` events cannot clear an
    /// active quarantine (and vice versa).
    quarantine: Vec<bool>,
    /// Health score per site, published by the control plane's
    /// telemetry distiller; 1.0 (exactly) until told otherwise.
    health: Vec<f64>,
    /// Decision log for reports: (t, chosen site).
    pub decisions: Vec<(SimTime, usize)>,
}

impl ElasticityBroker {
    /// Build the broker for a fixed site vector. Site names are
    /// interned in vector order (duplicated names share an id — and
    /// therefore an SLA — exactly like the legacy by-name lookup).
    /// `worker_cpus`/`worker_mem_gb` come from the cluster template and
    /// determine each site's worker price point.
    pub fn new<S: AsRef<CloudSite>>(kind: PolicyKind, sites: &[S],
                                    slas: &[Sla], worker_cpus: u32,
                                    worker_mem_gb: f64)
        -> ElasticityBroker {
        let names = SiteNames::new();
        let site_ids: Vec<SiteId> = sites
            .iter()
            .map(|s| names.intern(&s.as_ref().spec.name))
            .collect();
        let resolved = ResolvedSlas::resolve(slas, &names);
        let mut order: Vec<usize> = (0..sites.len()).collect();
        order.sort_by(|&a, &b| {
            sites[a].as_ref().spec.name.cmp(&sites[b].as_ref().spec.name)
        });
        let mut name_ranks = vec![0u32; sites.len()];
        for (r, &i) in order.iter().enumerate() {
            name_ranks[i] = r as u32;
        }
        let worker_prices = sites
            .iter()
            .map(|s| {
                // The same selector the cluster provisions through, so
                // the ranked price is the billed price.
                s.as_ref()
                    .spec
                    .worker_instance_type(worker_cpus, worker_mem_gb)
                    .price
                    .usd_per_hour
            })
            .collect();
        let hazards = sites
            .iter()
            .map(|s| s.as_ref().spec.failure.preempt_rate_per_hour)
            .collect();
        ElasticityBroker {
            table: SiteTable {
                names,
                slas: resolved,
                site_ids,
                name_ranks,
                worker_prices,
                hazards,
                latency_from_fe: vec![0.0; sites.len()],
            },
            policy: kind.build(),
            outage: vec![false; sites.len()],
            quarantine: vec![false; sites.len()],
            health: vec![1.0; sites.len()],
            decisions: Vec::new(),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn table(&self) -> &SiteTable {
        &self.table
    }

    /// The front end has been placed: resolve WAN latencies from its
    /// site through the underlay (the overlay's site-router hop rides
    /// exactly this link).
    pub fn set_front_end<S: AsRef<CloudSite>>(&mut self, fe_site: usize,
                                              net: &Network, sites: &[S]) {
        for i in 0..sites.len() {
            self.table.latency_from_fe[i] = if i == fe_site {
                0.0
            } else {
                net.link(sites[fe_site].as_ref().net_id,
                         sites[i].as_ref().net_id)
                    .map(|l| l.latency_s)
                    .unwrap_or(f64::INFINITY)
            };
        }
    }

    /// Scenario hook: mark a whole-site outage on/off.
    pub fn set_outage(&mut self, site: usize, dark: bool) {
        if let Some(o) = self.outage.get_mut(site) {
            *o = dark;
        }
    }

    pub fn outage_active(&self, site: usize) -> bool {
        self.outage.get(site).copied().unwrap_or(false)
    }

    /// Circuit-breaker hook: quarantine a silent site (or lift the
    /// quarantine once the breaker closes). Quarantined sites are
    /// treated like outages — availability forced to 0 — but on a flag
    /// scenario events cannot touch.
    pub fn set_quarantine(&mut self, site: usize, dark: bool) {
        if let Some(q) = self.quarantine.get_mut(site) {
            *q = dark;
        }
    }

    pub fn quarantine_active(&self, site: usize) -> bool {
        self.quarantine.get(site).copied().unwrap_or(false)
    }

    /// Telemetry hook: publish the control plane's health score for a
    /// site (clamped to `[0, 1]`; NaN is treated as fully degraded —
    /// a poisoned score must never *promote* a site).
    pub fn set_health(&mut self, site: usize, score: f64) {
        if let Some(h) = self.health.get_mut(site) {
            *h = if score.is_nan() { 0.0 } else { score.clamp(0.0, 1.0) };
        }
    }

    pub fn health_of(&self, site: usize) -> f64 {
        self.health.get(site).copied().unwrap_or(1.0)
    }

    /// Sample the live signals for one site. The effective price reads
    /// the site's own launch-time price factor, so scenario price
    /// spikes reach the policies through the same state that bills the
    /// ledger — there is no second copy to keep in sync.
    pub fn signals<S: AsRef<CloudSite>>(&self, site: usize, sites: &[S],
                                        used_per_site: &[u32],
                                        queue_depth: u32) -> SiteSignals {
        let s = sites[site].as_ref();
        let outage = self.outage[site];
        let quarantined = self.quarantine[site];
        SiteSignals {
            availability: if outage || quarantined {
                0.0
            } else {
                s.spec.availability
            },
            free_vms: s.spec.quota.max_vms.saturating_sub(s.used_vms())
                as u32,
            free_vcpus: s.spec.quota.max_vcpus
                .saturating_sub(s.used_vcpus()),
            sla_headroom: self.table.slas.headroom(
                self.table.site_ids[site], used_per_site[site]),
            effective_price: self.table.worker_prices[site]
                * s.price_factor(),
            cost_rate: s.ledger.open_rate_usd_per_hour(),
            latency_to_fe: self.table.latency_from_fe[site],
            hazard_per_hour: self.table.hazards[site],
            queue_depth,
            outage,
            quarantined,
            health: self.health[site],
        }
    }

    /// The shared eligibility gate — byte-for-byte the legacy
    /// `select_site` checks (availability floor, zero-instance SLA,
    /// VM/vCPU quota, SLA headroom), plus scenario outages through the
    /// forced-zero availability.
    fn eligible(&self, site: usize, s: &CloudSite, cpus: u32,
                sig: &SiteSignals) -> bool {
        if sig.availability < MIN_AVAILABILITY {
            return false;
        }
        if let Some((_, max)) =
            self.table.slas.get(self.table.site_ids[site])
        {
            if max == Some(0) {
                return false;
            }
        }
        if s.used_vms() + 1 > s.spec.quota.max_vms {
            return false;
        }
        if s.used_vcpus() + cpus > s.spec.quota.max_vcpus {
            return false;
        }
        if sig.sla_headroom == Some(0) {
            return false;
        }
        true
    }

    fn pick<S: AsRef<CloudSite>>(&self, policy: &dyn PlacementPolicy,
                                 sites: &[S], used_per_site: &[u32],
                                 cpus: u32, queue_depth: u32,
                                 excluded: Option<&[bool]>)
        -> Option<usize> {
        let mut best: Option<(Score, usize)> = None;
        for i in 0..sites.len() {
            if excluded
                .map(|e| e.get(i).copied().unwrap_or(false))
                .unwrap_or(false)
            {
                continue;
            }
            let sig = self.signals(i, sites, used_per_site, queue_depth);
            if !self.eligible(i, sites[i].as_ref(), cpus, &sig) {
                continue;
            }
            let score = policy.score(i, &self.table, &sig);
            let replace = match &best {
                Some((b, _)) => score.better_than(b),
                None => true,
            };
            if replace {
                best = Some((score, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Score every eligible site under the configured policy, best
    /// first — the ranked candidate list a broker-decision trace event
    /// is annotated with. Read-only (the decision log is untouched) and
    /// re-scores independently of [`select`](Self::select), so calling
    /// it — the tracing layer only does so when enabled — cannot
    /// perturb any placement. Sorting uses the same lexicographic
    /// order as [`Score::better_than`], so index 0 is exactly what
    /// `select` with the same inputs would pick.
    pub fn ranked_candidates<S: AsRef<CloudSite>>(
        &self, sites: &[S], used_per_site: &[u32], cpus: u32,
        queue_depth: u32, excluded: Option<&[bool]>)
        -> Vec<(usize, Score)> {
        let mut ranked: Vec<(usize, Score)> = Vec::new();
        for i in 0..sites.len() {
            if excluded
                .map(|e| e.get(i).copied().unwrap_or(false))
                .unwrap_or(false)
            {
                continue;
            }
            let sig = self.signals(i, sites, used_per_site, queue_depth);
            if !self.eligible(i, sites[i].as_ref(), cpus, &sig) {
                continue;
            }
            ranked.push((i, self.policy.score(i, &self.table, &sig)));
        }
        ranked.sort_by(|a, b| {
            a.1.primary
                .total_cmp(&b.1.primary)
                .then(a.1.secondary.total_cmp(&b.1.secondary))
                .then(a.1.tiebreak.cmp(&b.1.tiebreak))
        });
        ranked
    }

    /// Rank sites as *job-block routing* targets for the partitioned
    /// dispatcher, best first. Unlike
    /// [`ranked_candidates`](Self::ranked_candidates) — which gates on
    /// the per-VM provisioning limits (VM/vCPU quota, SLA headroom) —
    /// routing a job to capacity a site already has only requires the
    /// site to be reachable: the sole gate is the availability floor,
    /// which folds in scenario outages and circuit-breaker quarantines.
    /// Read-only and deterministic for fixed inputs.
    pub fn route_candidates<S: AsRef<CloudSite>>(
        &self, sites: &[S], used_per_site: &[u32], queue_depth: u32)
        -> Vec<usize> {
        let mut ranked: Vec<(usize, Score)> = Vec::new();
        for i in 0..sites.len() {
            let sig = self.signals(i, sites, used_per_site, queue_depth);
            if sig.availability < MIN_AVAILABILITY {
                continue;
            }
            ranked.push((i, self.policy.score(i, &self.table, &sig)));
        }
        ranked.sort_by(|a, b| {
            a.1.primary
                .total_cmp(&b.1.primary)
                .then(a.1.secondary.total_cmp(&b.1.secondary))
                .then(a.1.tiebreak.cmp(&b.1.tiebreak))
        });
        ranked.into_iter().map(|(i, _)| i).collect()
    }

    /// Pick the site for one new worker under the configured policy.
    pub fn select<S: AsRef<CloudSite>>(&mut self, sites: &[S],
                                       used_per_site: &[u32], cpus: u32,
                                       queue_depth: u32, t: SimTime)
        -> Option<usize> {
        let pick = self.pick(self.policy.as_ref(), sites, used_per_site,
                             cpus, queue_depth, None);
        if let Some(i) = pick {
            self.decisions.push((t, i));
        }
        pick
    }

    /// Like [`select`](Self::select), with an explicit per-site
    /// exclusion mask on top of the shared eligibility gate. Used for
    /// retry failover (skip the site that kept failing) and to avoid
    /// WAN-partitioned sites while the partition lasts.
    pub fn select_excluding<S: AsRef<CloudSite>>(
        &mut self, sites: &[S], used_per_site: &[u32], cpus: u32,
        queue_depth: u32, t: SimTime, excluded: &[bool])
        -> Option<usize> {
        let pick = self.pick(self.policy.as_ref(), sites, used_per_site,
                             cpus, queue_depth, Some(excluded));
        if let Some(i) = pick {
            self.decisions.push((t, i));
        }
        pick
    }

    /// Pick the front-end site. Always SLA-ranked: the front end is the
    /// cluster's fixed point, whatever the elastic-worker policy.
    pub fn select_front_end<S: AsRef<CloudSite>>(&mut self, sites: &[S],
                                                 used_per_site: &[u32],
                                                 cpus: u32, t: SimTime)
        -> Option<usize> {
        let pick = self.pick(&SlaRank, sites, used_per_site, cpus, 0,
                             None);
        if let Some(i) = pick {
            self.decisions.push((t, i));
        }
        pick
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudsim::{SiteSpec, VmRequest};
    use crate::netsim::{LinkSpec, NetId};
    use crate::orchestrator::select_site;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn site(spec: SiteSpec, i: usize) -> CloudSite {
        CloudSite::new(spec, i as u8, NetId(i), 40 + i as u64)
    }

    fn paper_slas() -> Vec<Sla> {
        vec![
            Sla { site_name: "CESNET-MCC".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "AWS".into(), priority: 1,
                  max_instances: None },
        ]
    }

    fn paper_sites() -> Vec<CloudSite> {
        vec![
            site(SiteSpec::cesnet_metacentrum(), 0),
            site(SiteSpec::aws_us_east_2(), 1),
        ]
    }

    fn broker(kind: PolicyKind, sites: &[CloudSite], slas: &[Sla])
        -> ElasticityBroker {
        ElasticityBroker::new(kind, sites, slas, 2, 4.0)
    }

    #[test]
    fn sla_rank_matches_legacy_select_site() {
        let mut sites = paper_sites();
        let slas = paper_slas();
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        let used = vec![0, 0];
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)),
                   select_site(&sites, &slas, &used, 2));
        // Fill CESNET to its 3-VM quota: both must burst to AWS.
        for i in 0..3 {
            sites[0]
                .request_vm(&VmRequest {
                    name: format!("n{i}"),
                    instance_type: "standard.medium".into(),
                    network: None,
                    public_ip: false,
                }, t(0.0))
                .unwrap();
        }
        assert_eq!(b.select(&sites, &used, 2, 0, t(1.0)), Some(1));
        assert_eq!(select_site(&sites, &slas, &used, 2), Some(1));
        assert_eq!(b.decisions.len(), 2);
    }

    #[test]
    fn cost_min_prefers_free_site_over_sla_home() {
        // SLA prefers AWS, but CESNET is grant-funded ($0).
        let sites = paper_sites();
        let slas = vec![
            Sla { site_name: "AWS".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "CESNET-MCC".into(), priority: 1,
                  max_instances: None },
        ];
        let used = vec![0, 0];
        let mut sla = broker(PolicyKind::SlaRank, &sites, &slas);
        let mut cost = broker(PolicyKind::CostMin, &sites, &slas);
        assert_eq!(sla.select(&sites, &used, 2, 0, t(0.0)), Some(1));
        assert_eq!(cost.select(&sites, &used, 2, 0, t(0.0)), Some(0));
    }

    #[test]
    fn spot_aware_avoids_hazard_cost_min_chases_discount() {
        let sites = vec![
            site(SiteSpec::aws_us_east_2(), 0),
            site(SiteSpec::aws_spot_us_east_2(), 1),
        ];
        let slas: Vec<Sla> = Vec::new();
        let used = vec![0, 0];
        let mut spot = broker(PolicyKind::SpotAware, &sites, &slas);
        let mut cost = broker(PolicyKind::CostMin, &sites, &slas);
        // Spot market is cheaper but hazardous.
        assert_eq!(cost.select(&sites, &used, 2, 0, t(0.0)), Some(1));
        assert_eq!(spot.select(&sites, &used, 2, 0, t(0.0)), Some(0));
        // Under heavy queue pressure the premium stops being worth it:
        // SpotAware flips to the cheap spot market.
        let deep = crate::broker::policy::SPOT_PRESSURE_QUEUE + 1;
        assert_eq!(spot.select(&sites, &used, 2, deep, t(1.0)), Some(1));
    }

    #[test]
    fn latency_min_follows_the_wan() {
        let mut net = Network::new();
        let sites = vec![
            site(SiteSpec::cesnet_metacentrum(), 0),
            site(SiteSpec::aws_us_east_2(), 1),
            site(SiteSpec::opennebula("ON-EU"), 2),
        ];
        for s in &sites {
            net.add_location(&s.spec.name);
        }
        net.set_link(NetId(0), NetId(1), LinkSpec::transatlantic());
        net.set_link(NetId(0), NetId(2), LinkSpec::wan());
        let slas: Vec<Sla> = Vec::new();
        let used = vec![0, 0, 0];
        let mut b = broker(PolicyKind::LatencyMin, &sites, &slas);
        b.set_front_end(0, &net, &sites);
        assert_eq!(b.table().latency_from_fe(0), 0.0);
        assert!(b.table().latency_from_fe(1)
                > b.table().latency_from_fe(2));
        // FE site itself first; once full, the nearer WAN site wins
        // over the transatlantic one.
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)), Some(0));
        let mut filled = sites;
        for i in 0..3 {
            filled[0]
                .request_vm(&VmRequest {
                    name: format!("n{i}"),
                    instance_type: "standard.medium".into(),
                    network: None,
                    public_ip: false,
                }, t(0.0))
                .unwrap();
        }
        assert_eq!(b.select(&filled, &used, 2, 0, t(1.0)), Some(2));
    }

    #[test]
    fn outage_excludes_site_until_lifted() {
        let sites = paper_sites();
        let slas = paper_slas();
        let used = vec![0, 0];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        b.set_outage(0, true);
        assert!(b.outage_active(0));
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)), Some(1));
        b.set_outage(1, true);
        assert_eq!(b.select(&sites, &used, 2, 0, t(1.0)), None);
        b.set_outage(0, false);
        assert_eq!(b.select(&sites, &used, 2, 0, t(2.0)), Some(0));
    }

    #[test]
    fn quarantine_excludes_site_like_an_outage_on_its_own_flag() {
        let sites = paper_sites();
        let slas = paper_slas();
        let used = vec![0, 0];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        b.set_quarantine(0, true);
        assert!(b.quarantine_active(0));
        assert!(!b.outage_active(0));
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)), Some(1));
        assert!(b.signals(0, &sites, &used, 0).quarantined);
        assert_eq!(b.signals(0, &sites, &used, 0).availability, 0.0);
        // A scenario outage ending elsewhere must not lift quarantine.
        b.set_outage(0, true);
        b.set_outage(0, false);
        assert!(b.quarantine_active(0));
        assert_eq!(b.select(&sites, &used, 2, 0, t(1.0)), Some(1));
        b.set_quarantine(0, false);
        assert_eq!(b.select(&sites, &used, 2, 0, t(2.0)), Some(0));
    }

    #[test]
    fn health_score_deranks_site_before_any_breaker_opens() {
        let sites = paper_sites();
        let slas = paper_slas();
        let used = vec![0, 0];
        let mut b = broker(PolicyKind::HealthAware, &sites, &slas);
        // Full health: identical to SlaRank — the SLA home wins.
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)), Some(0));
        assert_eq!(b.signals(0, &sites, &used, 0).health, 1.0);
        // Degradation inside the deadband changes nothing.
        b.set_health(0, 0.95);
        assert_eq!(b.select(&sites, &used, 2, 0, t(1.0)), Some(0));
        // Past the deadband the flaky SLA home loses a priority step
        // and the healthy priority-1 site takes placements — no
        // outage, no quarantine, availability untouched.
        b.set_health(0, 0.8);
        assert!(!b.quarantine_active(0));
        assert!(!b.outage_active(0));
        assert!(b.signals(0, &sites, &used, 0).availability > 0.0);
        assert_eq!(b.select(&sites, &used, 2, 0, t(2.0)), Some(1));
        // Recovery restores the original ranking.
        b.set_health(0, 1.0);
        assert_eq!(b.select(&sites, &used, 2, 0, t(3.0)), Some(0));
        // SlaRank itself ignores the score entirely.
        let mut s = broker(PolicyKind::SlaRank, &sites, &slas);
        s.set_health(0, 0.1);
        assert_eq!(s.select(&sites, &used, 2, 0, t(0.0)), Some(0));
        // NaN and out-of-range scores are sanitized, never promoted.
        b.set_health(0, f64::NAN);
        assert_eq!(b.health_of(0), 0.0);
        b.set_health(0, 42.0);
        assert_eq!(b.health_of(0), 1.0);
    }

    #[test]
    fn select_excluding_masks_sites_on_top_of_eligibility() {
        let sites = paper_sites();
        let slas = paper_slas();
        let used = vec![0, 0];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        assert_eq!(b.select_excluding(&sites, &used, 2, 0, t(0.0),
                                      &[false, false]), Some(0));
        assert_eq!(b.select_excluding(&sites, &used, 2, 0, t(1.0),
                                      &[true, false]), Some(1));
        assert_eq!(b.select_excluding(&sites, &used, 2, 0, t(2.0),
                                      &[true, true]), None);
    }

    #[test]
    fn route_candidates_gate_on_reachability_only() {
        let sites = paper_sites();
        let slas = vec![
            Sla { site_name: "CESNET-MCC".into(), priority: 0,
                  max_instances: Some(2) },
            Sla { site_name: "AWS".into(), priority: 1,
                  max_instances: None },
        ];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        // SLA headroom exhausted at the home site: provisioning skips
        // it, but job blocks still route to the capacity it has — and
        // it still ranks first.
        assert_eq!(b.select(&sites, &[2, 0], 2, 0, t(0.0)), Some(1));
        assert_eq!(b.route_candidates(&sites, &[2, 0], 0), vec![0, 1]);
        // Quarantine and outage are the only gates.
        b.set_quarantine(0, true);
        assert_eq!(b.route_candidates(&sites, &[2, 0], 0), vec![1]);
        b.set_outage(1, true);
        assert!(b.route_candidates(&sites, &[2, 0], 0).is_empty());
        b.set_quarantine(0, false);
        b.set_outage(1, false);
        assert_eq!(b.route_candidates(&sites, &[2, 0], 0), vec![0, 1]);
    }

    #[test]
    fn price_spike_redirects_cost_min() {
        let mut sites = vec![
            site(SiteSpec::aws_us_east_2(), 0),
            site(SiteSpec::aws_spot_us_east_2(), 1),
        ];
        let slas: Vec<Sla> = Vec::new();
        let used = vec![0, 0];
        let mut b = broker(PolicyKind::CostMin, &sites, &slas);
        assert_eq!(b.select(&sites, &used, 2, 0, t(0.0)), Some(1));
        // Spot price spikes 10x above on-demand: cost-min flips. The
        // broker reads the factor straight off the site.
        sites[1].set_price_factor(10.0);
        assert_eq!(b.select(&sites, &used, 2, 0, t(1.0)), Some(0));
        sites[1].set_price_factor(1.0);
        assert_eq!(b.select(&sites, &used, 2, 0, t(2.0)), Some(1));
    }

    #[test]
    fn duplicate_site_names_share_the_sla() {
        // Two capacity pools exposed under one provider name: both
        // resolve to the same SLA, like the legacy by-name lookup.
        let mut sites = vec![
            site(SiteSpec::cesnet_metacentrum(), 0),
            site(SiteSpec::cesnet_metacentrum(), 1),
        ];
        let slas = vec![Sla { site_name: "CESNET-MCC".into(), priority: 0,
                              max_instances: Some(4) }];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        // Equal rank: the first pool wins deterministically.
        assert_eq!(b.select(&sites, &[0, 0], 2, 0, t(0.0)), Some(0));
        // SLA headroom applies per used-count entry.
        assert_eq!(b.select(&sites, &[4, 0], 2, 0, t(1.0)), Some(1));
        // Fill pool 0's quota: pool 1 takes over.
        for i in 0..3 {
            sites[0]
                .request_vm(&VmRequest {
                    name: format!("n{i}"),
                    instance_type: "standard.medium".into(),
                    network: None,
                    public_ip: false,
                }, t(0.0))
                .unwrap();
        }
        assert_eq!(b.select(&sites, &[0, 0], 2, 0, t(2.0)), Some(1));
    }

    #[test]
    fn signals_expose_quota_cost_and_hazard() {
        let mut sites = vec![site(SiteSpec::aws_spot_us_east_2(), 0)];
        let b = broker(PolicyKind::SlaRank, &sites, &[]);
        sites[0]
            .request_vm(&VmRequest {
                name: "wn".into(),
                instance_type: "t2.medium".into(),
                network: None,
                public_ip: false,
            }, t(0.0))
            .unwrap();
        let sig = b.signals(0, &sites, &[1], 7);
        assert_eq!(sig.free_vms, 19);
        assert_eq!(sig.free_vcpus, 38);
        assert!(sig.cost_rate > 0.0);
        assert!(sig.hazard_per_hour > 0.0);
        assert_eq!(sig.queue_depth, 7);
        assert_eq!(sig.sla_headroom, None);
        assert!(!sig.outage);
        // Spot t2.medium at 30% of 0.0464.
        assert!((sig.effective_price - 0.0464 * 0.3).abs() < 1e-9);
    }

    #[test]
    fn sla_headroom_gates_selection() {
        let sites = paper_sites();
        let slas = vec![
            Sla { site_name: "CESNET-MCC".into(), priority: 0,
                  max_instances: Some(2) },
            Sla { site_name: "AWS".into(), priority: 1,
                  max_instances: None },
        ];
        let mut b = broker(PolicyKind::SlaRank, &sites, &slas);
        assert_eq!(b.select(&sites, &[1, 0], 2, 0, t(0.0)), Some(0));
        // CESNET's SLA is exhausted: burst even though quota has room.
        assert_eq!(b.select(&sites, &[2, 0], 2, 0, t(1.0)), Some(1));
        assert_eq!(select_site(&sites, &slas, &[2, 0], 2), Some(1));
    }
}
