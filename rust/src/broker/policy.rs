//! Pluggable site-placement policies.
//!
//! A [`PlacementPolicy`] scores every *eligible* site (eligibility —
//! availability, SLA, quota, headroom — is owned by the broker and
//! identical for all policies, mirroring the legacy
//! `orchestrator::select_site` checks); the broker picks the lowest
//! score. Scores are pure functions of the immutable [`SiteTable`] and
//! the per-decision [`SiteSignals`], so every policy is deterministic
//! and unit-testable without a simulation.

use super::{SiteSignals, SiteTable};

/// Deterministic, totally-ordered score; lower wins. Ties fall through
/// `primary` → `secondary` → `tiebreak` (the site-name rank, so the
/// final order never depends on map iteration or float noise).
#[derive(Debug, Clone, Copy)]
pub struct Score {
    pub primary: f64,
    pub secondary: f64,
    pub tiebreak: u32,
}

impl Score {
    /// Strictly better (lower) than `other` under the total order.
    pub fn better_than(&self, other: &Score) -> bool {
        match self.primary.total_cmp(&other.primary) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => {
                match self.secondary.total_cmp(&other.secondary) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => {
                        self.tiebreak < other.tiebreak
                    }
                }
            }
        }
    }
}

/// SLA priority folded into one f64: priority for SLA sites,
/// +∞ for opportunistic (no-SLA) sites — which therefore rank after
/// every SLA site, exactly like the legacy `(is_none, priority)` key.
fn sla_key(table: &SiteTable, site: usize) -> f64 {
    match table.sla_priority(site) {
        Some(p) => p as f64,
        None => f64::INFINITY,
    }
}

/// Availability descending, quantized at 1e-6 exactly like the legacy
/// ranking key (`(1e6 - avail * 1e6) as i64`).
fn avail_key(sig: &SiteSignals) -> f64 {
    (1e6 - sig.availability * 1e6) as i64 as f64
}

/// How many SLA-priority steps a fully dead health score (0.0) demotes
/// a site by. With `floor` quantization a site must lose more than
/// `1/HEALTH_RANK_SPAN` (~6%) of its health before it is re-ranked at
/// all — a deadband that keeps sub-noise telemetry jitter from
/// flapping placement decisions.
pub const HEALTH_RANK_SPAN: f64 = 16.0;

/// Whole SLA-priority steps of demotion earned by a degraded health
/// score. Exactly `0.0` at `health == 1.0` (IEEE: `1.0 - 1.0 == 0.0`),
/// so a fault-free run adds nothing to any ranking key — the
/// [`HealthAware`] ≡ [`SlaRank`] equivalence contract rests on this.
pub fn health_rank_penalty(health: f64) -> f64 {
    ((1.0 - health.clamp(0.0, 1.0)) * HEALTH_RANK_SPAN).floor()
}

/// True once the health score is degraded enough to demote the site by
/// at least one SLA-priority step — the broker's "de-ranked" predicate,
/// also used by the control plane to timestamp when adaptive placement
/// started steering away from a site.
pub fn health_deranked(health: f64) -> bool {
    health_rank_penalty(health) > 0.0
}

/// Multiplicative health decay for magnitude-keyed policies (price,
/// latency, hazard): `1.0` at full health — exactly, so fault-free
/// decisions are untouched — rising linearly to `2.0` at health 0, so
/// a half-dead site's price/latency/hazard counts double. Exposed for
/// policies that rank on continuous costs rather than SLA steps.
pub fn health_decay(health: f64) -> f64 {
    2.0 - health.clamp(0.0, 1.0)
}

/// Fine-grained (sub-priority-step) health penalty for secondary keys,
/// quantized to whole units like [`avail_key`] so comparisons never
/// hinge on float noise. Exactly `0.0` at full health.
fn health_tiebreak_penalty(health: f64) -> f64 {
    ((1.0 - health.clamp(0.0, 1.0)) * 1e9).round()
}

/// A site-selection policy: scores one eligible site.
pub trait PlacementPolicy: Send {
    fn name(&self) -> &'static str;

    /// Score an eligible site; lower wins. Must be deterministic.
    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score;
}

/// Baseline: the paper's SLA-priority ranking — decision-identical to
/// the legacy `orchestrator::select_site` (proven by the property test
/// in `tests/broker_policies.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct SlaRank;

impl PlacementPolicy for SlaRank {
    fn name(&self) -> &'static str {
        "sla-rank"
    }

    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score {
        Score {
            primary: sla_key(table, site),
            secondary: avail_key(sig),
            tiebreak: table.name_rank(site),
        }
    }
}

/// Cheapest-first: effective worker $/hour (list price × live scenario
/// price factor; grant-funded research sites are $0), SLA rank breaking
/// price ties.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostMin;

impl PlacementPolicy for CostMin {
    fn name(&self) -> &'static str {
        "cost-min"
    }

    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score {
        Score {
            primary: sig.effective_price,
            secondary: sla_key(table, site),
            tiebreak: table.name_rank(site),
        }
    }
}

/// Closest-first: one-way WAN latency from the front-end's site through
/// the vRouter overlay (0 for the front-end site itself), SLA rank
/// breaking ties. Until the front-end is placed all latencies are 0 and
/// this degrades to `SlaRank` ordering via the secondary key.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyMin;

impl PlacementPolicy for LatencyMin {
    fn name(&self) -> &'static str {
        "latency-min"
    }

    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score {
        Score {
            primary: sig.latency_to_fe,
            secondary: sla_key(table, site),
            tiebreak: table.name_rank(site),
        }
    }
}

/// Pending-queue depth above which [`SpotAware`] stops paying the
/// stability premium and chases price like [`CostMin`] — a deep
/// backlog makes preemption risk worth taking, since requeued jobs
/// would have waited anyway.
pub const SPOT_PRESSURE_QUEUE: u32 = 256;

/// Preemption-averse: sites are weighted by their spot-reclaim hazard
/// (events per VM-hour) first, effective price second — a hazardous
/// spot market is only chosen when nothing stabler has capacity.
/// Under heavy queue pressure (> [`SPOT_PRESSURE_QUEUE`] pending
/// jobs) the weights flip: cheap spot capacity first, hazard as the
/// tie-break.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpotAware;

impl PlacementPolicy for SpotAware {
    fn name(&self) -> &'static str {
        "spot-aware"
    }

    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score {
        let (primary, secondary) = if sig.queue_depth > SPOT_PRESSURE_QUEUE
        {
            (sig.effective_price, sig.hazard_per_hour)
        } else {
            (sig.hazard_per_hour, sig.effective_price)
        };
        Score {
            primary,
            secondary,
            tiebreak: table.name_rank(site),
        }
    }
}

/// Fault-telemetry-aware SLA ranking: [`SlaRank`]'s keys plus the
/// health score the control plane distills from each site's chaos
/// counters (retransmission rate, provisioning retries, recent
/// quarantine time — see `cluster::control`). A degrading site is
/// demoted by whole SLA-priority steps ([`health_rank_penalty`]), so a
/// flaky priority-0 site starts losing placements to a healthy
/// priority-1 site *before* its circuit breaker ever opens; within a
/// priority band the fine-grained penalty breaks availability ties
/// toward the healthier site. Under a fault-free run every health
/// score is exactly 1.0, every penalty is exactly 0.0, and the score
/// tuple — including tie behaviour — is identical to [`SlaRank`]'s
/// (property-proven in `tests/broker_policies.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct HealthAware;

impl PlacementPolicy for HealthAware {
    fn name(&self) -> &'static str {
        "health-aware"
    }

    fn score(&self, site: usize, table: &SiteTable, sig: &SiteSignals)
        -> Score {
        Score {
            primary: sla_key(table, site)
                + health_rank_penalty(sig.health),
            secondary: avail_key(sig)
                + health_tiebreak_penalty(sig.health),
            tiebreak: table.name_rank(site),
        }
    }
}

/// Config-friendly policy selector (what [`crate::cluster::RunConfig`]
/// carries; `build` yields the boxed trait object the broker drives).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    SlaRank,
    CostMin,
    LatencyMin,
    SpotAware,
    HealthAware,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::SlaRank,
        PolicyKind::CostMin,
        PolicyKind::LatencyMin,
        PolicyKind::SpotAware,
        PolicyKind::HealthAware,
    ];

    pub fn label(self) -> &'static str {
        match self {
            PolicyKind::SlaRank => "sla-rank",
            PolicyKind::CostMin => "cost-min",
            PolicyKind::LatencyMin => "latency-min",
            PolicyKind::SpotAware => "spot-aware",
            PolicyKind::HealthAware => "health-aware",
        }
    }

    pub fn build(self) -> Box<dyn PlacementPolicy> {
        match self {
            PolicyKind::SlaRank => Box::new(SlaRank),
            PolicyKind::CostMin => Box::new(CostMin),
            PolicyKind::LatencyMin => Box::new(LatencyMin),
            PolicyKind::SpotAware => Box::new(SpotAware),
            PolicyKind::HealthAware => Box::new(HealthAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_order_is_total_and_lexicographic() {
        let a = Score { primary: 0.0, secondary: 5.0, tiebreak: 9 };
        let b = Score { primary: 1.0, secondary: 0.0, tiebreak: 0 };
        assert!(a.better_than(&b));
        assert!(!b.better_than(&a));
        let c = Score { primary: 0.0, secondary: 4.0, tiebreak: 9 };
        assert!(c.better_than(&a));
        let d = Score { primary: 0.0, secondary: 5.0, tiebreak: 8 };
        assert!(d.better_than(&a));
        // Exact ties are not "better" — the broker keeps the first.
        assert!(!a.better_than(&a));
        // Infinities order after every finite score.
        let inf = Score { primary: f64::INFINITY, secondary: 0.0,
                          tiebreak: 0 };
        assert!(a.better_than(&inf));
        assert!(!inf.better_than(&a));
    }

    #[test]
    fn policy_kinds_build_matching_labels() {
        for kind in PolicyKind::ALL {
            assert_eq!(kind.build().name(), kind.label());
        }
    }

    #[test]
    fn health_penalties_vanish_exactly_at_full_health() {
        assert_eq!(health_rank_penalty(1.0), 0.0);
        assert_eq!(health_tiebreak_penalty(1.0), 0.0);
        assert_eq!(health_decay(1.0), 1.0);
        assert!(!health_deranked(1.0));
        // The deadband: small degradation re-ranks nothing...
        assert_eq!(health_rank_penalty(0.95), 0.0);
        assert!(!health_deranked(0.95));
        // ...but it still nudges the fine-grained tie-break key.
        assert!(health_tiebreak_penalty(0.95) > 0.0);
        // Past the deadband the site loses whole SLA-priority steps.
        assert_eq!(health_rank_penalty(0.9), 1.0);
        assert!(health_deranked(0.9));
        assert_eq!(health_rank_penalty(0.0), HEALTH_RANK_SPAN);
        // Out-of-range scores clamp instead of exploding.
        assert_eq!(health_rank_penalty(-3.0), HEALTH_RANK_SPAN);
        assert_eq!(health_rank_penalty(7.0), 0.0);
        assert_eq!(health_decay(0.0), 2.0);
    }
}
