//! OpenVPN cipher cost model (§3.5.6 performance-security trade-off).
//!
//! Throughput caps reflect what a t2.medium-class vRouter VM can push
//! through a single OpenVPN tunnel with each cipher; ordering (plain >
//! AES-128-GCM > AES-256-GCM > ChaCha20 > BF-CBC) is what matters for
//! reproducing the trade-off, not the absolute numbers.

/// Tunnel cipher choices exposed to deployments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Cipher {
    /// `--cipher none`: authentication only, no confidentiality. The paper
    /// suggests this for cluster software that already encrypts natively.
    Plain,
    /// AES-128-GCM (AES-NI accelerated).
    Aes128Gcm,
    /// AES-256-GCM — the secure default.
    Aes256Gcm,
    /// ChaCha20-Poly1305 (no AES-NI needed).
    ChaCha20,
    /// Legacy Blowfish-CBC (OpenVPN's historical default).
    BlowfishCbc,
}

impl Cipher {
    pub const ALL: [Cipher; 5] = [
        Cipher::Plain,
        Cipher::Aes128Gcm,
        Cipher::Aes256Gcm,
        Cipher::ChaCha20,
        Cipher::BlowfishCbc,
    ];

    /// Single-tunnel throughput cap on the reference vRouter VM, bytes/s.
    pub fn throughput_bps(self) -> f64 {
        match self {
            Cipher::Plain => 112.5e6,      // ~900 Mbps, tun copy-bound
            Cipher::Aes128Gcm => 80.0e6,   // ~640 Mbps
            Cipher::Aes256Gcm => 70.0e6,   // ~560 Mbps
            Cipher::ChaCha20 => 60.0e6,    // ~480 Mbps
            Cipher::BlowfishCbc => 17.5e6, // ~140 Mbps
        }
    }

    /// Added processing latency per tunnelled hop, seconds.
    pub fn hop_latency_s(self) -> f64 {
        match self {
            Cipher::Plain => 0.0002,
            Cipher::Aes128Gcm => 0.0004,
            Cipher::Aes256Gcm => 0.0005,
            Cipher::ChaCha20 => 0.0006,
            Cipher::BlowfishCbc => 0.0012,
        }
    }

    /// vRouter CPU cost per byte (fraction of one core-second), used to
    /// model the central point as a compute bottleneck under fan-in.
    pub fn cpu_cost_per_byte(self) -> f64 {
        // One fully-loaded core saturates at exactly the throughput cap.
        1.0 / self.throughput_bps()
    }

    /// Security level label (for reports).
    pub fn security(self) -> &'static str {
        match self {
            Cipher::Plain => "none",
            Cipher::Aes128Gcm => "128-bit AEAD",
            Cipher::Aes256Gcm => "256-bit AEAD",
            Cipher::ChaCha20 => "256-bit AEAD",
            Cipher::BlowfishCbc => "64-bit block (legacy)",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Cipher::Plain => "none",
            Cipher::Aes128Gcm => "AES-128-GCM",
            Cipher::Aes256Gcm => "AES-256-GCM",
            Cipher::ChaCha20 => "ChaCha20-Poly1305",
            Cipher::BlowfishCbc => "BF-CBC",
        }
    }
}

impl std::str::FromStr for Cipher {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "plain" => Ok(Cipher::Plain),
            "aes-128-gcm" | "aes128" => Ok(Cipher::Aes128Gcm),
            "aes-256-gcm" | "aes256" => Ok(Cipher::Aes256Gcm),
            "chacha20" | "chacha20-poly1305" => Ok(Cipher::ChaCha20),
            "bf-cbc" | "blowfish" => Ok(Cipher::BlowfishCbc),
            other => anyhow::bail!("unknown cipher {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_plain_fastest_blowfish_slowest() {
        let caps: Vec<f64> =
            Cipher::ALL.iter().map(|c| c.throughput_bps()).collect();
        assert!(caps.windows(2).all(|w| w[0] >= w[1]), "{caps:?}");
        let lats: Vec<f64> =
            Cipher::ALL.iter().map(|c| c.hop_latency_s()).collect();
        assert!(lats.windows(2).all(|w| w[0] <= w[1]), "{lats:?}");
    }

    #[test]
    fn cpu_cost_inverse_of_throughput() {
        for c in Cipher::ALL {
            let t = c.throughput_bps();
            assert!((c.cpu_cost_per_byte() * t - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn parses_from_str() {
        assert_eq!("aes-256-gcm".parse::<Cipher>().unwrap(),
                   Cipher::Aes256Gcm);
        assert_eq!("none".parse::<Cipher>().unwrap(), Cipher::Plain);
        assert!("rot13".parse::<Cipher>().is_err());
    }
}
