//! Flow-level network simulator: the underlay the vRouter overlay rides on.
//!
//! Models inter-site WAN links (latency + bandwidth) and intra-site LANs.
//! The overlay's OpenVPN hops add a cipher-dependent throughput cap and
//! per-hop latency — the substance of the paper's §3.5.6
//! performance-security trade-off.

pub mod cipher;

pub use cipher::Cipher;

use std::collections::HashMap;

/// Index of a network location (a cloud site or the public internet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub usize);

/// Directed-symmetric link properties.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Usable bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl LinkSpec {
    /// Typical intra-European research-network WAN link.
    pub fn wan() -> LinkSpec {
        LinkSpec { latency_s: 0.020, bandwidth_bps: 1.25e8 } // 1 Gbps
    }

    /// Transatlantic link (CESNET ↔ AWS us-east-2 in the paper).
    pub fn transatlantic() -> LinkSpec {
        LinkSpec { latency_s: 0.055, bandwidth_bps: 6.25e7 } // 500 Mbps
    }

    /// Intra-site LAN.
    pub fn lan() -> LinkSpec {
        LinkSpec { latency_s: 0.0004, bandwidth_bps: 1.25e9 } // 10 Gbps
    }
}

/// The underlay: sites + pairwise links.
#[derive(Debug, Default)]
pub struct Network {
    names: Vec<String>,
    links: HashMap<(NetId, NetId), LinkSpec>,
    default_link: Option<LinkSpec>,
}

impl Network {
    pub fn new() -> Network {
        Network { names: Vec::new(), links: HashMap::new(),
                  default_link: Some(LinkSpec::wan()) }
    }

    /// Register a location; returns its id.
    pub fn add_location(&mut self, name: &str) -> NetId {
        self.names.push(name.to_string());
        NetId(self.names.len() - 1)
    }

    pub fn name(&self, id: NetId) -> &str {
        &self.names[id.0]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Set the (symmetric) link between two locations.
    pub fn set_link(&mut self, a: NetId, b: NetId, spec: LinkSpec) {
        self.links.insert(Self::key(a, b), spec);
    }

    /// Fallback link used for unspecified pairs (None = unreachable).
    pub fn set_default_link(&mut self, spec: Option<LinkSpec>) {
        self.default_link = spec;
    }

    fn key(a: NetId, b: NetId) -> (NetId, NetId) {
        if a <= b { (a, b) } else { (b, a) }
    }

    /// Link between two locations (same location ⇒ LAN).
    pub fn link(&self, a: NetId, b: NetId) -> Option<LinkSpec> {
        if a == b {
            return Some(LinkSpec::lan());
        }
        self.links.get(&Self::key(a, b)).copied().or(self.default_link)
    }

    /// One-way latency along a multi-hop path of locations.
    pub fn path_latency(&self, path: &[NetId]) -> Option<f64> {
        let mut total = 0.0;
        for w in path.windows(2) {
            total += self.link(w[0], w[1])?.latency_s;
        }
        Some(total)
    }

    /// Bottleneck bandwidth along a path.
    pub fn path_bandwidth(&self, path: &[NetId]) -> Option<f64> {
        let mut bw = f64::INFINITY;
        for w in path.windows(2) {
            bw = bw.min(self.link(w[0], w[1])?.bandwidth_bps);
        }
        Some(bw)
    }
}

/// One overlay hop as seen by a flow: underlay link + the tunnel cipher
/// terminating at a vRouter with finite crypto throughput.
#[derive(Debug, Clone, Copy)]
pub struct OverlayHop {
    pub link: LinkSpec,
    /// None = in-clear LAN hop (no tunnel).
    pub tunnel: Option<Cipher>,
}

/// Time to move `bytes` across a sequence of overlay hops,
/// store-and-forward at each vRouter.
///
/// Each tunnelled hop is capped at min(link bandwidth, cipher throughput)
/// and pays the cipher's per-hop processing latency on top of propagation.
pub fn transfer_time(bytes: f64, hops: &[OverlayHop]) -> f64 {
    let mut t = 0.0;
    for hop in hops {
        let bw = match hop.tunnel {
            Some(c) => hop.link.bandwidth_bps.min(c.throughput_bps()),
            None => hop.link.bandwidth_bps,
        };
        let proc = hop.tunnel.map(|c| c.hop_latency_s()).unwrap_or(0.0);
        t += hop.link.latency_s + proc + bytes / bw;
    }
    t
}

/// Effective steady-state throughput (bytes/s) across the hops — the
/// bottleneck once pipelining hides per-hop latencies.
pub fn path_throughput(hops: &[OverlayHop]) -> f64 {
    hops.iter()
        .map(|h| match h.tunnel {
            Some(c) => h.link.bandwidth_bps.min(c.throughput_bps()),
            None => h.link.bandwidth_bps,
        })
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_site_net() -> (Network, NetId, NetId) {
        let mut n = Network::new();
        let a = n.add_location("cesnet");
        let b = n.add_location("aws");
        n.set_link(a, b, LinkSpec::transatlantic());
        (n, a, b)
    }

    #[test]
    fn same_location_is_lan() {
        let (n, a, _) = two_site_net();
        let l = n.link(a, a).unwrap();
        assert!(l.latency_s < 0.001);
    }

    #[test]
    fn default_link_for_unknown_pairs() {
        let mut n = Network::new();
        let a = n.add_location("a");
        let b = n.add_location("b");
        assert!(n.link(a, b).is_some()); // default WAN
        n.set_default_link(None);
        assert!(n.link(a, b).is_none());
    }

    #[test]
    fn path_metrics() {
        let mut n = Network::new();
        let a = n.add_location("a");
        let b = n.add_location("b");
        let c = n.add_location("c");
        n.set_link(a, b, LinkSpec { latency_s: 0.01, bandwidth_bps: 1e8 });
        n.set_link(b, c, LinkSpec { latency_s: 0.03, bandwidth_bps: 5e7 });
        let lat = n.path_latency(&[a, b, c]).unwrap();
        assert!((lat - 0.04).abs() < 1e-12);
        assert_eq!(n.path_bandwidth(&[a, b, c]).unwrap(), 5e7);
    }

    #[test]
    fn cipher_caps_reduce_throughput_monotonically() {
        let link = LinkSpec { latency_s: 0.02, bandwidth_bps: 1.25e9 };
        let t_plain = transfer_time(
            1e9, &[OverlayHop { link, tunnel: Some(Cipher::Plain) }]);
        let t_128 = transfer_time(
            1e9, &[OverlayHop { link, tunnel: Some(Cipher::Aes128Gcm) }]);
        let t_256 = transfer_time(
            1e9, &[OverlayHop { link, tunnel: Some(Cipher::Aes256Gcm) }]);
        let t_bf = transfer_time(
            1e9, &[OverlayHop { link, tunnel: Some(Cipher::BlowfishCbc) }]);
        assert!(t_plain < t_128 && t_128 < t_256 && t_256 < t_bf,
                "{t_plain} {t_128} {t_256} {t_bf}");
    }

    #[test]
    fn untunnelled_hop_is_link_limited() {
        let link = LinkSpec { latency_s: 0.0, bandwidth_bps: 1e6 };
        let t = transfer_time(2e6, &[OverlayHop { link, tunnel: None }]);
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_is_bottleneck() {
        let fast = LinkSpec { latency_s: 0.0, bandwidth_bps: 1e9 };
        let slow = LinkSpec { latency_s: 0.0, bandwidth_bps: 1e7 };
        let hops = [
            OverlayHop { link: fast, tunnel: Some(Cipher::Aes256Gcm) },
            OverlayHop { link: slow, tunnel: None },
        ];
        assert_eq!(path_throughput(&hops), 1e7);
    }
}
