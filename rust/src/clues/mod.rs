//! CLUES elasticity engine (§3.4).
//!
//! CLUES monitors the LRMS job queue and node states and decides when to
//! power worker nodes on (pending jobs, no free slots) and off (idle
//! beyond a timeout). The decision function is pure over a snapshot, so
//! it is unit-testable without the full simulation; the cluster world
//! executes the returned [`Action`]s through the orchestrator.
//!
//! Behaviours reproduced from the paper's §4.2:
//! * pending power-offs are **cancelled** when new jobs arrive early,
//! * a node whose LRMS state reads *down* for consecutive polls is marked
//!   **failed** and powered off "to avoid unnecessary costs by failed
//!   VMs", then powered on again if jobs remain (the vnode-5 cycle).
//!
//! Tracking is keyed by interned [`NodeId`]s sharing the cluster-wide
//! interner, and the monitor tick iterates allocation-light
//! [`NodeStat`] snapshots — a 10k-node tick allocates no `String`s
//! except for the (rare) emitted actions.

use std::collections::HashMap;

use crate::ids::{NodeId, NodeNames};
use crate::lrms::{Lrms, NodeHealth, NodeStat};
use crate::sim::SimTime;

/// CLUES configuration (a subset of its real policy knobs).
#[derive(Debug, Clone)]
pub struct CluesConfig {
    /// Monitor poll period, seconds.
    pub poll_interval_s: f64,
    /// Idle time before a node is powered off.
    pub idle_timeout_s: f64,
    /// Elasticity bounds on *worker* count.
    pub min_workers: u32,
    pub max_workers: u32,
    /// Consecutive down polls before a node is declared failed.
    pub down_polls_to_fail: u32,
    /// Slots per worker (the paper's jobs take a whole node → 1).
    pub slots_per_worker: u32,
}

impl Default for CluesConfig {
    fn default() -> Self {
        CluesConfig {
            poll_interval_s: 60.0,
            idle_timeout_s: 300.0,
            min_workers: 0,
            max_workers: 5,
            down_polls_to_fail: 2,
            slots_per_worker: 1,
        }
    }
}

/// Power state CLUES tracks per worker node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerState {
    /// Requested from the orchestrator; not yet in the LRMS.
    PoweringOn,
    /// Alive and registered in the LRMS.
    On,
    /// Power-off requested (queued or executing at the orchestrator).
    PoweringOff,
    /// Declared failed (down too long).
    Failed,
    /// Gone.
    Off,
}

/// Decisions CLUES emits; the cluster world executes them. Actions carry
/// names (they cross into the orchestrator, whose updates are named).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Ask the orchestrator for `count` new worker nodes.
    PowerOn { count: u32 },
    /// Ask the orchestrator to decommission `node`.
    PowerOff { node: String },
    /// Revoke a still-queued power-off for `node`.
    CancelPowerOff { node: String },
    /// Declare `node` failed (world should power it off and may replace
    /// it on a later tick).
    MarkFailed { node: String },
}

#[derive(Debug, Clone)]
struct Tracked {
    state: PowerState,
    consecutive_down: u32,
}

/// The elasticity engine.
pub struct Clues {
    pub cfg: CluesConfig,
    names: NodeNames,
    nodes: HashMap<NodeId, Tracked>,
    /// Decision log for reports: (t, action).
    pub log: Vec<(SimTime, Action)>,
    /// Reused snapshot buffer: at steady state a tick performs no
    /// per-tick `Vec<NodeStat>` allocation, whatever the node count.
    stats_scratch: Vec<NodeStat>,
}

impl Clues {
    pub fn new(cfg: CluesConfig) -> Clues {
        Clues::with_names(cfg, NodeNames::new())
    }

    /// Share the cluster-wide interner so ids line up with the LRMS.
    pub fn with_names(cfg: CluesConfig, names: NodeNames) -> Clues {
        Clues {
            cfg,
            names,
            nodes: HashMap::new(),
            log: Vec::new(),
            stats_scratch: Vec::new(),
        }
    }

    /// Register a node under CLUES management (e.g. initial workers, or
    /// a node the orchestrator just started provisioning).
    pub fn track(&mut self, name: &str, state: PowerState) {
        let id = self.names.intern(name);
        self.track_id(id, state);
    }

    pub fn track_id(&mut self, id: NodeId, state: PowerState) {
        self.nodes.insert(id, Tracked { state, consecutive_down: 0 });
    }

    pub fn set_state(&mut self, name: &str, state: PowerState) {
        if let Some(id) = self.names.get(name) {
            self.set_state_id(id, state);
        }
    }

    pub fn set_state_id(&mut self, id: NodeId, state: PowerState) {
        if let Some(n) = self.nodes.get_mut(&id) {
            n.state = state;
            if state == PowerState::On {
                n.consecutive_down = 0;
            }
        }
    }

    pub fn state(&self, name: &str) -> Option<PowerState> {
        self.names.get(name).and_then(|id| self.state_id(id))
    }

    pub fn state_id(&self, id: NodeId) -> Option<PowerState> {
        self.nodes.get(&id).map(|n| n.state)
    }

    pub fn forget(&mut self, name: &str) {
        if let Some(id) = self.names.get(name) {
            self.forget_id(id);
        }
    }

    pub fn forget_id(&mut self, id: NodeId) {
        self.nodes.remove(&id);
    }

    fn count(&self, state: PowerState) -> u32 {
        self.nodes.values().filter(|n| n.state == state).count() as u32
    }

    /// Workers that count against max (anything not Off/Failed).
    fn active_workers(&self) -> u32 {
        self.nodes
            .values()
            .filter(|n| !matches!(n.state,
                PowerState::Off | PowerState::Failed))
            .count() as u32
    }

    /// One monitor tick. `lrms` provides queue + node state; `is_down`
    /// overrides health for transient-flap injection (it is what the
    /// monitor *reads*, which may disagree with reality — vnode-5).
    pub fn tick(
        &mut self,
        t: SimTime,
        lrms: &dyn Lrms,
        is_down: &dyn Fn(&str) -> bool,
    ) -> Vec<Action> {
        let mut actions = Vec::new();
        // Owned scratch (taken off self) so the sections below may
        // borrow `self.nodes` mutably while iterating the snapshots.
        let mut stats = std::mem::take(&mut self.stats_scratch);
        lrms.node_stats_into(&mut stats);

        // --- 1. Failure detection on On nodes ----------------------------
        for s in &stats {
            let Some(tracked) = self.nodes.get_mut(&s.id) else {
                continue;
            };
            if tracked.state != PowerState::On {
                continue;
            }
            // Only consult the (possibly expensive) monitor override for
            // tracked On nodes — this runs per node per tick.
            let down = s.health == NodeHealth::Down
                || self.names.with_name(s.id, |n| is_down(n));
            if down {
                tracked.consecutive_down += 1;
                if tracked.consecutive_down >= self.cfg.down_polls_to_fail {
                    tracked.state = PowerState::Failed;
                    actions.push(Action::MarkFailed {
                        node: self.names.name(s.id),
                    });
                }
            } else {
                tracked.consecutive_down = 0;
            }
        }

        let pending = lrms.pending() as u32;

        // --- 2. Cancel pending power-offs when work arrives ---------------
        if pending > 0 {
            let mut offs: Vec<NodeId> = self
                .nodes
                .iter()
                .filter(|(_, tr)| tr.state == PowerState::PoweringOff)
                .map(|(&id, _)| id)
                .collect();
            offs.sort(); // deterministic action order
            for id in offs {
                actions.push(Action::CancelPowerOff {
                    node: self.names.name(id),
                });
                // The world confirms the cancellation (set_state(On))
                // only if the orchestrator could still revoke it.
            }
        }

        // --- 3. Scale up ---------------------------------------------------
        let mut free_slots: u32 = 0;
        for s in &stats {
            if s.health == NodeHealth::Up
                && self
                    .nodes
                    .get(&s.id)
                    .map(|tr| tr.state == PowerState::On)
                    .unwrap_or(false)
                && !self.names.with_name(s.id, |n| is_down(n))
            {
                free_slots += s.slots - s.used_slots;
            }
        }
        let incoming = self.count(PowerState::PoweringOn)
            * self.cfg.slots_per_worker;
        // Nodes with a cancel in flight will come back too.
        let returning = if pending > 0 {
            self.count(PowerState::PoweringOff) * self.cfg.slots_per_worker
        } else {
            0
        };
        let deficit = pending.saturating_sub(free_slots + incoming
                                             + returning);
        if deficit > 0 {
            let headroom = self
                .cfg
                .max_workers
                .saturating_sub(self.active_workers());
            let want = deficit.div_ceil(self.cfg.slots_per_worker)
                .min(headroom);
            if want > 0 {
                actions.push(Action::PowerOn { count: want });
            }
        }

        // --- 4. Scale down ---------------------------------------------------
        if pending == 0 {
            let mut on_workers: Vec<&crate::lrms::NodeStat> = stats
                .iter()
                .filter(|s| {
                    self.nodes
                        .get(&s.id)
                        .map(|tr| tr.state == PowerState::On)
                        .unwrap_or(false)
                })
                .collect();
            // Power off the longest-idle nodes first.
            on_workers.sort_by(|a, b| {
                let ia = a.idle_since.map(|s| s.0).unwrap_or(f64::MAX);
                let ib = b.idle_since.map(|s| s.0).unwrap_or(f64::MAX);
                ia.partial_cmp(&ib).unwrap()
            });
            let mut removable = self
                .active_workers()
                .saturating_sub(self.cfg.min_workers);
            for s in on_workers {
                if removable == 0 {
                    break;
                }
                let idle_long_enough = s
                    .idle_since
                    .map(|x| t.0 - x.0 >= self.cfg.idle_timeout_s)
                    .unwrap_or(false);
                if s.used_slots == 0 && idle_long_enough {
                    if let Some(tr) = self.nodes.get_mut(&s.id) {
                        tr.state = PowerState::PoweringOff;
                    }
                    actions.push(Action::PowerOff {
                        node: self.names.name(s.id),
                    });
                    removable -= 1;
                }
            }
        }

        for a in &actions {
            self.log.push((t, a.clone()));
        }
        self.stats_scratch = stats;
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::NodeNames;
    use crate::lrms::{Lrms, Slurm};

    fn no_flap(_: &str) -> bool {
        false
    }

    fn setup(workers: &[&str]) -> (Slurm, Clues) {
        let names = NodeNames::new();
        let mut lrms = Slurm::with_names(names.clone());
        let mut clues = Clues::with_names(CluesConfig {
            idle_timeout_s: 300.0,
            max_workers: 5,
            ..CluesConfig::default()
        }, names);
        for w in workers {
            lrms.register_node(w, 1, SimTime(0.0));
            clues.track(w, PowerState::On);
        }
        (lrms, clues)
    }

    #[test]
    fn powers_on_for_pending_jobs_up_to_max() {
        let (mut lrms, mut clues) = setup(&["vnode-1", "vnode-2"]);
        for i in 0..50 {
            lrms.submit(&format!("j{i}"), 1, SimTime(0.0));
        }
        lrms.schedule(SimTime(0.0)); // fills both nodes
        let actions = clues.tick(SimTime(60.0), &lrms, &no_flap);
        // 48 pending, max_workers 5, 2 active → 3 more (the paper's AWS 3)
        assert_eq!(actions, vec![Action::PowerOn { count: 3 }]);
    }

    #[test]
    fn no_power_on_while_enough_incoming() {
        let (mut lrms, mut clues) = setup(&["vnode-1"]);
        clues.track("vnode-2", PowerState::PoweringOn);
        lrms.submit("a", 1, SimTime(0.0));
        lrms.schedule(SimTime(0.0));
        lrms.submit("b", 1, SimTime(1.0));
        // 1 pending, 1 incoming → no action.
        let actions = clues.tick(SimTime(60.0), &lrms, &no_flap);
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn powers_off_idle_nodes_after_timeout() {
        let (lrms, mut clues) = setup(&["vnode-1", "vnode-2"]);
        // Everything idle since t=0.
        let none = clues.tick(SimTime(100.0), &lrms, &no_flap);
        assert!(none.is_empty()); // not idle long enough
        let actions = clues.tick(SimTime(400.0), &lrms, &no_flap);
        assert_eq!(actions.len(), 2);
        assert!(actions.iter().all(|a| matches!(a,
            Action::PowerOff { .. })));
        assert_eq!(clues.state("vnode-1"), Some(PowerState::PoweringOff));
    }

    #[test]
    fn min_workers_respected_on_scale_down() {
        let (lrms, mut clues) = setup(&["vnode-1", "vnode-2"]);
        clues.cfg.min_workers = 1;
        let actions = clues.tick(SimTime(1000.0), &lrms, &no_flap);
        assert_eq!(actions.len(), 1, "{actions:?}");
    }

    #[test]
    fn cancels_pending_poweroff_when_jobs_arrive() {
        let (mut lrms, mut clues) = setup(&["vnode-1"]);
        clues.set_state("vnode-1", PowerState::PoweringOff);
        lrms.submit("late-job", 1, SimTime(500.0));
        let actions = clues.tick(SimTime(510.0), &lrms, &no_flap);
        assert!(actions.contains(&Action::CancelPowerOff {
            node: "vnode-1".into()
        }), "{actions:?}");
        // And it does NOT immediately also power on a new node, because
        // the returning node covers the single pending job.
        assert!(!actions.iter().any(|a| matches!(a,
            Action::PowerOn { .. })), "{actions:?}");
    }

    #[test]
    fn transient_down_marks_failed_after_threshold() {
        let (lrms, mut clues) = setup(&["vnode-5"]);
        let flap = |n: &str| n == "vnode-5";
        let a1 = clues.tick(SimTime(60.0), &lrms, &flap);
        assert!(a1.is_empty()); // first down poll: tolerated
        let a2 = clues.tick(SimTime(120.0), &lrms, &flap);
        assert_eq!(a2, vec![Action::MarkFailed { node: "vnode-5".into() }]);
        assert_eq!(clues.state("vnode-5"), Some(PowerState::Failed));
    }

    #[test]
    fn down_counter_resets_on_recovery() {
        let (lrms, mut clues) = setup(&["vnode-5"]);
        let flap = |n: &str| n == "vnode-5";
        clues.tick(SimTime(60.0), &lrms, &flap);
        clues.tick(SimTime(120.0), &lrms, &no_flap); // recovered
        let a3 = clues.tick(SimTime(180.0), &lrms, &flap);
        assert!(a3.is_empty()); // counter restarted
    }

    #[test]
    fn failed_node_replaced_when_jobs_pending() {
        let (mut lrms, mut clues) = setup(&["vnode-5"]);
        for i in 0..3 {
            lrms.submit(&format!("j{i}"), 1, SimTime(0.0));
        }
        lrms.schedule(SimTime(0.0));
        let flap = |n: &str| n == "vnode-5";
        clues.tick(SimTime(60.0), &lrms, &flap);
        let a2 = clues.tick(SimTime(120.0), &lrms, &flap);
        assert!(a2.contains(&Action::MarkFailed { node: "vnode-5".into() }));
        // vnode-5 no longer counts as capacity → power-on for the queue
        // (the paper: "since there are remaining jobs, CLUES powers it on
        // again").
        assert!(a2.iter().any(|a| matches!(a, Action::PowerOn { .. })),
                "{a2:?}");
    }

    #[test]
    fn respects_max_workers() {
        let (mut lrms, mut clues) = setup(&["w1", "w2", "w3", "w4", "w5"]);
        for i in 0..99 {
            lrms.submit(&format!("j{i}"), 1, SimTime(0.0));
        }
        lrms.schedule(SimTime(0.0));
        let actions = clues.tick(SimTime(60.0), &lrms, &no_flap);
        assert!(actions.is_empty(), "at max: {actions:?}");
    }

    #[test]
    fn id_and_name_apis_agree() {
        let (_lrms, mut clues) = setup(&["vnode-1"]);
        let id = clues.names.get("vnode-1").unwrap();
        assert_eq!(clues.state_id(id), Some(PowerState::On));
        clues.set_state_id(id, PowerState::PoweringOff);
        assert_eq!(clues.state("vnode-1"), Some(PowerState::PoweringOff));
        clues.forget_id(id);
        assert_eq!(clues.state("vnode-1"), None);
    }
}
