//! PJRT runtime: load the AOT-compiled audio classifier and serve
//! inference from the Rust request path (no Python at runtime).
//!
//! `make artifacts` writes `artifacts/audio_classifier_b{B}.hlo.txt`
//! (HLO text, parameters folded as constants) plus `MANIFEST.txt` with
//! shape metadata and a golden logit. This module compiles each artifact
//! once on the PJRT CPU client and executes it per job.
//!
//! The PJRT client comes from the external `xla` crate, which cannot be
//! vendored into the offline build environment. The real execution path
//! is therefore behind the `pjrt` cargo feature (which additionally
//! requires adding `xla` to `[dependencies]`); without it this module
//! keeps the exact same API but [`ModelRuntime::load`] reports the
//! runtime as unavailable and [`artifacts_available`] returns false so
//! tests and the simulator skip real inference gracefully.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

/// One artifact entry from MANIFEST.txt.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub path: String,
    pub batch: usize,
    pub n_frames: usize,
    pub n_bins: usize,
    pub n_classes: usize,
    pub param_count: u64,
    /// logits[0,0] for synth_clip(0) as computed by the JAX build path.
    pub golden0: f64,
}

/// Parse MANIFEST.txt.
pub fn read_manifest(dir: &Path) -> anyhow::Result<Vec<ManifestEntry>> {
    let path = dir.join("MANIFEST.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 8 {
            bail!("{path:?}:{}: expected 8 fields, got {}", i + 1, f.len());
        }
        out.push(ManifestEntry {
            name: f[0].to_string(),
            path: f[1].to_string(),
            batch: f[2].parse()?,
            n_frames: f[3].parse()?,
            n_bins: f[4].parse()?,
            n_classes: f[5].parse()?,
            param_count: f[6].parse()?,
            golden0: f[7].parse()?,
        });
    }
    Ok(out)
}

/// A compiled model executable bound to one batch size.
pub struct ModelRuntime {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    pub entry: ManifestEntry,
    /// Executions served (perf counter).
    pub executions: std::cell::Cell<u64>,
}

impl ModelRuntime {
    /// Load and compile the artifact for `batch` from `dir`.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>, batch: usize)
        -> anyhow::Result<ModelRuntime> {
        let dir = dir.as_ref();
        let entries = read_manifest(dir)?;
        let entry = entries
            .into_iter()
            .find(|e| e.batch == batch)
            .with_context(|| format!(
                "no artifact for batch size {batch} in {dir:?}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        let hlo_path: PathBuf = dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("artifact path not UTF-8")?)
            .map_err(|e| anyhow::anyhow!("parsing {hlo_path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {hlo_path:?}: {e}"))?;
        Ok(ModelRuntime { exe, entry, executions: std::cell::Cell::new(0) })
    }

    /// Stub without the `pjrt` feature: same signature, always errors.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>, batch: usize)
        -> anyhow::Result<ModelRuntime> {
        let _ = (dir.as_ref(), batch);
        bail!("PJRT runtime unavailable: build with `--features pjrt` \
               and an `xla` dependency (offline builds run the \
               simulation without real inference)")
    }

    /// Input element count per batch.
    #[cfg(feature = "pjrt")]
    fn input_len(&self) -> usize {
        self.entry.batch * self.entry.n_frames * self.entry.n_bins
    }

    /// Run inference on up to `batch` clips (each N_FRAMES*N_BINS long).
    /// Shorter batches are zero-padded; only the real rows are returned.
    #[cfg(feature = "pjrt")]
    pub fn infer(&self, clips: &[Vec<f32>])
        -> anyhow::Result<Vec<Vec<f32>>> {
        if clips.is_empty() || clips.len() > self.entry.batch {
            bail!("batch of {} clips does not fit executable batch {}",
                  clips.len(), self.entry.batch);
        }
        let clip_len = self.entry.n_frames * self.entry.n_bins;
        let mut flat = Vec::with_capacity(self.input_len());
        for c in clips {
            if c.len() != clip_len {
                bail!("clip has {} samples, expected {clip_len}", c.len());
            }
            flat.extend_from_slice(c);
        }
        flat.resize(self.input_len(), 0.0);

        let input = xla::Literal::vec1(&flat)
            .reshape(&[
                self.entry.batch as i64,
                self.entry.n_frames as i64,
                self.entry.n_bins as i64,
            ])
            .map_err(|e| anyhow::anyhow!("reshape input: {e}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let logits_lit = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        let logits = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("read logits: {e}"))?;
        self.executions.set(self.executions.get() + 1);
        Ok(clips
            .iter()
            .enumerate()
            .map(|(i, _)| {
                logits[i * self.entry.n_classes
                    ..(i + 1) * self.entry.n_classes]
                    .to_vec()
            })
            .collect())
    }

    /// Stub without the `pjrt` feature (unreachable in practice: `load`
    /// refuses to construct a runtime).
    #[cfg(not(feature = "pjrt"))]
    pub fn infer(&self, clips: &[Vec<f32>])
        -> anyhow::Result<Vec<Vec<f32>>> {
        let _ = clips;
        bail!("PJRT runtime unavailable (pjrt feature disabled)")
    }

    /// Classify one synthetic file by id (generates the clip in-process).
    pub fn infer_file(&self, file_id: u64) -> anyhow::Result<Vec<f32>> {
        let clip = crate::workload::synth_clip(file_id);
        Ok(self.infer(&[clip])?.remove(0))
    }

    /// Verify the runtime against the build-path golden logit.
    pub fn verify_golden(&self) -> anyhow::Result<f64> {
        let logits = self.infer_file(0)?;
        let got = logits[0] as f64;
        let want = self.entry.golden0;
        let err = (got - want).abs();
        if err > 1e-3 {
            bail!("golden mismatch: rust={got} jax={want} (|Δ|={err})");
        }
        Ok(err)
    }

    /// Top-k (class index, logit) pairs for a logit vector.
    pub fn top_k(logits: &[f32], k: usize) -> Vec<(usize, f32)> {
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.into_iter().take(k).map(|i| (i, logits[i])).collect()
    }
}

/// Default artifacts directory: $EVHC_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("EVHC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when the runtime can actually serve inference: the `pjrt`
/// feature is compiled in AND artifacts exist on disk. Tests and the
/// demo binaries skip PJRT paths otherwise.
pub fn artifacts_available() -> bool {
    cfg!(feature = "pjrt")
        && artifacts_dir().join("MANIFEST.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing_roundtrip() {
        let dir = std::env::temp_dir().join("evhc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("MANIFEST.txt"),
            "audio_classifier_b1 audio_classifier_b1.hlo.txt 1 96 257 527 \
             781391 2.302364731e1\n",
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].batch, 1);
        assert_eq!(m[0].n_classes, 527);
        assert!((m[0].golden0 - 23.02364731).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_rejects_malformed() {
        let dir = std::env::temp_dir().join("evhc_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("MANIFEST.txt"), "too few fields\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn top_k_orders_descending() {
        let logits = vec![0.1, 5.0, -2.0, 3.0];
        let top = ModelRuntime::top_k(&logits, 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 3);
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // skip gracefully when artifacts are missing.
}
