//! The control-plane half of the cluster world, dispatched serially on
//! the control shard (a barrier point of the sharded engines).
//!
//! [`ControlWorld`] owns every piece of cross-site state: the
//! orchestrator workflow engine, the LRMS controller, CLUES, the
//! elasticity broker, the vRouter overlay + CA, the IM (networks,
//! tunnel fabric), the workload queue, per-VM accounting and the
//! control recorder shard. Under the [`ControlPlane`] contract it may
//! read and mutate any [`SiteWorld`] while handling a control event
//! (provisioning VMs, reclaiming them in scenario waves, reading
//! broker signals) and may schedule commands into any site shard —
//! but all *site-originated* effects arrive here as control events
//! emitted with the configured WAN latency, never as direct mutation.

use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;

use crate::broker::{ElasticityBroker, ScenarioEvent, Score};
use crate::clues::{Action, Clues, PowerState};
use crate::cloudsim::VmId;
use crate::ids::{NodeId, NodeNames};
use crate::im::{Im, NodeRole};
use crate::lrms::{JobId, Lrms, NodeHealth, NodeStat};
use crate::metrics::{DisplayState, Recorder};
use crate::netsim::Network;
use crate::obs::{MetricsRegistry, TraceShard};
use crate::orchestrator::{UpdateId, UpdateOp, WorkflowEngine};
use crate::runtime::ModelRuntime;
use crate::sim::shard::ControlPlane;
use crate::sim::{ShardedQueue, SimTime};
use crate::util::prng::Prng;
use crate::vrouter::Overlay;
use crate::workload::trace::{SynthSource, TraceFeed};
use crate::workload::Workload;

use super::dispatch::{DispatchJob, DispatchLrmsView, DispatchMode,
                      DispatchRun, Dispatcher, DoneOutcome,
                      StartOutcome};
use super::faults::{ResolvedWindow, SiteHealthTracker};
use super::{Ev, RunConfig, SiteWorld, FE_NAME};

/// Runtime info per deployment node (controller's view).
#[derive(Debug, Clone, Copy)]
pub(crate) struct NodeRt {
    pub(crate) site: usize,
    pub(crate) vm: VmId,
    pub(crate) role: NodeRole,
    /// One-time udocker setup already paid?
    setup_done: bool,
    requested_at: SimTime,
    joined_at: Option<SimTime>,
}

/// Provisioning-retry record for one node (chaos mode only).
#[derive(Debug, Clone, Copy)]
struct RetryRec {
    /// Failed attempts so far.
    attempt: u32,
    /// Site of the first attempt — excluded once `failover_after`
    /// attempts have failed, so the broker ranks the alternatives.
    first_site: usize,
    /// A `RetryProvision` event is already scheduled (guards against
    /// duplicated `BootFailed` reports double-scheduling retries).
    pending: bool,
}

/// One VM incarnation's accounting row (ledger row index at its site).
#[derive(Debug, Clone)]
pub(crate) struct VmRec {
    pub(crate) name: String,
    pub(crate) site: usize,
    pub(crate) role: NodeRole,
    pub(crate) ledger_idx: usize,
    pub(crate) busy_secs: f64,
}

/// Weight of one tick's observation in the health EWMA (the
/// complement stays on the previous score).
const HEALTH_GAIN: f64 = 0.3;
/// Stress contributed per site→control message dropped since the
/// previous tick.
const HEALTH_DROP_WEIGHT: f64 = 0.2;
/// Stress per retransmission (a drop the reliable layer had to repair).
const HEALTH_RETRANSMIT_WEIGHT: f64 = 0.1;
/// Stress per backed-off provisioning retry attributed to the site.
const HEALTH_RETRY_WEIGHT: f64 = 0.5;
/// Stress of sitting in quarantine for the whole tick.
const HEALTH_QUARANTINE_STRESS: f64 = 3.0;

/// One deterministic health-EWMA step: fold the fault telemetry a site
/// accumulated since the previous CLUES tick into its score. The
/// instantaneous observation is `1 / (1 + stress)` (exactly 1.0 on a
/// calm tick), blended as `prev + HEALTH_GAIN * (instant - prev)` — a
/// fully healthy site stays at exactly 1.0 (no drift), a faulty one
/// decays geometrically toward the observation, and a recovering one
/// climbs back the same way. Pure `f64` arithmetic on deterministic
/// counters, so the trajectory is byte-identical across engines.
pub(crate) fn ewma_health(prev: f64, drops: u64, retransmits: u64,
                          retries: u64, quarantined: bool) -> f64 {
    let stress = drops as f64 * HEALTH_DROP_WEIGHT
        + retransmits as f64 * HEALTH_RETRANSMIT_WEIGHT
        + retries as f64 * HEALTH_RETRY_WEIGHT
        + if quarantined { HEALTH_QUARANTINE_STRESS } else { 0.0 };
    let instant = 1.0 / (1.0 + stress);
    let prev = prev.clamp(0.0, 1.0);
    (prev + HEALTH_GAIN * (instant - prev)).clamp(0.0, 1.0)
}

/// Render a broker candidate ranking as `site:primary-score` pairs,
/// best first, for a `broker.decision` trace annotation.
fn fmt_ranked(ranked: &[(usize, Score)]) -> String {
    let mut s = String::from("[");
    for (k, (site, sc)) in ranked.iter().enumerate() {
        if k > 0 {
            s.push(' ');
        }
        let _ = write!(s, "{site}:{:.6}", sc.primary);
    }
    s.push(']');
    s
}

/// The cross-site control plane.
pub struct ControlWorld {
    pub cfg: RunConfig,
    pub net: Network,
    pub overlay: Overlay,
    pub lrms: Box<dyn Lrms>,
    pub clues: Clues,
    pub engine: WorkflowEngine,
    pub im: Im,
    /// Multi-site elasticity broker (owns grow-to-which-site).
    pub broker: ElasticityBroker,
    /// Partitioned-dispatch route queue + lease table (`Some` iff
    /// `cfg.dispatch == DispatchMode::Partitioned`). When present,
    /// sites schedule their own jobs and the central `lrms` tracks
    /// only node membership and health; every queue-depth read goes
    /// through [`Dispatcher::unplaced`] / [`DispatchLrmsView`].
    pub(crate) dispatch: Option<Dispatcher>,
    /// The control shard's metrics stream.
    pub(crate) recorder: Recorder,
    /// The control shard's causal-trace sink (shard 0). Off — and
    /// free — unless `cfg.obs.trace`; see `crate::obs` for the
    /// digest-neutrality contract.
    pub(crate) trace: TraceShard,
    /// On-clock gauge sampler, driven from the CluesTick handler only.
    /// Off unless `cfg.obs.metrics`.
    pub(crate) metrics: MetricsRegistry,
    /// Cluster-wide name⇄id interner (shared with lrms/clues/recorders).
    pub(crate) names: NodeNames,
    pub(crate) nodes: HashMap<NodeId, NodeRt>,
    /// node → in-progress AddWorker update to complete on join.
    update_for_node: HashMap<NodeId, UpdateId>,
    /// Permanent archive of (node, requested, joined) — survives node
    /// termination, unlike the live `nodes` map.
    pub(crate) deploy_log: Vec<(String, SimTime, SimTime)>,
    /// One accounting record per VM incarnation (ledger row index).
    pub(crate) vm_records: Vec<VmRec>,
    /// node → index into vm_records for the live incarnation.
    live_record: HashMap<NodeId, usize>,
    /// Streaming arrival frontend: blocks are pulled from the trace
    /// source up to `cfg.ingest_watermark_jobs` ahead of the clock and
    /// scheduled as `Ev::SubmitBlock` control events, so the workload
    /// never materializes beyond the watermark. All pulls happen in
    /// control handlers, stamped on the sim clock — byte-identical
    /// across engines.
    pub(crate) feed: TraceFeed,
    /// jobs submitted so far / completed.
    jobs_submitted: u32,
    pub(crate) jobs_completed: u32,
    next_file_id: u64,
    rng: Prng,
    fe_site: usize,
    fe_ready: bool,
    initial_pending: u32,
    deploy_update: Option<UpdateId>,
    /// Optional real-inference runtime.
    runtime: Option<ModelRuntime>,
    pub(crate) inferences_run: u64,
    pub(crate) inference_wall_secs: f64,
    clues_ticking: bool,
    /// When the initial cluster came up (workload + injection t=0).
    workload_t0: SimTime,
    /// Jobs requeued by a preemption/outage, awaiting completion.
    preempt_pending: HashSet<JobId>,
    pub(crate) preempted_vms: u32,
    pub(crate) preempted_jobs: u32,
    pub(crate) preempt_recovered: u32,
    /// Active price-spike windows per site: the latest spike's factor
    /// rules while any window is open; list price returns only when
    /// the count drains to zero (overlapping spikes compose).
    price_spikes_active: Vec<u32>,
    /// Scratch buffer for per-tick node snapshots (reused; a 10k-node
    /// tick allocates no per-tick `Vec`).
    stats_scratch: Vec<NodeStat>,
    n_sites: usize,
    control_latency: f64,
    /// The WAN chaos layer is live for this run (a fault plan, a
    /// scenario WAN partition, or a spec-level message loss rate).
    /// When false every chaos code path is skipped, so pre-chaos runs
    /// keep their event streams — and digests — bit for bit.
    chaos: bool,
    /// Dedicated stream for retry-backoff jitter. Separate from the
    /// main `rng` so enabling chaos never perturbs boot/job sampling.
    chaos_rng: Prng,
    /// Per-site circuit breakers fed by heartbeat outcomes.
    breakers: Vec<SiteHealthTracker>,
    /// Heartbeat pings sent to a site and not yet answered.
    hb_outstanding: Vec<u32>,
    /// Nesting count of active WAN partitions per site (scripted
    /// windows and scenario events may overlap).
    partition_depth: Vec<u32>,
    /// Circuit breaker open: the site is quarantined.
    quarantined: Vec<bool>,
    /// When each open quarantine window started (for `quarantine_secs`
    /// accounting; still-open windows are closed at the makespan).
    pub(crate) quarantine_opened_at: Vec<Option<f64>>,
    /// Per-site exponentially-decayed health score in `[0, 1]` (1.0 =
    /// fully healthy), refreshed each CLUES tick from the fault
    /// telemetry observed since the previous tick and published to the
    /// broker ([`crate::broker::SiteSignals::health`]).
    pub(crate) health: Vec<f64>,
    /// Fault-counter snapshots from the previous health refresh:
    /// (messages dropped, retransmissions, provisioning retries).
    health_seen: Vec<(u64, u64, u64)>,
    /// Provisioning retries attributed per site (the site of the first
    /// failed attempt).
    site_retries: Vec<u64>,
    /// Lowest health each site ever reached (trajectory floor).
    pub(crate) health_min: Vec<f64>,
    /// When each site's health first crossed the de-rank threshold
    /// ([`crate::broker::policy::health_deranked`]), if ever.
    pub(crate) health_deranked_at: Vec<Option<f64>>,
    /// When each site's circuit breaker first opened, if ever (the
    /// de-rank must beat this for adaptive placement to matter).
    pub(crate) first_quarantine_at: Vec<Option<f64>>,
    /// Correlated per-site partition windows installed (plan region
    /// groups + scenario regional outages, one per member site).
    pub(crate) regional_windows: u32,
    /// In-flight provisioning retries, keyed by node.
    retry_state: HashMap<NodeId, RetryRec>,
    /// Jobs requeued by a quarantine lease revocation, awaiting
    /// completion elsewhere.
    chaos_pending: HashSet<JobId>,
    /// Fatal configuration error detected at workload start (e.g. a
    /// fault plan targeting the front-end site). `run()` surfaces it.
    pub(crate) fatal: Option<String>,
    pub(crate) provision_retries: u32,
    pub(crate) provision_failovers: u32,
    pub(crate) quarantine_windows: u32,
    pub(crate) quarantine_secs: f64,
    pub(crate) lease_requeued: u32,
    pub(crate) lease_recovered: u32,
}

impl ControlWorld {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build(
        cfg: RunConfig,
        net: Network,
        overlay: Overlay,
        lrms: Box<dyn Lrms>,
        clues: Clues,
        engine: WorkflowEngine,
        im: Im,
        broker: ElasticityBroker,
        recorder: Recorder,
        names: NodeNames,
        runtime: Option<ModelRuntime>,
        rng: Prng,
        n_sites: usize,
        control_latency: f64,
    ) -> ControlWorld {
        let mut cfg = cfg;
        // Arrival frontend: an explicit trace source, or the materialized
        // workload wrapped in `SynthSource` (block-for-block identical by
        // construction). The streaming path is the only submission path.
        let source = cfg.source.take().unwrap_or_else(|| {
            Box::new(SynthSource::new(cfg.workload.clone()))
        });
        let feed = TraceFeed::new(source, cfg.ingest_watermark_jobs);
        let chaos = !cfg.faults.is_empty()
            || cfg.scenario.events.iter().any(|e| {
                matches!(e, ScenarioEvent::WanPartition { .. }
                         | ScenarioEvent::RegionalOutage { .. })
            })
            || cfg.sites.iter().any(|s| s.failure.message_loss_prob > 0.0);
        let chaos_rng = Prng::new(cfg.seed ^ 0xFA57_C8A0);
        let trace = TraceShard::new(0, cfg.obs.trace);
        let metrics = MetricsRegistry::new(cfg.obs.metrics);
        let breakers = vec![
            SiteHealthTracker::new(cfg.retry.quarantine_after);
            n_sites
        ];
        let dispatch = (cfg.dispatch == DispatchMode::Partitioned)
            .then(|| Dispatcher::new(n_sites));
        ControlWorld {
            cfg,
            net,
            overlay,
            lrms,
            clues,
            engine,
            im,
            broker,
            dispatch,
            recorder,
            trace,
            metrics,
            names,
            nodes: HashMap::new(),
            update_for_node: HashMap::new(),
            deploy_log: Vec::new(),
            vm_records: Vec::new(),
            live_record: HashMap::new(),
            feed,
            jobs_submitted: 0,
            jobs_completed: 0,
            next_file_id: 0,
            rng,
            fe_site: 0,
            fe_ready: false,
            initial_pending: 0,
            deploy_update: None,
            runtime,
            inferences_run: 0,
            inference_wall_secs: 0.0,
            clues_ticking: false,
            workload_t0: SimTime::ZERO,
            preempt_pending: HashSet::new(),
            preempted_vms: 0,
            preempted_jobs: 0,
            preempt_recovered: 0,
            price_spikes_active: vec![0; n_sites],
            stats_scratch: Vec::new(),
            n_sites,
            control_latency,
            chaos,
            chaos_rng,
            breakers,
            hb_outstanding: vec![0; n_sites],
            partition_depth: vec![0; n_sites],
            quarantined: vec![false; n_sites],
            quarantine_opened_at: vec![None; n_sites],
            health: vec![1.0; n_sites],
            health_seen: vec![(0, 0, 0); n_sites],
            site_retries: vec![0; n_sites],
            health_min: vec![1.0; n_sites],
            health_deranked_at: vec![None; n_sites],
            first_quarantine_at: vec![None; n_sites],
            regional_windows: 0,
            retry_state: HashMap::new(),
            chaos_pending: HashSet::new(),
            fatal: None,
            provision_retries: 0,
            provision_failovers: 0,
            quarantine_windows: 0,
            quarantine_secs: 0.0,
            lease_requeued: 0,
            lease_recovered: 0,
        }
    }

    /// Hand the control shard's trace buffer to the run assembler
    /// (leaves a permanently-off sink behind).
    pub(crate) fn take_trace(&mut self) -> TraceShard {
        std::mem::replace(&mut self.trace, TraceShard::off(0))
    }

    /// Hand the gauge samples to the run assembler.
    pub(crate) fn take_metrics(&mut self) -> MetricsRegistry {
        std::mem::take(&mut self.metrics)
    }

    // ---------------------------------------------------------------
    // Deployment plumbing
    // ---------------------------------------------------------------

    fn worker_instance_type(&self, sites: &[SiteWorld], site: usize)
        -> String {
        // The shared SiteSpec selector — also what prices the broker's
        // CostMin/SpotAware table, so ranking and billing agree.
        let want = &self.cfg.template.worker;
        sites[site]
            .cloud
            .spec
            .worker_instance_type(want.num_cpus, want.mem_gb)
            .name
            .clone()
    }

    fn vrouter_instance_type(&self, sites: &[SiteWorld], site: usize)
        -> String {
        // Cheapest instance in the catalog (t2.micro at AWS).
        sites[site]
            .cloud
            .spec
            .instance_types
            .iter()
            .min_by(|a, b| {
                a.price
                    .usd_per_hour
                    .partial_cmp(&b.price.usd_per_hour)
                    .unwrap()
                    .then(a.vcpus.cmp(&b.vcpus))
            })
            .map(|t| t.name.clone())
            .unwrap()
    }

    /// Provision one node at `site` and schedule its boot completion
    /// (plus sampled stochastic crash/spot-reclaim timers) into the
    /// site's shard.
    fn provision(&mut self, q: &mut ShardedQueue<Ev>,
                 sites: &mut [SiteWorld], site: usize, name: &str,
                 role: NodeRole, t: SimTime) -> anyhow::Result<()> {
        let id = self.names.intern(name);
        let itype = match role {
            NodeRole::FrontEnd => self.worker_instance_type(sites, site),
            NodeRole::WorkerNode => self.worker_instance_type(sites, site),
            NodeRole::SiteVRouter => {
                self.vrouter_instance_type(sites, site)
            }
        };
        let cloud = &mut sites[site].cloud;
        let (_net_id, net_secs) =
            self.im.ensure_network(cloud, site, "evhc")?;
        let p = self.im.provision_node(
            cloud,
            site,
            "evhc",
            name,
            role,
            &itype,
            self.cfg.template.lrms,
            t,
        )?;
        self.nodes.insert(id, NodeRt {
            site,
            vm: p.vm,
            role,
            setup_done: false,
            requested_at: t,
            joined_at: None,
        });
        self.live_record.insert(id, self.vm_records.len());
        self.vm_records.push(VmRec {
            name: name.to_string(),
            site,
            role,
            ledger_idx: cloud.ledger.entries.len() - 1,
            busy_secs: 0.0,
        });
        self.recorder.node_state_id(t, id, DisplayState::PoweringOn);
        if self.trace.enabled() {
            self.trace.instant(t, "node", "node.requested", format!(
                "node={name} site={site} role={role:?}"));
        }
        let boot_at = t.0 + net_secs + p.boot_secs;
        q.schedule_at(SimTime(boot_at), Ev::BootDone {
            site,
            vm: p.vm,
            node: id,
            failed: p.boot_fails,
            ctx_secs: p.ctx_secs,
        });
        // Stochastic failure injection: sample a time-to-failure (and,
        // for non-FE roles, a spot-reclaim time) from the site's
        // failure model, anchored at boot completion. Timers for VMs
        // that die first are dropped at the site (crash_vm rejects
        // non-running states).
        let failure = cloud.spec.failure.clone();
        if let Some(secs) = failure.sample_crash_in(&mut self.rng) {
            q.schedule_at(SimTime(boot_at + secs), Ev::CrashTimer {
                site,
                vm: p.vm,
                node: id,
                preempt: false,
            });
        }
        if role != NodeRole::FrontEnd {
            // The FE survives spot reclaims: it is the cluster's fixed
            // point (LRMS controller + vRouter CP).
            if let Some(secs) = failure.sample_preempt_in(&mut self.rng) {
                q.schedule_at(SimTime(boot_at + secs), Ev::CrashTimer {
                    site,
                    vm: p.vm,
                    node: id,
                    preempt: true,
                });
            }
        }
        Ok(())
    }

    /// Does `site` already host a live vRouter (or the CP)?
    fn site_has_router(&self, site: usize) -> bool {
        if site == self.fe_site && self.fe_ready {
            return true;
        }
        self.nodes.values().any(|rt| {
            rt.site == site
                && rt.role == NodeRole::SiteVRouter
                && rt.joined_at.is_some()
        })
    }

    fn vrouter_name(&self, sites: &[SiteWorld], site: usize) -> String {
        format!("vrouter-{}", sites[site].cloud.spec.name.to_lowercase())
    }

    /// Lowest unused worker index → "vnode-N" (names are reused after
    /// termination, matching the paper's vnode-5 power-off/on cycle).
    fn next_worker(&self) -> (NodeId, String) {
        for i in 1.. {
            let name = format!("vnode-{i}");
            let id = self.names.intern(&name);
            if !self.nodes.contains_key(&id) {
                return (id, name);
            }
        }
        unreachable!()
    }

    fn used_workers_per_site(&self) -> Vec<u32> {
        let mut v = vec![0u32; self.n_sites];
        for rt in self.nodes.values() {
            // Placeholder entries (PowerOn reserved the name but no site
            // was chosen yet) have site == usize::MAX.
            if rt.role == NodeRole::WorkerNode && rt.site < v.len() {
                v[rt.site] += 1;
            }
        }
        v
    }

    /// Start adding a worker (one orchestrator update). Returns false if
    /// no site has capacity. Under chaos, WAN-partitioned sites are
    /// excluded from broker placement: a command sent into a partition
    /// would vanish.
    fn start_add_worker(&mut self, q: &mut ShardedQueue<Ev>,
                        sites: &mut [SiteWorld], name: &str,
                        t: SimTime) -> bool {
        let used = self.used_workers_per_site();
        let cpus = self.cfg.template.worker.num_cpus;
        let queue_depth = self.pending_depth() as u32;
        // Under chaos, WAN-partitioned sites are masked out: a command
        // sent into a partition would vanish.
        let excluded: Option<Vec<bool>> = (self.cfg.template.hybrid
            && self.chaos)
            .then(|| {
                (0..self.n_sites)
                    .map(|s| self.partition_depth[s] > 0)
                    .collect()
            });
        let site = if self.cfg.template.hybrid {
            let picked = match &excluded {
                Some(e) => self.broker.select_excluding(
                    sites, &used, cpus, queue_depth, t, e),
                None => {
                    self.broker.select(sites, &used, cpus, queue_depth, t)
                }
            };
            if self.trace.enabled() {
                let ranked = self.broker.ranked_candidates(
                    sites, &used, cpus, queue_depth, excluded.as_deref());
                self.trace.instant(t, "broker", "broker.decision",
                    format!("node={name} picked={picked:?} \
                             queue={queue_depth} ranked={}",
                            fmt_ranked(&ranked)));
            }
            picked
        } else {
            // Non-hybrid: only the FE's site may host workers.
            let s = self.fe_site;
            let cloud = &sites[s].cloud;
            let fits = cloud.used_vms() < cloud.spec.quota.max_vms
                && cloud.used_vcpus() + cpus <= cloud.spec.quota.max_vcpus;
            fits.then_some(s)
        };
        let Some(site) = site else {
            self.recorder.milestone(t, format!(
                "no capacity anywhere for {name}"));
            return false;
        };
        self.place_worker(q, sites, name, site, t)
    }

    /// Provision `name` as a worker at the chosen `site` (bringing up a
    /// site vRouter first when bursting into a router-less site).
    fn place_worker(&mut self, q: &mut ShardedQueue<Ev>,
                    sites: &mut [SiteWorld], name: &str, site: usize,
                    t: SimTime) -> bool {
        // Bursting into a router-less site: vRouter first (plus one more
        // VM of quota), then the worker.
        if site != self.fe_site && !self.site_has_router(site) {
            let vr = self.vrouter_name(sites, site);
            let vr_id = self.names.intern(&vr);
            if !self.nodes.contains_key(&vr_id) {
                if let Err(e) = self.provision(q, sites, site, &vr,
                                               NodeRole::SiteVRouter, t) {
                    self.recorder.milestone(t, format!(
                        "vRouter provision failed at {}: {e}",
                        sites[site].cloud.spec.name));
                    return false;
                }
                self.recorder.milestone(t, format!(
                    "provisioning {vr} at {}",
                    sites[site].cloud.spec.name));
            }
        }
        match self.provision(q, sites, site, name, NodeRole::WorkerNode, t)
        {
            Ok(()) => {
                self.recorder.milestone(t, format!(
                    "provisioning {name} at {}",
                    sites[site].cloud.spec.name));
                true
            }
            Err(e) => {
                self.recorder.milestone(t, format!(
                    "worker provision failed: {e}"));
                false
            }
        }
    }

    // ---------------------------------------------------------------
    // Chaos self-healing: provisioning retries, heartbeats, quarantine
    // ---------------------------------------------------------------

    /// A provisioning attempt for `node` failed: schedule a backed-off
    /// retry. Returns false when the retry budget is exhausted (the
    /// caller falls back to the legacy give-up path). Duplicate
    /// `BootFailed` deliveries are absorbed by the `pending` flag.
    fn schedule_provision_retry(&mut self, q: &mut ShardedQueue<Ev>,
                                node: NodeId, first_site: usize,
                                t: SimTime) -> bool {
        let (attempt, give_up) = {
            let rec = self.retry_state.entry(node).or_insert(RetryRec {
                attempt: 0,
                first_site,
                pending: false,
            });
            if rec.pending {
                return true; // duplicate report of the same failure
            }
            rec.attempt += 1;
            (rec.attempt, rec.attempt >= self.cfg.retry.max_attempts)
        };
        let name = self.names.name(node);
        if give_up {
            self.retry_state.remove(&node);
            self.recorder.milestone(t, format!(
                "giving up on {name} after {attempt} provisioning \
                 attempts"));
            return false;
        }
        if let Some(rec) = self.retry_state.get_mut(&node) {
            rec.pending = true;
        }
        let delay = self.cfg.retry.backoff(attempt - 1,
                                           &mut self.chaos_rng);
        self.provision_retries += 1;
        if first_site < self.n_sites {
            self.site_retries[first_site] += 1;
        }
        self.recorder.milestone(t, format!(
            "{name} provisioning attempt {attempt} failed — retrying \
             in {delay:.0}s"));
        if self.trace.enabled() {
            self.trace.instant(t, "node", "node.retry", format!(
                "node={name} attempt={attempt} backoff_s={delay:.3}"));
        }
        q.schedule_in(delay, Ev::RetryProvision { node });
        true
    }

    /// Any message from `s` proves the WAN path is alive: clear the
    /// outstanding-heartbeat count and feed the circuit breaker (two
    /// half-open reports close it and lift the quarantine).
    fn note_site_alive(&mut self, q: &mut ShardedQueue<Ev>,
                       sites: &mut [SiteWorld], s: usize, t: SimTime) {
        if s >= self.n_sites || s == self.fe_site {
            return;
        }
        self.hb_outstanding[s] = 0;
        if self.breakers[s].report() {
            self.close_quarantine(q, sites, s, t);
        }
    }

    /// Count unanswered heartbeats; trip the breaker into quarantine
    /// after `quarantine_after` consecutive misses.
    fn heartbeat_scan(&mut self, q: &mut ShardedQueue<Ev>,
                      sites: &mut [SiteWorld], t: SimTime) {
        for s in 0..self.n_sites {
            if s == self.fe_site || self.hb_outstanding[s] == 0 {
                continue;
            }
            if self.breakers[s].miss() {
                self.open_quarantine(q, sites, s, t);
            }
        }
    }

    /// Probe every remote site that currently hosts joined nodes. The
    /// ping rides the site shard (command latency), the reply crosses
    /// the fault layer — so sustained loss starves the breaker.
    fn send_heartbeats(&mut self, q: &mut ShardedQueue<Ev>, t: SimTime) {
        let _ = t;
        let mut present = vec![false; self.n_sites];
        for rt in self.nodes.values() {
            if rt.site < self.n_sites && rt.joined_at.is_some() {
                present[rt.site] = true;
            }
        }
        for s in 0..self.n_sites {
            if s == self.fe_site || !present[s] {
                continue;
            }
            self.hb_outstanding[s] += 1;
            q.schedule_in(self.control_latency,
                          Ev::HeartbeatPing { site: s });
        }
    }

    /// One health refresh (each CLUES tick under chaos): fold the
    /// fault telemetry every site accumulated since the previous tick
    /// into its EWMA score ([`ewma_health`]) and publish the result to
    /// the broker, so `HealthAware` placement sees a degrading site
    /// decay in ranking before its breaker ever opens. Reading the
    /// site-shard fault counters here is safe: CLUES ticks are control
    /// events, which dispatch at barrier points of every engine.
    fn update_health(&mut self, sites: &[SiteWorld], t: SimTime) {
        for s in 0..self.n_sites {
            let drops = sites[s].faults.dropped;
            let rts = sites[s].faults.retransmits;
            let retries = self.site_retries[s];
            let (d0, r0, p0) = self.health_seen[s];
            self.health_seen[s] = (drops, rts, retries);
            let h = ewma_health(self.health[s],
                                drops - d0,
                                rts - r0,
                                retries - p0,
                                self.quarantined[s]);
            self.health[s] = h;
            if h < self.health_min[s] {
                self.health_min[s] = h;
            }
            if self.health_deranked_at[s].is_none()
                && crate::broker::policy::health_deranked(h)
            {
                self.health_deranked_at[s] = Some(t.0);
                self.recorder.milestone(t, format!(
                    "{} health down to {h:.3} — de-ranked for \
                     placement", sites[s].cloud.spec.name));
                if self.trace.enabled() {
                    self.trace.instant(t, "broker", "health.deranked",
                        format!("site={s} health={h:.6}"));
                }
            }
            self.broker.set_health(s, h);
        }
    }

    /// Sample the on-clock gauge grid (once per CLUES tick): the
    /// cluster-wide queue depth and completion count plus, per site,
    /// worker counts, the health score, the open-ledger burn rate and
    /// the cumulative WAN chaos counters. Runs in the CluesTick handler
    /// — a control event, dispatched at a global barrier of every
    /// engine — so the cross-shard reads are race-free and the series
    /// is byte-identical however the run was parallelized. Purely
    /// passive: reads only, so digests are untouched.
    fn sample_metrics(&mut self, sites: &[SiteWorld], t: SimTime) {
        self.metrics.sample_cluster(t, "queue_depth",
                                    self.pending_depth() as f64);
        self.metrics.sample_cluster(t, "jobs_completed",
                                    self.jobs_completed as f64);
        let mut joined = vec![0u32; self.n_sites];
        let mut booting = vec![0u32; self.n_sites];
        for rt in self.nodes.values() {
            if rt.role != NodeRole::WorkerNode || rt.site >= self.n_sites
            {
                continue;
            }
            if rt.joined_at.is_some() {
                joined[rt.site] += 1;
            } else {
                booting[rt.site] += 1;
            }
        }
        for s in 0..self.n_sites {
            let m = &mut self.metrics;
            m.sample(t, s as u32, "workers_joined", joined[s] as f64);
            m.sample(t, s as u32, "workers_booting", booting[s] as f64);
            m.sample(t, s as u32, "health", self.health[s]);
            m.sample(t, s as u32, "burn_usd_per_hour",
                     sites[s].cloud.ledger.open_rate_usd_per_hour());
            let (d, du, r) = sites[s].faults.counters();
            m.sample(t, s as u32, "wan_dropped", d as f64);
            m.sample(t, s as u32, "wan_duplicated", du as f64);
            m.sample(t, s as u32, "wan_retransmits", r as f64);
        }
    }

    /// Trip the circuit breaker for `s`: the broker treats the site as
    /// dark, its leased jobs requeue elsewhere, and its nodes are held
    /// down until the site reports in again.
    fn open_quarantine(&mut self, q: &mut ShardedQueue<Ev>,
                       sites: &mut [SiteWorld], s: usize, t: SimTime) {
        if self.quarantined[s] {
            return;
        }
        self.quarantined[s] = true;
        self.broker.set_quarantine(s, true);
        self.quarantine_windows += 1;
        self.quarantine_opened_at[s] = Some(t.0);
        if self.first_quarantine_at[s].is_none() {
            self.first_quarantine_at[s] = Some(t.0);
        }
        self.recorder.milestone(t, format!(
            "{} silent for {} heartbeats — quarantined, requeuing its \
             leased jobs elsewhere", sites[s].cloud.spec.name,
            self.cfg.retry.quarantine_after));
        if self.trace.enabled() {
            self.trace.instant(t, "chaos", "breaker.open", format!(
                "site={s} ({})", sites[s].cloud.spec.name));
        }
        let mut victims: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|(_, rt)| {
                rt.site == s
                    && rt.role == NodeRole::WorkerNode
                    && rt.joined_at.is_some()
            })
            .map(|(&id, _)| id)
            .collect();
        victims.sort();
        for id in victims {
            let name = self.names.name(id);
            let requeued = self
                .lrms
                .set_node_health(&name, NodeHealth::Down, t)
                .unwrap_or_default();
            for j in requeued {
                if self.chaos_pending.insert(j) {
                    self.lease_requeued += 1;
                }
            }
            self.recorder.node_state_id(t, id, DisplayState::Failed);
        }
        if let Some(d) = self.dispatch.as_mut() {
            // Partitioned: revoke every lease the quarantined site
            // holds. The jobs re-route elsewhere under a fresh epoch
            // (at this event's barrier tail), so everything the dark
            // site still reports about them — including a zombie
            // completion — is stale on arrival.
            let revoked = d.reroute_site(s, t.0);
            for j in revoked {
                if self.chaos_pending.insert(j) {
                    self.lease_requeued += 1;
                }
            }
        }
        self.pump_jobs(q, t);
    }

    /// The breaker closed (the site answered again): lift the
    /// quarantine and revive its held-down nodes.
    fn close_quarantine(&mut self, q: &mut ShardedQueue<Ev>,
                        sites: &mut [SiteWorld], s: usize, t: SimTime) {
        if !self.quarantined[s] {
            return;
        }
        self.quarantined[s] = false;
        self.broker.set_quarantine(s, false);
        if let Some(opened) = self.quarantine_opened_at[s].take() {
            self.quarantine_secs += t.0 - opened;
        }
        self.recorder.milestone(t, format!(
            "{} back in contact — quarantine lifted",
            sites[s].cloud.spec.name));
        if self.trace.enabled() {
            self.trace.instant(t, "chaos", "breaker.close", format!(
                "site={s} ({})", sites[s].cloud.spec.name));
        }
        let mut held: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|(_, rt)| {
                rt.site == s
                    && rt.role == NodeRole::WorkerNode
                    && rt.joined_at.is_some()
            })
            .map(|(&id, _)| id)
            .collect();
        held.sort();
        for id in held {
            let name = self.names.name(id);
            let down = self
                .lrms
                .node_stat(id)
                .map(|st| st.health == NodeHealth::Down)
                .unwrap_or(false);
            if down && !self.reported_down(&name, t) {
                let _ = self.lrms.set_node_health(&name,
                                                  NodeHealth::Up, t);
                // Reset the CLUES down-streak so the revived node is
                // not immediately re-failed by stale counts.
                self.clues.set_state_id(id, PowerState::On);
                let idle = self
                    .lrms
                    .node_stat(id)
                    .map(|st| st.is_idle())
                    .unwrap_or(true);
                self.recorder.node_state_id(t, id,
                    if idle { DisplayState::Idle }
                    else { DisplayState::Used });
            }
        }
        self.pump_jobs(q, t);
    }

    // ---------------------------------------------------------------
    // Job plumbing
    // ---------------------------------------------------------------

    /// The initial cluster is up: anchor the workload timeline here
    /// (the paper's "15:00") and start the CLUES monitor loop.
    fn begin_workload(&mut self, q: &mut ShardedQueue<Ev>,
                      sites: &mut [SiteWorld], t: SimTime) {
        self.workload_t0 = t;
        // The front end is placed by now, so fault plans can finally be
        // checked against it: a "WAN" fault at the FE site is
        // meaningless (control and site share a LAN there) and almost
        // certainly a misconfigured plan. Fail the run loudly instead
        // of silently misbehaving — no workload is scheduled, the queue
        // drains, and `run()` returns the error.
        if self.chaos {
            if let Some(msg) = self.fe_fault_conflict(sites) {
                self.recorder.milestone(t, format!("FATAL: {msg}"));
                self.fatal = Some(msg);
                return;
            }
            self.install_fault_windows(q, sites, t);
        }
        self.recorder.milestone(t, format!(
            "initial cluster ready ({} workers) — workload timeline t0",
            self.cfg.template.scalable.count));
        // Pull the trace up to the ingest watermark and schedule one
        // SubmitBlock per buffered block; each submission refills the
        // buffer in turn (see the Ev::SubmitBlock handler). Under the
        // unbounded default every block is scheduled right here, which
        // reproduces the pre-streaming schedule bit for bit.
        match self.feed.refill() {
            Ok(scheduled) => {
                for (i, at) in scheduled {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::SubmitBlock(i as usize));
                }
            }
            Err(e) => {
                let msg = format!("trace source failed: {e:#}");
                self.recorder.milestone(t, format!("FATAL: {msg}"));
                self.fatal = Some(msg);
                return;
            }
        }
        // Scenario events ride the same relative timeline. They are
        // operator actions on the control plane (reclaims touch the
        // LRMS and broker), so they ride the control shard.
        for ev in &self.cfg.scenario.events {
            if ev.target_sites().iter().any(|&s| s >= self.n_sites) {
                continue; // defensive: validated at construction
            }
            match ev {
                ScenarioEvent::SpotWave { site, at, count } => {
                    q.schedule_at(SimTime(t.0 + at.0), Ev::SpotWave {
                        site: *site,
                        count: *count,
                    });
                }
                &ScenarioEvent::SiteOutage { site, at, duration_secs }
                => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::OutageStart { site });
                    q.schedule_at(SimTime(t.0 + at.0 + duration_secs),
                                  Ev::OutageEnd { site });
                }
                &ScenarioEvent::PriceSpike { site, at, duration_secs,
                                             factor } => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::PriceSpikeStart { site, factor });
                    q.schedule_at(SimTime(t.0 + at.0 + duration_secs),
                                  Ev::PriceSpikeEnd { site });
                }
                &ScenarioEvent::WanPartition { site, at, duration_secs }
                => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::WanPartitionStart { site });
                    q.schedule_at(SimTime(t.0 + at.0 + duration_secs),
                                  Ev::WanPartitionEnd { site });
                }
                ScenarioEvent::RegionalOutage { sites: members, at,
                                                duration_secs } => {
                    // One correlated backbone failure = one partition
                    // marker pair per member, all sharing the same
                    // clock. The existing per-site nesting depth
                    // composes overlapping windows.
                    for &site in members {
                        q.schedule_at(SimTime(t.0 + at.0),
                                      Ev::WanPartitionStart { site });
                        q.schedule_at(
                            SimTime(t.0 + at.0 + duration_secs),
                            Ev::WanPartitionEnd { site });
                    }
                }
            }
        }
        if !self.clues_ticking {
            self.clues_ticking = true;
            q.schedule_in(self.clues.cfg.poll_interval_s, Ev::CluesTick);
        }
    }

    /// Does the fault plan (or a scenario WAN partition) target the
    /// front-end site? Only answerable after FE placement.
    fn fe_fault_conflict(&self, sites: &[SiteWorld]) -> Option<String> {
        let fe = self.fe_site;
        let fe_name = &sites[fe].cloud.spec.name;
        if self.cfg.faults.windows.iter().any(|w| w.site == fe) {
            return Some(format!(
                "WAN fault plan targets site {fe} ({fe_name}), which \
                 hosts the front end — the control plane shares its \
                 LAN, so a WAN fault there is meaningless"));
        }
        if self.cfg.faults.regions.iter().any(|g| g.sites.contains(&fe))
        {
            return Some(format!(
                "WAN fault plan regional outage includes site {fe} \
                 ({fe_name}), which hosts the front end"));
        }
        if self.cfg.scenario.events.iter().any(|ev| matches!(
            ev, ScenarioEvent::WanPartition { site, .. } if *site == fe))
        {
            return Some(format!(
                "scenario WAN partition targets site {fe} ({fe_name}), \
                 which hosts the front end"));
        }
        if self.cfg.scenario.events.iter().any(|ev| matches!(
            ev, ScenarioEvent::RegionalOutage { sites, .. }
                if sites.contains(&fe)))
        {
            return Some(format!(
                "scenario regional outage includes site {fe} \
                 ({fe_name}), which hosts the front end"));
        }
        None
    }

    /// Resolve the t0-relative fault plan into absolute-time windows,
    /// install them into each site's fault layer, and schedule the
    /// control-side markers for scripted partition windows (broker
    /// avoidance, vRouter down/up, milestones).
    fn install_fault_windows(&mut self, q: &mut ShardedQueue<Ev>,
                             sites: &mut [SiteWorld], t: SimTime) {
        // Region groups resolve into ordinary per-site partition
        // windows here — downstream of this point the fault layer sees
        // only `(site, seq)`-keyed streams, so correlation costs
        // nothing in cross-engine byte-identity.
        let expanded = self.cfg.faults.expanded_windows();
        self.regional_windows +=
            (expanded.len() - self.cfg.faults.windows.len()) as u32;
        for s in 0..self.n_sites {
            let mut windows: Vec<ResolvedWindow> = expanded
                .iter()
                .filter(|w| w.site == s)
                .map(|w| ResolvedWindow {
                    from: t.0 + w.at.0,
                    to: t.0 + w.at.0 + w.duration_secs,
                    loss: w.loss,
                    dup: w.dup,
                    jitter_s: w.jitter_s,
                    partition: w.partition,
                })
                .collect();
            // Scenario WAN partitions (regional or not) are total-loss
            // windows on the site side too, so in-flight reports die
            // on the wire.
            for ev in &self.cfg.scenario.events {
                let (members, at, duration_secs) = match ev {
                    ScenarioEvent::WanPartition { site, at,
                                                  duration_secs } => {
                        (std::slice::from_ref(site), at, duration_secs)
                    }
                    ScenarioEvent::RegionalOutage { sites, at,
                                                    duration_secs } => {
                        (sites.as_slice(), at, duration_secs)
                    }
                    _ => continue,
                };
                if members.contains(&s) {
                    if matches!(ev,
                                ScenarioEvent::RegionalOutage { .. })
                    {
                        self.regional_windows += 1;
                    }
                    windows.push(ResolvedWindow {
                        from: t.0 + at.0,
                        to: t.0 + at.0 + duration_secs,
                        loss: 1.0,
                        dup: 0.0,
                        jitter_s: 0.0,
                        partition: true,
                    });
                }
            }
            if !windows.is_empty() {
                sites[s].faults.install(windows);
            }
        }
        for w in &expanded {
            if w.partition {
                q.schedule_at(SimTime(t.0 + w.at.0),
                              Ev::WanPartitionStart { site: w.site });
                q.schedule_at(SimTime(t.0 + w.at.0 + w.duration_secs),
                              Ev::WanPartitionEnd { site: w.site });
            }
        }
    }

    /// A node was lost mid-lifecycle (boot failure, crash, preemption):
    /// complete whatever update is still in flight for it, or the
    /// serialized engine stalls forever. Handles both CLUES-originated
    /// workers (tracked in `update_for_node`) and *initial* workers,
    /// which are provisioned inside the InitialDeploy update with no
    /// per-node entry — a pre-join loss of one must still drain
    /// `initial_pending`.
    fn settle_update_on_loss(&mut self, q: &mut ShardedQueue<Ev>,
                             sites: &mut [SiteWorld], node: NodeId,
                             rt: &NodeRt, t: SimTime) {
        if let Some(id) = self.update_for_node.remove(&node) {
            let _ = self.engine.complete(id, t);
            q.schedule_in(0.0, Ev::OrchestratorPump);
        } else if rt.role == NodeRole::WorkerNode
            && rt.joined_at.is_none()
            && self.initial_pending > 0
        {
            self.initial_pending -= 1;
            if self.initial_pending == 0 {
                if let Some(id) = self.deploy_update.take() {
                    let _ = self.engine.complete(id, t);
                    self.begin_workload(q, sites, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }
        }
    }

    /// Forcibly reclaim one node's VM (scenario spot wave / outage).
    /// Running jobs requeue and are tracked for the recovery metric; a
    /// node already being decommissioned is left to finish normally,
    /// and the front end is never reclaimed (it is the cluster's fixed
    /// point — LRMS controller + vRouter CP). Returns true if the node
    /// was actually reclaimed.
    fn preempt_node(&mut self, q: &mut ShardedQueue<Ev>,
                    sites: &mut [SiteWorld], node: NodeId, t: SimTime,
                    reason: &str) -> bool {
        let Some(rt) = self.nodes.get(&node).copied() else {
            return false;
        };
        if rt.role == NodeRole::FrontEnd {
            return false; // the FE survives preemption scenarios
        }
        if rt.site >= sites.len() {
            return false; // placeholder: no site chosen, no VM yet
        }
        if self.dispatch.is_some() {
            // Partitioned: the site owns the node's scheduler slice,
            // so the reclaim rides its shard as an immediate forced
            // crash. The site crashes the VM, requeues or spills its
            // local jobs, and reports `NodeLost { preempted: true }`,
            // whose handler does the central teardown and the
            // preemption accounting exactly once.
            let name = self.names.name(node);
            self.recorder.milestone(t, format!("{name} {reason}"));
            q.schedule_in(0.0, Ev::CrashTimer {
                site: rt.site,
                vm: rt.vm,
                node,
                preempt: true,
            });
            return true;
        }
        if sites[rt.site].cloud.crash_vm(rt.vm, t).is_err() {
            // Already Terminating/Terminated: the in-flight
            // decommission owns the ledger close and update.
            return false;
        }
        let name = self.names.name(node);
        let mut requeued = self
            .lrms
            .set_node_health(&name, NodeHealth::Down, t)
            .unwrap_or_default();
        if let Ok(more) = self.lrms.deregister_node(&name, t) {
            requeued.extend(more);
        }
        for j in requeued {
            if self.preempt_pending.insert(j) {
                self.preempted_jobs += 1;
            }
        }
        self.settle_update_on_loss(q, sites, node, &rt, t);
        self.nodes.remove(&node);
        self.clues.set_state_id(node, PowerState::Failed);
        self.clues.forget_id(node);
        self.recorder.node_state_id(t, node, DisplayState::Failed);
        self.recorder.milestone(t, format!("{name} {reason}"));
        if self.trace.enabled() {
            self.trace.instant(t, "node", "node.preempted", format!(
                "node={name} site={} reason={reason}", rt.site));
        }
        self.preempted_vms += 1;
        true
    }

    /// Nodes at `site` eligible for forcible reclaim, in deterministic
    /// (NodeId) order. The front end survives: it is the cluster's
    /// fixed point (LRMS controller + vRouter CP).
    fn reclaim_victims(&self, site: usize, workers_only: bool)
        -> Vec<NodeId> {
        let mut victims: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|(_, rt)| {
                rt.site == site
                    && rt.role != NodeRole::FrontEnd
                    && (!workers_only
                        || (rt.role == NodeRole::WorkerNode
                            && rt.joined_at.is_some()))
            })
            .map(|(&id, _)| id)
            .collect();
        victims.sort();
        victims
    }

    /// Injection times are relative to the workload t0.
    fn reported_down(&self, node: &str, t: SimTime) -> bool {
        self.cfg.injections.node_reported_down(
            node, SimTime(t.0 - self.workload_t0.0))
    }

    /// Cluster-wide pending depth: the central LRMS queue, or the
    /// dispatcher's unplaced count in partitioned mode (queued at the
    /// control plane or leased but not yet started at a site).
    fn pending_depth(&self) -> usize {
        match self.dispatch.as_ref() {
            None => self.lrms.pending(),
            Some(d) => d.unplaced(),
        }
    }

    /// One CLUES monitor pass (no `InjectionPlan` clone: the closure
    /// borrows the plan for the duration of the tick). In partitioned
    /// mode CLUES polls through the [`DispatchLrmsView`]: membership
    /// and health from the central LRMS, occupancy and pending depth
    /// from the dispatcher's lease table.
    fn clues_tick(&mut self, t: SimTime) -> Vec<Action> {
        let w0 = self.workload_t0;
        let inj = &self.cfg.injections;
        let down =
            |n: &str| inj.node_reported_down(n, SimTime(t.0 - w0.0));
        match self.dispatch.as_ref() {
            None => self.clues.tick(t, self.lrms.as_ref(), &down),
            Some(d) => {
                let view = DispatchLrmsView {
                    inner: self.lrms.as_ref(),
                    disp: d,
                };
                self.clues.tick(t, &view, &down)
            }
        }
    }

    /// Run LRMS scheduling and materialize job executions as
    /// site-shard timers.
    fn pump_jobs(&mut self, q: &mut ShardedQueue<Ev>, t: SimTime) {
        if self.dispatch.is_some() {
            // Partitioned mode: sites place their own jobs during
            // their parallel windows; the control plane only routes
            // blocks ([`Self::dispatch_route`], at the tail of every
            // control event).
            return;
        }
        for (job, node) in self.lrms.schedule(t) {
            let mut secs = Workload::sample_job_secs(&mut self.rng);
            // Scheduled jobs always run on a joined node, whose site is
            // known — that site's shard carries the execution timer.
            let mut site = 0usize;
            if let Some(rt) = self.nodes.get_mut(&node) {
                site = rt.site;
                if !rt.setup_done {
                    // One-time udocker install + image pull + container
                    // create (paper: ~4 min 30 s).
                    secs += self.cfg.workload.sample_setup_secs(
                        &mut self.rng);
                    rt.setup_done = true;
                }
            }
            self.recorder.node_state_id(t, node, DisplayState::Used);
            // Real inference (sampled): wall-clock compute, virtual time
            // stays the paper's measured job duration.
            if let Some(rtm) = &self.runtime {
                let every = self.cfg.inference_every.max(1) as u64;
                if self.next_file_id % every == 0 {
                    let w0 = std::time::Instant::now();
                    if rtm.infer_file(self.next_file_id).is_ok() {
                        self.inferences_run += 1;
                        self.inference_wall_secs +=
                            w0.elapsed().as_secs_f64();
                    }
                }
            }
            self.next_file_id += 1;
            let gen = self.lrms.job(job).map(|j| j.requeues).unwrap_or(0);
            q.schedule_in(secs, Ev::JobTimer { site, job, node, gen });
        }
    }

    fn workload_done(&self) -> bool {
        // The trace is fully drained (no block left to pull or pop)
        // and every job that was ever submitted has completed. With a
        // streaming source the total is unknown until the source
        // reports end-of-trace, so "done" is defined by the feed, not
        // by a precomputed job count.
        self.feed.drained()
            && self.jobs_completed >= self.jobs_submitted
    }

    /// Process one site's batched completed-run report: validate each
    /// run against the live LRMS record (stale executions that were
    /// requeued away are dropped), free the slots, account busy time,
    /// then run one scheduling sweep for the whole batch.
    fn apply_job_batch(&mut self, q: &mut ShardedQueue<Ev>,
                       done: Vec<super::JobRun>, t: SimTime) {
        for run in done {
            let live = self.lrms.job(run.job).map(|j| {
                j.requeues == run.gen
                    && j.state == crate::lrms::JobState::Running
                    && j.node == Some(run.node)
            }).unwrap_or(false);
            if !live {
                continue;
            }
            let _ = self.lrms.on_job_finished(run.job, true, t);
            self.jobs_completed += 1;
            if self.preempt_pending.remove(&run.job) {
                self.preempt_recovered += 1;
            }
            if self.chaos_pending.remove(&run.job) {
                self.lease_recovered += 1;
            }
            if let Some(stat) = self.lrms.node_stat(run.node) {
                if stat.used_slots == 0 {
                    self.recorder.node_state_id(t, run.node,
                                                DisplayState::Idle);
                }
            }
            // Record the run interval from the LRMS job record.
            if let Some(j) = self.lrms.job(run.job) {
                if let (Some(s), Some(e)) = (j.started_at, j.finished_at)
                {
                    self.recorder.job_run_id(run.node, s, e);
                    if let Some(&ri) = self.live_record.get(&run.node) {
                        self.vm_records[ri].busy_secs += e.0 - s.0;
                    }
                    // The job's full causal chain, emitted now that its
                    // completion report has crossed the WAN: queue wait
                    // (submit→start), execution (start→finish), report
                    // lag (finish→batch arrival).
                    if self.trace.enabled() {
                        let d = format!("job={} node={}", run.job,
                                        self.names.name(run.node));
                        self.trace.span(t, "job", "job.queue",
                                        j.submitted_at, s, d.clone());
                        self.trace.span(t, "job", "job.run", s, e,
                                        d.clone());
                        self.trace.span(t, "job", "job.report-lag", e, t,
                                        d);
                    }
                }
            }
        }
        self.pump_jobs(q, t);
    }

    // ---------------------------------------------------------------
    // Partitioned dispatch (see `super::dispatch`)
    // ---------------------------------------------------------------

    /// Process one site's partitioned-dispatch barrier report: accept
    /// lease-valid execution starts into the occupancy overlay,
    /// account lease-valid completions exactly once (counters,
    /// recorder, accounting, trace — the same bookkeeping
    /// [`Self::apply_job_batch`] does for the central scheduler), and
    /// requeue accepted spills in report order. Stale entries — zombie
    /// executions from a lease the dispatcher has since revoked — are
    /// dropped by the epoch/seq checks inside the dispatcher.
    fn apply_site_report(&mut self, sites: &mut [SiteWorld],
                         site: usize, started: Vec<DispatchRun>,
                         done: Vec<DispatchRun>,
                         spilled: Vec<DispatchJob>, t: SimTime) {
        for run in &started {
            let outcome = self
                .dispatch
                .as_mut()
                .expect("SiteJobReport only exists in partitioned mode")
                .on_started(site, run);
            if matches!(outcome, StartOutcome::Fresh { .. })
                && self.nodes.contains_key(&run.node)
            {
                self.recorder.node_state_id(t, run.node,
                                            DisplayState::Used);
            }
        }
        for run in &done {
            let outcome = self
                .dispatch
                .as_mut()
                .expect("SiteJobReport only exists in partitioned mode")
                .on_done(site, run);
            let DoneOutcome::Completed {
                started: s0,
                submitted_at,
                became_idle,
            } = outcome else {
                continue;
            };
            self.jobs_completed += 1;
            if self.preempt_pending.remove(&run.job) {
                self.preempt_recovered += 1;
            }
            if self.chaos_pending.remove(&run.job) {
                self.lease_recovered += 1;
            }
            if became_idle && self.nodes.contains_key(&run.node) {
                self.recorder.node_state_id(t, run.node,
                                            DisplayState::Idle);
            }
            self.recorder.job_run_id(run.node, s0, run.at);
            if let Some(&ri) = self.live_record.get(&run.node) {
                self.vm_records[ri].busy_secs += run.secs;
            }
            // The job's full causal chain, emitted now that its
            // completion has crossed the WAN: queue wait
            // (submit→start), execution (start→finish), report lag
            // (finish→report arrival).
            if self.trace.enabled() {
                let d = format!("job={} node={}", run.job,
                                self.names.name(run.node));
                self.trace.span(t, "job", "job.queue", submitted_at,
                                s0, d.clone());
                self.trace.span(t, "job", "job.run", s0, run.at,
                                d.clone());
                self.trace.span(t, "job", "job.report-lag", run.at, t,
                                d);
            }
        }
        // Spills re-enter at the queue front; feeding them in reverse
        // preserves the report's (submission) order there.
        let mut accepted = 0usize;
        for dj in spilled.iter().rev() {
            let ok = self
                .dispatch
                .as_mut()
                .expect("SiteJobReport only exists in partitioned mode")
                .on_spilled(site, dj, t.0);
            if ok {
                accepted += 1;
            }
        }
        if accepted > 0 {
            self.recorder.milestone(t, format!(
                "{} returned {accepted} jobs it cannot hold — \
                 re-routing", sites[site].cloud.spec.name));
            if self.trace.enabled() {
                self.trace.instant(t, "job", "job.spill", format!(
                    "site={site} jobs={accepted}"));
            }
        }
    }

    /// Route queued jobs to sites (the partitioned dispatcher's only
    /// placement decision): greedy from the queue front, each job to
    /// the best-ranked reachable site
    /// ([`ElasticityBroker::route_candidates`]) with spare *credit* —
    /// its registered Up-worker slots (central membership view) minus
    /// the slots already leased to it and not completed — so a site is
    /// never sent more work than it can plausibly hold. Runs at the
    /// tail of every control event; one [`Ev::JobBlock`] per receiving
    /// site, emitted in site-index order.
    fn dispatch_route(&mut self, q: &mut ShardedQueue<Ev>,
                      sites: &mut [SiteWorld], t: SimTime,
                      exclude: Option<usize>) {
        if !self.dispatch.as_ref().is_some_and(|d| d.queued() > 0) {
            return;
        }
        let mut credit = vec![0i64; self.n_sites];
        for (&id, rt) in &self.nodes {
            // Order-insensitive sum over the node map: deterministic.
            if rt.role != NodeRole::WorkerNode
                || rt.site >= self.n_sites
                || rt.joined_at.is_none()
            {
                continue;
            }
            if let Some(st) = self.lrms.node_stat(id) {
                if st.health == NodeHealth::Up {
                    credit[rt.site] += st.slots as i64;
                }
            }
        }
        // Headroom batching: with `max_blocks_per_barrier = k`, each
        // site may hold up to k barriers' worth of leased work (the
        // site-side spill cap scales to match), so large traces need
        // ~k× fewer route round-trips. k = 1 is the classic one-pass
        // greedy route, byte-identical to the pre-knob behaviour.
        let rounds = self.cfg.dispatch_cfg.max_blocks_per_barrier
            .max(1) as i64;
        for c in credit.iter_mut() {
            *c *= rounds;
        }
        let mut d = self.dispatch.take().expect("checked above");
        for (s, c) in credit.iter_mut().enumerate() {
            *c -= d.inflight(s) as i64;
        }
        let used = self.used_workers_per_site();
        let order = self.broker.route_candidates(sites, &used,
                                                 d.queued() as u32);
        let mut blocks: Vec<Vec<DispatchJob>> =
            vec![Vec::new(); self.n_sites];
        while let Some((_, slots)) = d.front() {
            // Under chaos, WAN-partitioned sites are skipped even
            // before their breaker opens: a block sent into a
            // partition would only feed zombie executions.
            let Some(&s) = order.iter().find(|&&s| {
                Some(s) != exclude
                    && !(self.chaos && self.partition_depth[s] > 0)
                    && credit[s] >= slots as i64
            }) else {
                break;
            };
            let dj = d.route_front(s);
            credit[s] -= dj.slots as i64;
            blocks[s].push(dj);
        }
        self.dispatch = Some(d);
        for (s, jobs) in blocks.into_iter().enumerate() {
            if jobs.is_empty() {
                continue;
            }
            if self.trace.enabled() {
                self.trace.instant(t, "job", "job.route", format!(
                    "site={s} jobs={}", jobs.len()));
            }
            q.schedule_in(0.0, Ev::JobBlock { site: s, jobs });
        }
    }

    // ---------------------------------------------------------------
    // CLUES action execution
    // ---------------------------------------------------------------

    fn apply_clues_actions(&mut self, q: &mut ShardedQueue<Ev>,
                           actions: Vec<Action>, t: SimTime) {
        for action in actions {
            match action {
                Action::PowerOn { count } => {
                    for _ in 0..count {
                        let (id, name) = self.next_worker();
                        // Reserve the name immediately so subsequent
                        // PowerOns pick fresh ones.
                        self.nodes.insert(id, NodeRt {
                            site: usize::MAX,
                            vm: VmId(u64::MAX),
                            role: NodeRole::WorkerNode,
                            setup_done: false,
                            requested_at: t,
                            joined_at: None,
                        });
                        self.clues.track_id(id, PowerState::PoweringOn);
                        self.recorder.node_state_id(
                            t, id, DisplayState::PoweringOn);
                        self.engine.submit(UpdateOp::AddWorker {
                            name,
                        }, t);
                    }
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
                Action::PowerOff { node } => {
                    let id = self.names.intern(&node);
                    self.engine.submit(UpdateOp::RemoveWorker {
                        name: node,
                    }, t);
                    self.recorder.node_state_id(t, id,
                                                DisplayState::PoweringOff);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
                Action::CancelPowerOff { node } => {
                    // O(1) keyed lookup instead of scanning the whole
                    // update history.
                    let id = self.engine.find_queued_remove(&node);
                    match id {
                        Some(id) if self.engine.cancel(id, t).is_ok() => {
                            // Rescued: the node never left.
                            let nid = self.names.intern(&node);
                            self.clues.set_state_id(nid, PowerState::On);
                            let idle = self
                                .lrms
                                .node_stat(nid)
                                .map(|s| s.is_idle())
                                .unwrap_or(false);
                            self.recorder.node_state_id(t, nid,
                                if idle { DisplayState::Idle }
                                else { DisplayState::Used });
                            self.recorder.milestone(t, format!(
                                "power-off of {node} cancelled \
                                 (jobs arrived early)"));
                        }
                        _ => {
                            // Too late (vnode-3): it will power off.
                        }
                    }
                }
                Action::MarkFailed { node } => {
                    let id = self.names.intern(&node);
                    // Quarantined sites hold their nodes Down on
                    // purpose: decommissioning them would race the
                    // heal. CLUES's own Failed marking already freed
                    // the headroom, so replacements spawn at healthy
                    // sites (that is the failover); the quarantine
                    // close revives whatever survived.
                    if self.chaos {
                        if let Some(rt) = self.nodes.get(&id) {
                            if rt.site < self.n_sites
                                && self.quarantined[rt.site]
                            {
                                continue;
                            }
                        }
                    }
                    self.recorder.node_state_id(t, id,
                                                DisplayState::Failed);
                    self.recorder.milestone(t, format!(
                        "{node} detected as off — marked failed, \
                         powering off to avoid cost"));
                    // Requeue its jobs and power it off.
                    let _ = self.lrms.set_node_health(&node,
                                                      NodeHealth::Down, t);
                    self.engine.submit(UpdateOp::RemoveWorker {
                        name: node,
                    }, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }
        }
    }

    /// Start any updates the (possibly serialized) engine allows.
    fn pump_orchestrator(&mut self, q: &mut ShardedQueue<Ev>,
                         sites: &mut [SiteWorld], t: SimTime) {
        for update in self.engine.startable(t) {
            match &update.op {
                UpdateOp::AddWorker { name } => {
                    let id = self.names.intern(name);
                    if !self.start_add_worker(q, sites, name, t) {
                        // No capacity: finish the update immediately and
                        // stop tracking the phantom node. Re-pump so
                        // updates queued behind this one are not starved.
                        let _ = self.engine.complete(update.id, t);
                        self.nodes.remove(&id);
                        self.clues.forget_id(id);
                        self.recorder.node_state_id(t, id,
                                                    DisplayState::Off);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                    } else {
                        self.update_for_node.insert(id, update.id);
                    }
                }
                UpdateOp::RemoveWorker { name } => {
                    let id = self.names.intern(name);
                    let Some(rt) = self.nodes.get(&id).copied() else {
                        let _ = self.engine.complete(update.id, t);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                        continue;
                    };
                    if rt.site >= sites.len() {
                        // The original node died and its name was
                        // reused by a PowerOn reservation that has no
                        // site yet (placeholder, site == usize::MAX):
                        // nothing to decommission. The old
                        // Im::decommission_node bounds check caught
                        // this; with the single-site Im API the guard
                        // lives here.
                        let _ = self.engine.complete(update.id, t);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                        continue;
                    }
                    let _ = self.lrms.deregister_node(name, t);
                    if let Some(d) = self.dispatch.as_mut() {
                        // The site slice deregisters it on
                        // TerminationDone; drop the overlay entry now
                        // so CLUES stops seeing the node as headroom.
                        d.drop_node(id);
                    }
                    match self.im.decommission_node(
                        &mut sites[rt.site].cloud, rt.vm, name, t) {
                        Ok(secs) => {
                            q.schedule_in(secs, Ev::TerminationDone {
                                site: rt.site,
                                vm: rt.vm,
                                node: id,
                                update: Some(update.id),
                            });
                        }
                        Err(_) => {
                            let _ = self.engine.complete(update.id, t);
                            q.schedule_in(0.0, Ev::OrchestratorPump);
                        }
                    }
                }
                UpdateOp::InitialDeploy => {
                    self.deploy_update = Some(update.id);
                    let used = self.used_workers_per_site();
                    // FE placement is always SLA-ranked (the fixed
                    // point); the configured policy governs workers.
                    let fe_site = self.broker.select_front_end(
                        sites, &used,
                        self.cfg.template.front_end.num_cpus, t)
                        .unwrap_or(0);
                    self.fe_site = fe_site;
                    self.broker.set_front_end(fe_site, &self.net, sites);
                    if let Err(e) = self.provision(q, sites, fe_site,
                                                   FE_NAME,
                                                   NodeRole::FrontEnd, t) {
                        self.recorder.milestone(t, format!(
                            "FATAL: cannot provision front-end: {e}"));
                        let _ = self.engine.complete(update.id, t);
                    } else {
                        self.recorder.milestone(t, format!(
                            "deploying front-end at {}",
                            sites[fe_site].cloud.spec.name));
                    }
                }
            }
        }
    }

    /// A node finished contextualization and joins the cluster.
    fn node_ready(&mut self, q: &mut ShardedQueue<Ev>,
                  sites: &mut [SiteWorld], node: NodeId, t: SimTime) {
        // A successful join settles any in-flight provisioning retry.
        self.retry_state.remove(&node);
        let Some(rt) = self.nodes.get_mut(&node) else { return };
        rt.joined_at = Some(t);
        let (site, role, requested_at) =
            (rt.site, rt.role, rt.requested_at);
        let name = self.names.name(node);
        self.deploy_log.push((name.clone(), requested_at, t));
        if self.trace.enabled() {
            self.trace.span(t, "node", "node.boot", requested_at, t,
                            format!("node={name} site={site} \
                                     role={role:?}"));
        }
        // Non-FE nodes keep a reverse tunnel to the Ansible master so
        // the control node can reach them without a public IP.
        if role != NodeRole::FrontEnd {
            let _ = self.im.connect_node(&name, t);
        }
        match role {
            NodeRole::FrontEnd => {
                self.fe_ready = true;
                self.im.establish_master(FE_NAME);
                // FE hosts the vRouter central point + CA.
                let base = sites[site]
                    .cloud
                    .networks
                    .get(crate::cloudsim::NetworkId(0))
                    .map(|n| n.cidr_base)
                    .unwrap_or(0x0A00_0000);
                let loc = sites[site].cloud.net_id;
                let _ = self.overlay.add_central_point(
                    FE_NAME, loc, base, t);
                self.recorder.milestone(t,
                    "front-end ready (LRMS controller + NFS + \
                     vRouter CP)".to_string());
                self.recorder.node_state_id(t, node,
                                            DisplayState::Used);
                // Initial workers, all within the same
                // InitialDeploy update.
                self.initial_pending =
                    self.cfg.template.scalable.count;
                if self.initial_pending == 0 {
                    if let Some(id) = self.deploy_update.take() {
                        let _ = self.engine.complete(id, t);
                        self.begin_workload(q, sites, t);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                    }
                }
                for _ in 0..self.cfg.template.scalable.count {
                    let (wid, wname) = self.next_worker();
                    self.clues.track_id(wid, PowerState::PoweringOn);
                    // Initial workers are provisioned directly by
                    // the IM inside the initial update.
                    if !self.start_add_worker(q, sites, &wname, t) {
                        self.initial_pending -= 1;
                    }
                }
            }
            NodeRole::SiteVRouter => {
                // Register + connect the site router to the CP.
                let loc = sites[site].cloud.net_id;
                let base = self
                    .im
                    .networks
                    .get(&site)
                    .and_then(|nid| {
                        sites[site].cloud.networks.get(*nid)
                    })
                    .map(|n| n.cidr_base)
                    .unwrap_or(0x0A01_0000);
                let _ = self
                    .im
                    .retrieve_certificate(&mut self.overlay,
                                          &name, t);
                // add_site_router issues the cert itself if the
                // callback did not; remove double issue.
                if self.overlay.element(&name).is_none() {
                    if self.overlay.ca.verify(&name) {
                        let _ = self.overlay.ca.revoke(&name);
                    }
                    let _ = self.overlay.add_site_router(
                        &name, loc, base, t);
                }
                self.recorder.milestone(t, format!(
                    "{name} connected to the CP (overlay up at \
                     {})", sites[site].cloud.spec.name));
                self.recorder.node_state_id(t, node,
                                            DisplayState::Used);
            }
            NodeRole::WorkerNode => {
                // Join the LRMS; node becomes schedulable.
                self.lrms.register_node(
                    &name, self.clues.cfg.slots_per_worker, t);
                if self.dispatch.is_some() {
                    // Partitioned: grant the node to its site's
                    // scheduler slice. The overlay starts idle; the
                    // grant rides the site shard like any other
                    // control command.
                    let slots = self.clues.cfg.slots_per_worker;
                    if let Some(d) = self.dispatch.as_mut() {
                        d.grant_node(node, t.0);
                    }
                    q.schedule_in(0.0, Ev::SiteNodeUp {
                        site,
                        node,
                        slots,
                    });
                }
                self.clues.track_id(node, PowerState::On);
                self.clues.set_state_id(node, PowerState::On);
                self.recorder.node_state_id(t, node,
                                            DisplayState::Idle);
                self.recorder.milestone(t, format!(
                    "{name} joined the cluster"));
                if let Some(id) = self.update_for_node.remove(&node)
                {
                    let _ = self.engine.complete(id, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
                if self.initial_pending > 0 {
                    self.initial_pending -= 1;
                    if self.initial_pending == 0 {
                        if let Some(id) = self.deploy_update.take() {
                            let _ = self.engine.complete(id, t);
                            self.begin_workload(q, sites, t);
                            q.schedule_in(0.0,
                                          Ev::OrchestratorPump);
                        }
                    }
                }
                self.pump_jobs(q, t);
            }
        }
    }
}

impl ControlPlane for ControlWorld {
    type Site = SiteWorld;

    /// The conservative lookahead of the sharded engines: every
    /// site→control emission is at least this far in the future.
    fn lookahead(&self) -> f64 {
        self.control_latency
    }

    fn handle(&mut self, sites: &mut [SiteWorld], t: SimTime, ev: Ev,
              q: &mut ShardedQueue<Ev>) {
        // Any site-originated message is implicit proof of life for its
        // site: it resets the heartbeat breaker before the event itself
        // is dispatched (a job report from a "silent" site must lift
        // the quarantine *before* its jobs are accounted).
        if self.chaos {
            match &ev {
                Ev::NodeReady { site, .. }
                | Ev::BootFailed { site, .. }
                | Ev::NodeLost { site, .. }
                | Ev::NodeOff { site, .. }
                | Ev::JobBatch { site, .. }
                | Ev::SiteJobReport { site, .. }
                | Ev::SiteHeartbeat { site } => {
                    let s = *site;
                    self.note_site_alive(q, sites, s, t);
                }
                _ => {}
            }
        }
        // Partitioned dispatch: a site whose report spilled work is
        // excluded from the re-route its own report triggers — it just
        // proved it cannot hold the jobs (captured here, before the
        // match consumes `ev`).
        let route_exclude = match &ev {
            Ev::SiteJobReport { site, spilled, .. }
                if !spilled.is_empty() => Some(*site),
            _ => None,
        };
        match ev {
            Ev::Deploy => {
                self.engine.submit(UpdateOp::InitialDeploy, t);
                self.pump_orchestrator(q, sites, t);
            }

            Ev::SubmitBlock(i) => {
                // The feed pops in the same global index order the
                // SubmitBlock events were scheduled in — arrival times
                // are validated non-decreasing, so event order matches
                // buffer order.
                debug_assert_eq!(self.feed.next_pop_index(), i as u64);
                let Some(block) = self.feed.pop_front() else {
                    return; // unreachable unless the feed misbehaved
                };
                let jobs = block.jobs;
                // One bulk core call per block (a 100k-job block is a
                // single submit), not one trait dispatch per job.
                match self.dispatch.as_mut() {
                    None => self.lrms.submit_batch(jobs, 1, t),
                    // Partitioned: the block enters the route queue and
                    // is leased out at this event's barrier tail.
                    Some(d) => d.submit(jobs, 1, t),
                }
                self.jobs_submitted += jobs;
                self.recorder.milestone(t, format!(
                    "block {} submitted: {jobs} jobs", i + 1));
                if self.trace.enabled() {
                    self.trace.instant(t, "job", "job.submit-block",
                        format!("block={} jobs={jobs}", i + 1));
                }
                // Popping freed watermark room: pull the next blocks
                // from the source and schedule them on the workload
                // timeline. Under the unbounded default everything was
                // already scheduled at t0 and this is a no-op.
                match self.feed.refill() {
                    Ok(scheduled) => {
                        for (j, at) in scheduled {
                            q.schedule_at(
                                SimTime(self.workload_t0.0 + at.0),
                                Ev::SubmitBlock(j as usize));
                        }
                    }
                    Err(e) => {
                        let msg =
                            format!("trace source failed: {e:#}");
                        self.recorder.milestone(
                            t, format!("FATAL: {msg}"));
                        self.fatal = Some(msg);
                    }
                }
                self.pump_jobs(q, t);
                // Immediate CLUES reaction on new work.
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
            }

            Ev::NodeReady { site, vm, node } => {
                // Stale if this VM incarnation was reclaimed while the
                // notification crossed the WAN and the name was reused
                // for a successor — a successor must not be joined on
                // the strength of its predecessor's contextualization.
                // The joined_at guard additionally absorbs duplicated
                // deliveries of the same join (WAN dup fault).
                let live = self.nodes.get(&node)
                    .map(|rt| rt.vm == vm && rt.site == site
                        && rt.joined_at.is_none())
                    .unwrap_or(false);
                if !live {
                    return;
                }
                self.node_ready(q, sites, node, t);
            }

            Ev::BootFailed { site, vm, node } => {
                let Some(rt) = self.nodes.get(&node).copied() else {
                    return;
                };
                if rt.vm != vm || rt.site != site {
                    return; // stale: the name already hosts a successor
                }
                if self.trace.enabled() {
                    self.trace.instant(t, "node", "node.boot-failed",
                        format!("node={} site={site}",
                                self.names.name(node)));
                }
                if self.chaos
                    && rt.role == NodeRole::WorkerNode
                    && self.schedule_provision_retry(q, node, rt.site, t)
                {
                    // The retry owns the node record now; the update (if
                    // any) stays open until the retry resolves.
                    return;
                }
                // Retry through CLUES on the next tick (the node
                // vanishes; CLUES sees the deficit again).
                self.settle_update_on_loss(q, sites, node, &rt, t);
                self.nodes.remove(&node);
                self.clues.forget_id(node);
            }

            Ev::JobBatch { done, .. } => {
                self.apply_job_batch(q, done, t);
            }

            Ev::SiteJobReport { site, started, done, spilled } => {
                self.apply_site_report(sites, site, started, done,
                                       spilled, t);
            }

            Ev::CluesTick => {
                // Sample the gauge grid before any reaction: the series
                // reads the state each tick found, not what it did.
                if self.metrics.enabled() {
                    self.sample_metrics(sites, t);
                }
                // Heartbeat bookkeeping first: a site whose probes all
                // vanished since the last tick trips its breaker before
                // CLUES reacts to the resulting Down nodes.
                if self.chaos {
                    self.heartbeat_scan(q, sites, t);
                    // Fold the telemetry of the elapsed tick into each
                    // site's health score before CLUES provisions
                    // anything, so this tick's placements already see
                    // the refreshed ranking.
                    self.update_health(sites, t);
                }
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                // Recovery path for transient flaps: if the monitor reads
                // the node as up again and the LRMS had it Down, revive.
                // The snapshot buffer is owned scratch (taken off self),
                // so the loop body may mutate the LRMS while iterating —
                // and the tick allocates nothing at steady state.
                let mut stats = std::mem::take(&mut self.stats_scratch);
                self.lrms.node_stats_into(&mut stats);
                for s in &stats {
                    if s.health != NodeHealth::Down {
                        continue;
                    }
                    let id = s.id;
                    // Quarantine holds its site's nodes Down until the
                    // breaker closes; the flap-revive path must not
                    // resurrect them early.
                    if self.chaos {
                        if let Some(rt) = self.nodes.get(&id) {
                            if rt.site < self.n_sites
                                && self.quarantined[rt.site]
                            {
                                continue;
                            }
                        }
                    }
                    let name = self.names.name(id);
                    // Only revive if CLUES has not already failed it.
                    if !self.reported_down(&name, t)
                        && self.clues.state_id(id) == Some(PowerState::On)
                    {
                        let _ = self.lrms.set_node_health(
                            &name, NodeHealth::Up, t);
                    }
                }
                self.stats_scratch = stats;
                self.pump_jobs(q, t);
                if self.chaos {
                    self.send_heartbeats(q, t);
                }
                // Keep ticking while there is anything left to manage.
                let all_workers_off = self
                    .nodes
                    .values()
                    .filter(|rt| rt.role == NodeRole::WorkerNode)
                    .count() == 0;
                if !(self.workload_done() && all_workers_off) {
                    q.schedule_in(self.clues.cfg.poll_interval_s,
                                  Ev::CluesTick);
                } else {
                    self.recorder.milestone(t,
                        "workload complete, all workers released"
                            .to_string());
                }
            }

            Ev::OrchestratorPump => {
                self.pump_orchestrator(q, sites, t);
            }

            Ev::NodeLost { site, vm, node, preempted } => {
                // Stale if the node was already replaced or terminated.
                let Some(rt) = self.nodes.get(&node).copied() else {
                    return;
                };
                if rt.vm != vm || rt.site != site {
                    return;
                }
                // The site already crashed the VM (and closed its
                // ledger row); the controller's side is the LRMS
                // requeue + elasticity bookkeeping.
                let name = self.names.name(node);
                if self.trace.enabled() {
                    self.trace.instant(t, "node",
                        if preempted { "node.preempted" }
                        else { "node.lost" },
                        format!("node={name} site={site}"));
                }
                let mut requeued = self
                    .lrms
                    .set_node_health(&name, NodeHealth::Down, t)
                    .unwrap_or_default();
                if let Ok(more) = self.lrms.deregister_node(&name, t) {
                    requeued.extend(more);
                }
                if let Some(d) = self.dispatch.as_mut() {
                    // Partitioned: the site already requeued the
                    // node's jobs into its local queue (the restart
                    // rebinds under a higher seq) or spilled them; the
                    // control side only tracks them for the recovery
                    // metric and drops the occupancy overlay.
                    requeued.extend(d.jobs_bound_to(node));
                    d.drop_node(node);
                }
                if preempted {
                    for j in requeued {
                        if self.preempt_pending.insert(j) {
                            self.preempted_jobs += 1;
                        }
                    }
                    self.preempted_vms += 1;
                }
                self.settle_update_on_loss(q, sites, node, &rt, t);
                self.nodes.remove(&node);
                self.clues.set_state_id(node, PowerState::Failed);
                self.clues.forget_id(node);
                // CLUES replaces it on its next tick if jobs remain.
                self.pump_jobs(q, t);
            }

            Ev::NodeOff { site, vm, node, update } => {
                // Drop the node only if this is still the incarnation
                // the termination belonged to: a crash notification in
                // the same latency window may already have removed it
                // and freed the name for a successor, which must not
                // be forgotten by its predecessor's power-off.
                let live = self.nodes.get(&node)
                    .map(|rt| rt.vm == vm && rt.site == site)
                    .unwrap_or(false);
                if live {
                    self.nodes.remove(&node);
                    self.clues.set_state_id(node, PowerState::Off);
                    self.clues.forget_id(node);
                }
                // The decommission update is done either way.
                if let Some(id) = update {
                    let _ = self.engine.complete(id, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }

            Ev::SpotWave { site, count } => {
                let victims = self.reclaim_victims(site, true);
                let n = if count == 0 {
                    victims.len()
                } else {
                    (count as usize).min(victims.len())
                };
                self.recorder.milestone(t, format!(
                    "spot-preemption wave at {}: reclaiming {n} of {} \
                     workers", sites[site].cloud.spec.name,
                    victims.len()));
                if self.trace.enabled() {
                    self.trace.instant(t, "scenario",
                        "scenario.spot-wave",
                        format!("site={site} reclaimed={n}"));
                }
                for id in victims.into_iter().take(n) {
                    self.preempt_node(q, sites, id, t,
                                      "preempted (spot wave)");
                }
                // Immediate CLUES pass so replacements start promptly
                // (the broker decides where they land).
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                self.pump_jobs(q, t);
            }

            Ev::OutageStart { site } => {
                self.broker.set_outage(site, true);
                self.recorder.milestone(t, format!(
                    "site outage: {} dark", sites[site].cloud.spec.name));
                if self.trace.enabled() {
                    self.trace.instant(t, "scenario",
                        "scenario.outage-open", format!("site={site}"));
                }
                for id in self.reclaim_victims(site, false) {
                    self.preempt_node(q, sites, id, t,
                                      "lost to site outage");
                }
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                self.pump_jobs(q, t);
            }

            Ev::OutageEnd { site } => {
                self.broker.set_outage(site, false);
                self.recorder.milestone(t, format!(
                    "site outage over: {} eligible again",
                    sites[site].cloud.spec.name));
                if self.trace.enabled() {
                    self.trace.instant(t, "scenario",
                        "scenario.outage-close", format!("site={site}"));
                }
            }

            Ev::PriceSpikeStart { site, factor } => {
                // The broker reads the site's factor through its
                // signals, so billing and policy stay in sync by
                // construction. Overlapping windows compose: the
                // latest spike's factor rules until every open window
                // has ended.
                self.price_spikes_active[site] += 1;
                sites[site].cloud.set_price_factor(factor);
                self.recorder.milestone(t, format!(
                    "price spike at {}: {factor}x list for new launches",
                    sites[site].cloud.spec.name));
                if self.trace.enabled() {
                    self.trace.instant(t, "scenario",
                        "scenario.price-spike-open",
                        format!("site={site} factor={factor}"));
                }
            }

            Ev::PriceSpikeEnd { site } => {
                self.price_spikes_active[site] =
                    self.price_spikes_active[site].saturating_sub(1);
                if self.trace.enabled() {
                    self.trace.instant(t, "scenario",
                        "scenario.price-spike-close",
                        format!("site={site}"));
                }
                if self.price_spikes_active[site] == 0 {
                    sites[site].cloud.set_price_factor(1.0);
                    self.recorder.milestone(t, format!(
                        "price spike over at {}",
                        sites[site].cloud.spec.name));
                } else {
                    self.recorder.milestone(t, format!(
                        "price spike window closed at {} (another spike \
                         still active)", sites[site].cloud.spec.name));
                }
            }

            Ev::RetryProvision { node } => {
                let Some(rt) = self.nodes.get(&node).copied() else {
                    // The node record is gone (e.g. a CancelPowerOff /
                    // RemoveWorker raced the retry): nothing to place.
                    self.retry_state.remove(&node);
                    return;
                };
                let Some(rec) = self.retry_state.get_mut(&node).map(|r| {
                    r.pending = false;
                    *r
                }) else {
                    return;
                };
                let name = self.names.name(node);
                let used = self.used_workers_per_site();
                let cpus = self.cfg.template.worker.num_cpus;
                let queue_depth = self.pending_depth() as u32;
                let site = if self.cfg.template.hybrid {
                    let mut excluded: Vec<bool> = (0..self.n_sites)
                        .map(|s| self.partition_depth[s] > 0
                            || self.quarantined[s])
                        .collect();
                    // After `failover_after` failed attempts, stop
                    // hammering the original site and let the broker
                    // rank the alternatives...
                    let avoid_first =
                        rec.attempt >= self.cfg.retry.failover_after;
                    if avoid_first && rec.first_site < excluded.len() {
                        excluded[rec.first_site] = true;
                    }
                    let mut s = self.broker.select_excluding(
                        sites, &used, cpus, queue_depth, t, &excluded);
                    // ...unless nowhere else fits — then the original
                    // site is still better than stranding the node.
                    if s.is_none() && avoid_first
                        && rec.first_site < excluded.len()
                    {
                        excluded[rec.first_site] = false;
                        s = self.broker.select_excluding(
                            sites, &used, cpus, queue_depth, t,
                            &excluded);
                    }
                    if self.trace.enabled() {
                        let ranked = self.broker.ranked_candidates(
                            sites, &used, cpus, queue_depth,
                            Some(&excluded));
                        self.trace.instant(t, "broker",
                            "broker.decision", format!(
                                "node={name} retry attempt={} \
                                 picked={s:?} queue={queue_depth} \
                                 ranked={}", rec.attempt,
                                fmt_ranked(&ranked)));
                    }
                    s
                } else {
                    let s = self.fe_site;
                    let cloud = &sites[s].cloud;
                    let fits = cloud.used_vms() < cloud.spec.quota.max_vms
                        && cloud.used_vcpus() + cpus
                            <= cloud.spec.quota.max_vcpus;
                    fits.then_some(s)
                };
                let placed = match site {
                    Some(s) => {
                        if s != rec.first_site {
                            self.provision_failovers += 1;
                            self.recorder.milestone(t, format!(
                                "{name} failing over from {} to {}",
                                sites[rec.first_site].cloud.spec.name,
                                sites[s].cloud.spec.name));
                        }
                        self.place_worker(q, sites, &name, s, t)
                    }
                    None => {
                        self.recorder.milestone(t, format!(
                            "no eligible site for retry of {name}"));
                        false
                    }
                };
                if !placed
                    && !self.schedule_provision_retry(q, node,
                                                      rec.first_site, t)
                {
                    // Retry budget exhausted: settle like a lost node so
                    // CLUES and the orchestrator move on.
                    self.settle_update_on_loss(q, sites, node, &rt, t);
                    self.nodes.remove(&node);
                    self.clues.set_state_id(node, PowerState::Failed);
                    self.clues.forget_id(node);
                    self.recorder.node_state_id(t, node,
                                                DisplayState::Failed);
                }
            }

            Ev::SiteHeartbeat { .. } => {
                // The liveness proof was consumed by the pre-dispatch
                // note_site_alive above; the event itself carries no
                // other payload.
            }

            Ev::WanPartitionStart { site } => {
                self.partition_depth[site] += 1;
                if self.partition_depth[site] == 1 {
                    self.recorder.milestone(t, format!(
                        "WAN partition: {} unreachable from the control \
                         plane", sites[site].cloud.spec.name));
                    if self.trace.enabled() {
                        self.trace.instant(t, "chaos",
                            "wan.partition-open",
                            format!("site={site}"));
                    }
                    if site != self.fe_site {
                        let vr = self.vrouter_name(sites, site);
                        if self.overlay.element(&vr).is_some() {
                            let _ = self.overlay.fail_site_router(&vr);
                        }
                    }
                }
            }

            Ev::WanPartitionEnd { site } => {
                self.partition_depth[site] =
                    self.partition_depth[site].saturating_sub(1);
                if self.partition_depth[site] == 0 {
                    self.recorder.milestone(t, format!(
                        "WAN partition healed: {} reachable again",
                        sites[site].cloud.spec.name));
                    if self.trace.enabled() {
                        self.trace.instant(t, "chaos",
                            "wan.partition-close",
                            format!("site={site}"));
                    }
                    if site != self.fe_site {
                        let vr = self.vrouter_name(sites, site);
                        if self.overlay.element(&vr).is_some() {
                            let _ = self.overlay.restore_site_router(&vr);
                        }
                    }
                }
            }

            // Site-shard events never reach the control handler.
            Ev::BootDone { .. }
            | Ev::CtxTimer { .. }
            | Ev::JobTimer { .. }
            | Ev::FlushTimer { .. }
            | Ev::CrashTimer { .. }
            | Ev::TerminationDone { .. }
            | Ev::HeartbeatPing { .. }
            | Ev::Retransmit { .. }
            | Ev::JobBlock { .. }
            | Ev::SiteNodeUp { .. } => {
                unreachable!("site event routed to the control shard")
            }
        }
        // Partitioned dispatch: any control event may have queued work
        // (block submission, spillover, lease revocation) or freed
        // credit (completions, node joins), so route at the barrier
        // tail — the one place leases are ever granted.
        if self.dispatch.is_some() {
            self.dispatch_route(q, sites, t, route_exclude);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::ewma_health;
    use crate::broker::policy::health_deranked;

    #[test]
    fn calm_site_holds_exactly_full_health() {
        // No drift: a fault-free site must stay at exactly 1.0 so
        // HealthAware remains decision-identical to SlaRank.
        let mut h = 1.0;
        for _ in 0..1000 {
            h = ewma_health(h, 0, 0, 0, false);
            assert_eq!(h, 1.0);
        }
    }

    #[test]
    fn sustained_faults_decay_health_below_the_derank_threshold() {
        // One dropped message per tick: the score decays toward the
        // observation and crosses the placement de-rank threshold
        // within a couple of ticks.
        let mut h = 1.0;
        let mut crossed_at = None;
        for tick in 0..10 {
            h = ewma_health(h, 1, 0, 0, false);
            if crossed_at.is_none() && health_deranked(h) {
                crossed_at = Some(tick);
            }
        }
        assert_eq!(crossed_at, Some(1), "h after sustained loss: {h}");
        // Quarantine is far more stressful than a lone drop.
        let hq = ewma_health(1.0, 0, 0, 0, true);
        assert!(hq < ewma_health(1.0, 1, 0, 0, false));
    }

    #[test]
    fn single_blip_stays_inside_the_deadband_and_recovers() {
        // One isolated drop dips the score but not past the de-rank
        // threshold; calm ticks then climb it back toward 1.0
        // monotonically.
        let dipped = ewma_health(1.0, 1, 0, 0, false);
        assert!(dipped < 1.0 && !health_deranked(dipped), "{dipped}");
        let mut h = ewma_health(0.5, 0, 0, 0, false);
        assert!(h > 0.5);
        let mut prev = h;
        for _ in 0..40 {
            h = ewma_health(h, 0, 0, 0, false);
            assert!(h >= prev);
            prev = h;
        }
        assert!(h > 0.99, "recovery stalled at {h}");
    }

    #[test]
    fn health_trajectory_is_deterministic_and_clamped() {
        // Same inputs, same trajectory — bit for bit (the score is in
        // the determinism digest).
        let trace = |seed: u64| -> Vec<u64> {
            let mut h = 1.0;
            (0..50)
                .map(|i| {
                    h = ewma_health(h, (i + seed) % 3, i % 2, 0,
                                    i % 7 == 0);
                    h.to_bits()
                })
                .collect()
        };
        assert_eq!(trace(1), trace(1));
        assert_ne!(trace(1), trace(2));
        // Out-of-range priors are clamped back into [0, 1].
        assert!(ewma_health(5.0, 0, 0, 0, false) <= 1.0);
        assert!(ewma_health(-3.0, 1000, 0, 0, true) >= 0.0);
    }
}
