//! Partitioned LRMS dispatch: site-sharded scheduling behind a thin
//! control-plane arbiter.
//!
//! The paper's cluster distributes *nodes* across cloud sites but keeps
//! one central LRMS placing every job, and the centralized
//! [`crate::cluster::ControlWorld`] reproduces that faithfully — at the
//! cost of control-coupling the whole workload: every placement is a
//! control-shard decision, so the parallel engines run at window
//! overhead parity with serial. This module is the partitioned
//! alternative ([`DispatchMode::Partitioned`]): each
//! [`crate::cluster::SiteWorld`] owns a [`SiteSched`] — a private
//! [`BatchCore`] slice over its local nodes that places jobs during the
//! site's parallel window — and the control plane shrinks to a
//! [`Dispatcher`] that only
//!
//! 1. routes workload-queue blocks to sites (broker-ranked,
//!    health/quarantine-aware, credit-bounded so a site is never sent
//!    more work than its registered capacity), and
//! 2. arbitrates cross-site spillover at barriers: jobs a site cannot
//!    hold are returned in its barrier emission
//!    (`Ev::SiteJobReport::spilled`) and re-routed.
//!
//! ## Two-phase leases — no job is ever double-placed
//!
//! The dispatcher tracks one lease per job. Routing a job to a site
//! bumps its *epoch*; every site report (start, completion, spill)
//! echoes the epoch it was leased under, and the dispatcher accepts a
//! report only if it matches the job's current lease. Re-routing a job
//! away (quarantine, preemption) therefore makes every in-flight report
//! from the old site *stale*: a quarantined site can keep executing its
//! zombie copy to the end, and the completion is simply dropped — the
//! job counts exactly once, at the site that holds the current lease.
//! Within one lease, executions are ordered by a site-local *seq*
//! (crash → local requeue → restart produces a higher seq), so a
//! duplicated or reordered WAN delivery can never rewind the binding:
//! starts are accepted only with `seq > last_seq`, completions only
//! with `seq >= last_seq`.
//!
//! Determinism: the dispatcher runs only at control barriers, the site
//! slices only inside their own shard windows, and every map iteration
//! either folds an order-insensitive sum or walks the dense job table
//! in id order — so Serial/Sharded/Stealing replays stay byte-identical
//! (`tests/partitioned_dispatch.rs` proves it the same way
//! `placement_equivalence.rs` proved the indexed scheduler).

use std::collections::{HashMap, HashSet, VecDeque};

use crate::ids::{NodeId, NodeNames};
use crate::lrms::core::{BatchCore, Placement};
use crate::lrms::{Assignment, Job, JobId, JobState, Lrms, NodeHealth,
                  NodeInfo, NodeStat};
use crate::sim::SimTime;
use crate::util::prng::Prng;
use crate::workload::Workload;

/// Who places jobs onto nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// The paper's shape (and the default): one central LRMS on the
    /// control shard schedules every job.
    Centralized,
    /// Site-sharded scheduling: each site's [`SiteSched`] places jobs
    /// locally; the control plane only routes blocks and arbitrates
    /// spillover.
    Partitioned,
}

/// Partitioned-dispatch tuning knobs ([`crate::cluster::RunConfig`]
/// carries one; the value used is echoed in
/// [`crate::cluster::RunReport::max_blocks_per_barrier`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DispatchConfig {
    /// Routing headroom per barrier, in whole rounds of each site's Up
    /// capacity: the credit extended to a site is
    /// `max_blocks_per_barrier × up-slots − inflight`, and a site's
    /// local backlog may hold the same multiple before
    /// [`SiteSched::spill_excess`] returns the overflow. `1` (the
    /// default) is the classic one-greedy-pass route — byte-identical
    /// to the pre-knob behaviour; larger values keep sites fed for
    /// several rounds per barrier, cutting control traffic on large
    /// streamed traces at the cost of coarser rebalancing.
    pub max_blocks_per_barrier: u32,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        DispatchConfig { max_blocks_per_barrier: 1 }
    }
}

/// One job leased to a site in an `Ev::JobBlock` (and echoed back in
/// spill reports). `epoch` is the lease generation — see the module
/// doc's two-phase contract.
#[derive(Debug, Clone)]
pub struct DispatchJob {
    pub job: JobId,
    pub slots: u32,
    pub epoch: u32,
}

/// One site-local execution event (start or completion) reported to
/// the dispatcher in an `Ev::SiteJobReport`.
///
/// For a start, `at` is the start instant and `secs` the sampled total
/// duration; for a completion, `at` is the completion instant and
/// `secs` the duration actually executed (so `at - secs` recovers the
/// start without trusting report ordering).
#[derive(Debug, Clone)]
pub struct DispatchRun {
    pub job: JobId,
    pub node: NodeId,
    /// Lease epoch the site held when this execution ran.
    pub epoch: u32,
    /// Site-local monotone execution counter (requeue → higher seq).
    pub seq: u32,
    pub at: SimTime,
    pub secs: f64,
}

/// Current lease of one dispatched job.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Lease {
    /// In the control-plane queue, waiting for a route.
    Queued,
    /// Leased to `site`; `on` is the last accepted execution binding
    /// (node, seq), `None` until a start report lands.
    Routed { site: usize, on: Option<(NodeId, u32)> },
    /// Completed (exactly once).
    Done,
}

#[derive(Debug)]
struct DJob {
    slots: u32,
    submitted_at: SimTime,
    /// Lease generation, bumped on every route.
    epoch: u32,
    /// Highest accepted execution seq under the current lease.
    last_seq: u32,
    lease: Lease,
}

/// Outcome of a start report (see [`Dispatcher::on_started`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StartOutcome {
    /// Accepted; if the job was already bound to another node under
    /// this lease (crash → local requeue → restart), that node.
    Fresh { rebound_from: Option<NodeId> },
    /// Stale lease/epoch/seq — dropped.
    Stale,
}

/// Outcome of a completion report (see [`Dispatcher::on_done`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DoneOutcome {
    /// Accepted: the job is Done. `started` is the execution start
    /// (`at - secs`), `submitted_at` the original submission, and
    /// `became_idle` whether this completion drained its node's last
    /// busy slot (occupancy-overlay signal for the recorder).
    Completed {
        started: SimTime,
        submitted_at: SimTime,
        became_idle: bool,
    },
    /// Stale lease/epoch/seq or duplicate — dropped.
    Stale,
}

/// The control-plane half of partitioned dispatch: the workload queue,
/// the per-job lease table, and the occupancy overlay that stands in
/// for the central LRMS's per-node view (CLUES reads it through
/// [`DispatchLrmsView`]).
#[derive(Debug)]
pub struct Dispatcher {
    jobs: Vec<DJob>,
    /// Route queue in submission order (spills return to the front).
    queue: VecDeque<JobId>,
    /// Leased-but-not-Done slots per site (the credit counterweight).
    inflight: Vec<u64>,
    /// Busy slots per granted node, from accepted start/done reports.
    busy: HashMap<NodeId, u32>,
    /// When each currently-idle granted node last became idle.
    idle_since: HashMap<NodeId, f64>,
    done: u32,
    /// Jobs queued or leased-but-unbound, maintained incrementally so
    /// the CLUES pending-depth poll is O(1) at millions of jobs.
    n_unplaced: usize,
    /// Jobs with an accepted start binding, maintained incrementally.
    n_running: usize,
}

impl Dispatcher {
    pub fn new(n_sites: usize) -> Dispatcher {
        Dispatcher {
            jobs: Vec::new(),
            queue: VecDeque::new(),
            inflight: vec![0; n_sites],
            busy: HashMap::new(),
            idle_since: HashMap::new(),
            done: 0,
            n_unplaced: 0,
            n_running: 0,
        }
    }

    /// Enqueue `count` identical `slots`-wide jobs (a workload block).
    pub fn submit(&mut self, count: u32, slots: u32, t: SimTime) {
        let slots = slots.max(1);
        self.n_unplaced += count as usize;
        self.jobs.reserve(count as usize);
        self.queue.reserve(count as usize);
        for _ in 0..count {
            let id = JobId(self.jobs.len() as u64);
            self.jobs.push(DJob {
                slots,
                submitted_at: t,
                epoch: 0,
                last_seq: 0,
                lease: Lease::Queued,
            });
            self.queue.push_back(id);
        }
    }

    /// Jobs waiting for a route right now.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Jobs ever submitted.
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Jobs completed (exactly-once, lease-validated).
    pub fn completed(&self) -> u32 {
        self.done
    }

    /// Jobs not yet bound to a node anywhere: queued at the control
    /// plane or leased to a site but not started. This is the pending
    /// depth CLUES polls for elasticity — an incrementally-maintained
    /// counter, not a job-table scan, so the poll stays O(1) on
    /// multi-million-job streamed traces.
    pub fn unplaced(&self) -> usize {
        debug_assert_eq!(
            self.n_unplaced,
            self.jobs
                .iter()
                .filter(|j| match j.lease {
                    Lease::Queued => true,
                    Lease::Routed { on, .. } => on.is_none(),
                    Lease::Done => false,
                })
                .count()
        );
        self.n_unplaced
    }

    /// Jobs with an accepted start binding and no completion yet.
    pub fn running(&self) -> usize {
        debug_assert_eq!(
            self.n_running,
            self.jobs
                .iter()
                .filter(|j| matches!(j.lease,
                                     Lease::Routed { on: Some(_), .. }))
                .count()
        );
        self.n_running
    }

    /// Slots leased to `site` and not yet completed.
    pub fn inflight(&self, site: usize) -> u64 {
        self.inflight[site]
    }

    /// Peek the next job to route: (id, slots).
    pub fn front(&self) -> Option<(JobId, u32)> {
        self.queue
            .front()
            .map(|&j| (j, self.jobs[j.0 as usize].slots))
    }

    /// Lease the queue-front job to `site` under a fresh epoch.
    pub fn route_front(&mut self, site: usize) -> DispatchJob {
        let id = self.queue.pop_front().expect("route_front: empty queue");
        let j = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(j.lease, Lease::Queued, "routing a leased job");
        j.epoch += 1;
        j.last_seq = 0;
        j.lease = Lease::Routed { site, on: None };
        self.inflight[site] += j.slots as u64;
        DispatchJob { job: id, slots: j.slots, epoch: j.epoch }
    }

    fn unbind(&mut self, node: NodeId, slots: u32, t: f64) {
        if let Some(b) = self.busy.get_mut(&node) {
            *b = b.saturating_sub(slots);
            if *b == 0 {
                self.idle_since.insert(node, t);
            }
        }
    }

    /// A site reports an execution start.
    pub fn on_started(&mut self, site: usize, run: &DispatchRun)
        -> StartOutcome {
        let Some(j) = self.jobs.get_mut(run.job.0 as usize) else {
            return StartOutcome::Stale;
        };
        let Lease::Routed { site: s, on } = j.lease else {
            return StartOutcome::Stale;
        };
        if s != site || j.epoch != run.epoch || run.seq <= j.last_seq {
            return StartOutcome::Stale;
        }
        let slots = j.slots;
        j.last_seq = run.seq;
        j.lease = Lease::Routed { site, on: Some((run.node, run.seq)) };
        let rebound_from = on.map(|(n, _)| n);
        match rebound_from {
            // First accepted binding under this lease: unplaced→running.
            None => {
                self.n_unplaced -= 1;
                self.n_running += 1;
            }
            // A rebind was already running; counts are unchanged.
            Some(old) => self.unbind(old, slots, run.at.0),
        }
        *self.busy.entry(run.node).or_insert(0) += slots;
        self.idle_since.remove(&run.node);
        StartOutcome::Fresh { rebound_from }
    }

    /// A site reports an execution completion.
    pub fn on_done(&mut self, site: usize, run: &DispatchRun)
        -> DoneOutcome {
        let Some(j) = self.jobs.get_mut(run.job.0 as usize) else {
            return DoneOutcome::Stale;
        };
        let Lease::Routed { site: s, on } = j.lease else {
            return DoneOutcome::Stale;
        };
        // `>=`, not `>`: a completion may overtake its own (dropped and
        // retransmitted) start report; it is still the newest execution.
        if s != site || j.epoch != run.epoch || run.seq < j.last_seq {
            return DoneOutcome::Stale;
        }
        let slots = j.slots;
        let submitted_at = j.submitted_at;
        j.lease = Lease::Done;
        // A bound job leaves `running`; one that completed ahead of its
        // lost start report was still counted unplaced.
        if on.is_some() {
            self.n_running -= 1;
        } else {
            self.n_unplaced -= 1;
        }
        self.inflight[site] =
            self.inflight[site].saturating_sub(slots as u64);
        self.done += 1;
        // Release the binding only if this completion is the bound
        // execution; a completion that raced ahead of its start never
        // occupied the overlay.
        let became_idle = match on {
            Some((n, seq)) if n == run.node && seq == run.seq => {
                self.unbind(n, slots, run.at.0);
                self.busy.get(&n).is_some_and(|&b| b == 0)
            }
            _ => false,
        };
        DoneOutcome::Completed {
            started: SimTime(run.at.0 - run.secs),
            submitted_at,
            became_idle,
        }
    }

    /// A site returns a job it cannot hold (spillover). Accepted spills
    /// go back to the *front* of the route queue (they are older than
    /// anything still queued). When accepting several spills from one
    /// report, feed them in reverse so the report order is preserved.
    pub fn on_spilled(&mut self, site: usize, dj: &DispatchJob, t: f64)
        -> bool {
        let Some(j) = self.jobs.get_mut(dj.job.0 as usize) else {
            return false;
        };
        let Lease::Routed { site: s, on } = j.lease else { return false };
        if s != site || j.epoch != dj.epoch {
            return false;
        }
        let slots = j.slots;
        j.lease = Lease::Queued;
        j.last_seq = 0;
        if on.is_some() {
            self.n_running -= 1;
            self.n_unplaced += 1;
        }
        self.inflight[site] =
            self.inflight[site].saturating_sub(slots as u64);
        if let Some((n, _)) = on {
            self.unbind(n, slots, t);
        }
        self.queue.push_front(dj.job);
        true
    }

    /// Revoke every lease held by `site` (its circuit breaker opened):
    /// the jobs return to the route queue front in id order and their
    /// next route bumps the epoch, so everything the site still reports
    /// about them is stale. Returns the revoked ids.
    pub fn reroute_site(&mut self, site: usize, t: f64) -> Vec<JobId> {
        let mut revoked = Vec::new();
        for i in 0..self.jobs.len() {
            let j = &mut self.jobs[i];
            let Lease::Routed { site: s, on } = j.lease else { continue };
            if s != site {
                continue;
            }
            let slots = j.slots;
            j.lease = Lease::Queued;
            j.last_seq = 0;
            if on.is_some() {
                self.n_running -= 1;
                self.n_unplaced += 1;
            }
            self.inflight[site] =
                self.inflight[site].saturating_sub(slots as u64);
            revoked.push(JobId(i as u64));
            if let Some((n, _)) = on {
                self.unbind(n, slots, t);
            }
        }
        for &id in revoked.iter().rev() {
            self.queue.push_front(id);
        }
        revoked
    }

    /// Jobs currently bound to `node`, in id order.
    pub fn jobs_bound_to(&self, node: NodeId) -> Vec<JobId> {
        self.jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| matches!(j.lease,
                Lease::Routed { on: Some((n, _)), .. } if n == node))
            .map(|(i, _)| JobId(i as u64))
            .collect()
    }

    /// A worker node joined (fresh incarnation): start its occupancy
    /// overlay at idle.
    pub fn grant_node(&mut self, node: NodeId, t: f64) {
        self.busy.insert(node, 0);
        self.idle_since.insert(node, t);
    }

    /// A worker node left (terminated/preempted): drop its overlay.
    pub fn drop_node(&mut self, node: NodeId) {
        self.busy.remove(&node);
        self.idle_since.remove(&node);
    }

    fn patch_stat(&self, s: &mut NodeStat) {
        if let Some(&b) = self.busy.get(&s.id) {
            s.used_slots = b.min(s.slots);
            s.idle_since = if b > 0 {
                None
            } else {
                self.idle_since.get(&s.id).map(|&t| SimTime(t))
            };
        }
    }
}

/// Read-only [`Lrms`] view CLUES polls in partitioned mode: node
/// *membership* comes from the central LRMS (which still tracks
/// registration and health), while per-node occupancy and the pending
/// depth come from the dispatcher's lease table — the central core
/// never sees a job. Every `&mut` scheduling entry point is
/// unreachable by construction.
pub struct DispatchLrmsView<'a> {
    pub inner: &'a dyn Lrms,
    pub disp: &'a Dispatcher,
}

impl Lrms for DispatchLrmsView<'_> {
    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn register_node(&mut self, _: &str, _: u32, _: SimTime) {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn deregister_node(&mut self, _: &str, _: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn set_node_health(&mut self, _: &str, _: NodeHealth, _: SimTime)
        -> anyhow::Result<Vec<JobId>> {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn submit(&mut self, _: &str, _: u32, _: SimTime) -> JobId {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn cancel(&mut self, _: JobId, _: SimTime) -> anyhow::Result<()> {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn schedule(&mut self, _: SimTime) -> Vec<Assignment> {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn on_job_finished(&mut self, _: JobId, _: bool, _: SimTime)
        -> anyhow::Result<()> {
        unreachable!("partitioned dispatch view is read-only");
    }

    fn job(&self, _: JobId) -> Option<&Job> {
        // Jobs live in the dispatcher's lease table, not the central
        // core; nothing on the monitoring path resolves them.
        None
    }

    fn jobs(&self) -> Vec<&Job> {
        Vec::new()
    }

    fn nodes(&self) -> Vec<NodeInfo> {
        let mut out = self.inner.nodes();
        for n in &mut out {
            let mut s = NodeStat {
                id: n.id,
                slots: n.slots,
                used_slots: n.used_slots,
                health: n.health,
                registered_at: n.registered_at,
                idle_since: n.idle_since,
            };
            self.disp.patch_stat(&mut s);
            n.used_slots = s.used_slots;
            n.idle_since = s.idle_since;
        }
        out
    }

    fn node_id(&self, name: &str) -> Option<NodeId> {
        self.inner.node_id(name)
    }

    fn node_name(&self, id: NodeId) -> Option<String> {
        self.inner.node_name(id)
    }

    fn node_stat(&self, id: NodeId) -> Option<NodeStat> {
        let mut s = self.inner.node_stat(id)?;
        self.disp.patch_stat(&mut s);
        Some(s)
    }

    fn node_stats(&self) -> Vec<NodeStat> {
        let mut out = Vec::new();
        self.node_stats_into(&mut out);
        out
    }

    fn node_stats_into(&self, out: &mut Vec<NodeStat>) {
        self.inner.node_stats_into(out);
        for s in out.iter_mut() {
            self.disp.patch_stat(s);
        }
    }

    fn pending(&self) -> usize {
        self.disp.unplaced()
    }

    fn running(&self) -> usize {
        self.disp.running()
    }
}

/// The site-shard half of partitioned dispatch: a private [`BatchCore`]
/// over the site's own nodes. Jobs arrive as leased [`DispatchJob`]s,
/// are placed during the site's parallel window, and every start /
/// completion / spill is buffered for the next report-grid flush.
/// Local ids are private to the slice; only global ids cross the WAN.
#[derive(Debug)]
pub struct SiteSched {
    core: BatchCore,
    names: NodeNames,
    /// Per-local-job lease info, dense by local [`JobId`].
    meta: Vec<LocalJob>,
    /// Site-local monotone execution counter (JobTimer generation).
    seq: u32,
    /// Site-local stream for job/setup durations: advanced in site
    /// event order, so all engines sample identically.
    rng: Prng,
    setup_mean: f64,
    /// Local-backlog allowance in rounds of Up capacity
    /// ([`DispatchConfig::max_blocks_per_barrier`]): the spill
    /// threshold scales with the routing credit, or k-round credit
    /// would immediately bounce as spill storms.
    backlog_rounds: u64,
    /// Node incarnations that already paid the one-time setup.
    setup_paid: HashSet<NodeId>,
    pub started_buf: Vec<DispatchRun>,
    pub done_buf: Vec<DispatchRun>,
    pub spill_buf: Vec<DispatchJob>,
}

#[derive(Debug, Clone, Copy)]
struct LocalJob {
    global: JobId,
    epoch: u32,
    slots: u32,
    /// Seq of the current execution (0 = never started).
    cur_seq: u32,
    /// Sampled duration of the current execution.
    cur_secs: f64,
}

impl SiteSched {
    pub fn new(placement: Placement, names: NodeNames, seed: u64,
               setup_mean: f64, max_blocks_per_barrier: u32) -> SiteSched {
        SiteSched {
            core: BatchCore::with_names(placement, names.clone()),
            names,
            meta: Vec::new(),
            seq: 0,
            rng: Prng::new(seed),
            setup_mean,
            backlog_rounds: max_blocks_per_barrier.max(1) as u64,
            setup_paid: HashSet::new(),
            started_buf: Vec::new(),
            done_buf: Vec::new(),
            spill_buf: Vec::new(),
        }
    }

    /// The control plane granted this site a worker node (fresh VM
    /// incarnation — it pays the one-time setup again).
    pub fn grant(&mut self, node: NodeId, slots: u32, t: SimTime) {
        let name = self.names.name(node);
        self.core.register_node(&name, slots, t);
        self.setup_paid.remove(&node);
    }

    /// A local node died or was decommissioned: remove it from the
    /// slice. Its running jobs requeue to the local queue front (the
    /// next sweep re-places or spills them).
    pub fn deregister(&mut self, node: NodeId, t: SimTime) {
        let name = self.names.name(node);
        if self.core.node_id(&name).is_some() {
            let _ = self.core.deregister_node(&name, t);
        }
        self.setup_paid.remove(&node);
    }

    /// Accept a routed block into the local queue.
    pub fn submit_block(&mut self, jobs: &[DispatchJob], t: SimTime) {
        for dj in jobs {
            let lid = self.core.submit("", dj.slots, t);
            debug_assert_eq!(lid.0 as usize, self.meta.len());
            self.meta.push(LocalJob {
                global: dj.job,
                epoch: dj.epoch,
                slots: dj.slots.max(1),
                cur_seq: 0,
                cur_secs: 0.0,
            });
        }
    }

    /// One local scheduling sweep: place what fits, sample durations,
    /// buffer start reports. Returns `(node, local job, seq, secs)`
    /// per start so the caller can schedule the completion timers.
    pub fn sweep(&mut self, t: SimTime)
        -> Vec<(NodeId, JobId, u32, f64)> {
        let placed = self.core.schedule(t);
        let mut out = Vec::with_capacity(placed.len());
        for (lid, node) in placed {
            let mut secs = Workload::sample_job_secs(&mut self.rng);
            if self.setup_paid.insert(node) {
                // First job on a fresh incarnation pays the one-time
                // udocker/image setup (the paper's 4 min 30 s ± 15%).
                secs += self.rng.uniform(self.setup_mean * 0.85,
                                         self.setup_mean * 1.15);
            }
            self.seq += 1;
            let m = &mut self.meta[lid.0 as usize];
            m.cur_seq = self.seq;
            m.cur_secs = secs;
            self.started_buf.push(DispatchRun {
                job: m.global,
                node,
                epoch: m.epoch,
                seq: self.seq,
                at: t,
                secs,
            });
            out.push((node, lid, self.seq, secs));
        }
        out
    }

    /// A completion timer fired. Returns true if it was the *current*
    /// execution of a still-running local job (stale timers from
    /// requeued-away executions are dropped here, before any state
    /// changes).
    pub fn finish(&mut self, lid: JobId, node: NodeId, gen: u32,
                  t: SimTime) -> bool {
        let Some(m) = self.meta.get(lid.0 as usize).copied() else {
            return false;
        };
        if m.cur_seq != gen {
            return false;
        }
        match self.core.job(lid) {
            Some(j) if j.state == JobState::Running
                && j.node == Some(node) => {}
            _ => return false,
        }
        self.core
            .on_job_finished(lid, true, t)
            .expect("validated Running above");
        self.done_buf.push(DispatchRun {
            job: m.global,
            node,
            epoch: m.epoch,
            seq: m.cur_seq,
            at: t,
            secs: m.cur_secs,
        });
        true
    }

    /// Spill the local backlog the site can no longer hold: the local
    /// queue may back up to `backlog_rounds` full rounds of the site's
    /// Up capacity (one round's jobs start within one job length);
    /// anything beyond that — in particular the *whole* queue when
    /// capacity dropped to zero — is returned to the dispatcher.
    /// Returns the number spilled.
    pub fn spill_excess(&mut self, t: SimTime) -> usize {
        let cap = self.core.up_slots().saturating_mul(self.backlog_rounds);
        let pending = self.core.pending() as u64;
        // Jobs here are 1-slot (the paper's workload), so the count
        // check is exact; the keep loop below is slot-accurate anyway.
        if pending == 0 || pending <= cap {
            return 0;
        }
        let drained = self.core.drain_pending(t);
        let mut kept: u64 = 0;
        let mut spilled = 0;
        for lid in drained {
            let m = self.meta[lid.0 as usize];
            if kept + m.slots as u64 <= cap {
                kept += m.slots as u64;
                let nlid = self.core.submit("", m.slots, t);
                debug_assert_eq!(nlid.0 as usize, self.meta.len());
                self.meta.push(LocalJob { cur_seq: 0, cur_secs: 0.0, ..m });
            } else {
                self.spill_buf.push(DispatchJob {
                    job: m.global,
                    slots: m.slots,
                    epoch: m.epoch,
                });
                spilled += 1;
            }
        }
        spilled
    }

    /// Anything buffered for the next report flush?
    pub fn has_reports(&self) -> bool {
        !self.started_buf.is_empty()
            || !self.done_buf.is_empty()
            || !self.spill_buf.is_empty()
    }

    /// Drain the report buffers: (started, done, spilled).
    pub fn take_reports(&mut self)
        -> (Vec<DispatchRun>, Vec<DispatchRun>, Vec<DispatchJob>) {
        (std::mem::take(&mut self.started_buf),
         std::mem::take(&mut self.done_buf),
         std::mem::take(&mut self.spill_buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn started(job: u64, node: NodeId, epoch: u32, seq: u32, at: f64,
               secs: f64) -> DispatchRun {
        DispatchRun { job: JobId(job), node, epoch, seq, at: t(at), secs }
    }

    #[test]
    fn lease_lifecycle_exactly_once() {
        let mut d = Dispatcher::new(2);
        d.submit(2, 1, t(0.0));
        assert_eq!(d.queued(), 2);
        assert_eq!(d.unplaced(), 2);
        let a = d.route_front(0);
        let b = d.route_front(1);
        assert_eq!(a.epoch, 1);
        assert_eq!(d.inflight(0), 1);
        assert_eq!(d.inflight(1), 1);
        let n = NodeId(0);
        d.grant_node(n, 0.0);
        let r = started(a.job.0, n, a.epoch, 1, 5.0, 17.0);
        assert_eq!(d.on_started(0, &r),
                   StartOutcome::Fresh { rebound_from: None });
        assert_eq!(d.unplaced(), 1); // b leased but unbound
        assert_eq!(d.running(), 1);
        // Duplicate start (same seq) is stale.
        assert_eq!(d.on_started(0, &r), StartOutcome::Stale);
        // Wrong-site completion is stale.
        let done = started(a.job.0, n, a.epoch, 1, 22.0, 17.0);
        assert_eq!(d.on_done(1, &done), DoneOutcome::Stale);
        match d.on_done(0, &done) {
            DoneOutcome::Completed { started, became_idle, .. } => {
                assert_eq!(started, t(5.0));
                assert!(became_idle);
            }
            o => panic!("{o:?}"),
        }
        assert_eq!(d.completed(), 1);
        assert_eq!(d.inflight(0), 0);
        // Second completion of the same job: dropped.
        assert_eq!(d.on_done(0, &done), DoneOutcome::Stale);
        assert_eq!(d.completed(), 1);
        let _ = b;
    }

    #[test]
    fn crash_requeue_rebinds_with_higher_seq() {
        let mut d = Dispatcher::new(1);
        d.submit(1, 1, t(0.0));
        let dj = d.route_front(0);
        let (n1, n2) = (NodeId(0), NodeId(1));
        d.grant_node(n1, 0.0);
        d.grant_node(n2, 0.0);
        d.on_started(0, &started(0, n1, dj.epoch, 1, 1.0, 10.0));
        // The node died; the site requeued and restarted on n2.
        let r2 = started(0, n2, dj.epoch, 3, 4.0, 10.0);
        assert_eq!(d.on_started(0, &r2),
                   StartOutcome::Fresh { rebound_from: Some(n1) });
        // A delayed duplicate of the first start cannot rewind.
        assert_eq!(d.on_started(0, &started(0, n1, dj.epoch, 1, 1.0, 10.0)),
                   StartOutcome::Stale);
        // The stale execution's node is free again in the overlay.
        let view_busy = d.jobs_bound_to(n1);
        assert!(view_busy.is_empty());
        assert_eq!(d.jobs_bound_to(n2), vec![JobId(0)]);
    }

    #[test]
    fn completion_may_overtake_lost_start() {
        let mut d = Dispatcher::new(1);
        d.submit(1, 1, t(0.0));
        let dj = d.route_front(0);
        let n = NodeId(0);
        d.grant_node(n, 0.0);
        // Start report dropped by the WAN; completion arrives first.
        let done = started(0, n, dj.epoch, 1, 20.0, 15.0);
        match d.on_done(0, &done) {
            DoneOutcome::Completed { started, became_idle, .. } => {
                assert_eq!(started, t(5.0));
                assert!(!became_idle); // never occupied the overlay
            }
            o => panic!("{o:?}"),
        }
        // The retransmitted start finally lands: job already Done.
        assert_eq!(d.on_started(0, &started(0, n, dj.epoch, 1, 5.0, 15.0)),
                   StartOutcome::Stale);
    }

    #[test]
    fn spill_returns_to_queue_front_in_report_order() {
        let mut d = Dispatcher::new(2);
        d.submit(4, 1, t(0.0));
        let a = d.route_front(0);
        let b = d.route_front(0);
        // Site 0 spills both (zero capacity): feed in reverse to keep
        // report order at the queue front, ahead of jobs 2 and 3.
        for dj in [&b, &a] {
            assert!(d.on_spilled(0, dj, 1.0));
        }
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.front().map(|(j, _)| j), Some(a.job));
        let ra = d.route_front(1);
        assert_eq!(ra.job, a.job);
        assert_eq!(ra.epoch, 2); // re-route bumped the epoch
        // The old site's late report about `a` is now stale.
        assert!(!d.on_spilled(0, &a, 2.0));
        assert_eq!(d.on_started(0, &started(a.job.0, NodeId(0), a.epoch,
                                            1, 2.0, 10.0)),
                   StartOutcome::Stale);
    }

    #[test]
    fn reroute_site_revokes_all_leases_and_stales_zombies() {
        let mut d = Dispatcher::new(2);
        d.submit(3, 1, t(0.0));
        let a = d.route_front(0);
        let b = d.route_front(0);
        let c = d.route_front(1);
        let n = NodeId(0);
        d.grant_node(n, 0.0);
        d.on_started(0, &started(a.job.0, n, a.epoch, 1, 1.0, 10.0));
        let revoked = d.reroute_site(0, 2.0);
        assert_eq!(revoked, vec![a.job, b.job]);
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.inflight(1), 1); // site 1's lease untouched
        assert_eq!(d.front().map(|(j, _)| j), Some(a.job));
        // The quarantined site's zombie completion is dropped even
        // before the re-route happens (lease is Queued) ...
        assert_eq!(d.on_done(0, &started(a.job.0, n, a.epoch, 1, 11.0,
                                         10.0)),
                   DoneOutcome::Stale);
        // ... and after the re-route the epoch no longer matches.
        let ra = d.route_front(1);
        assert_eq!(ra.epoch, a.epoch + 1);
        assert_eq!(d.on_done(0, &started(a.job.0, n, a.epoch, 1, 11.0,
                                         10.0)),
                   DoneOutcome::Stale);
        let _ = c;
    }

    #[test]
    fn occupancy_overlay_tracks_grant_bind_idle_drop() {
        let mut d = Dispatcher::new(1);
        d.submit(1, 1, t(0.0));
        let dj = d.route_front(0);
        let n = NodeId(3);
        d.grant_node(n, 1.0);
        let mut s = NodeStat {
            id: n,
            slots: 2,
            used_slots: 0,
            health: NodeHealth::Up,
            registered_at: t(1.0),
            idle_since: Some(t(1.0)),
        };
        d.patch_stat(&mut s);
        assert_eq!(s.used_slots, 0);
        assert_eq!(s.idle_since, Some(t(1.0)));
        d.on_started(0, &started(0, n, dj.epoch, 1, 2.0, 10.0));
        d.patch_stat(&mut s);
        assert_eq!(s.used_slots, 1);
        assert_eq!(s.idle_since, None);
        d.on_done(0, &started(0, n, dj.epoch, 1, 12.0, 10.0));
        d.patch_stat(&mut s);
        assert_eq!(s.used_slots, 0);
        assert_eq!(s.idle_since, Some(t(12.0)));
        d.drop_node(n);
        let before = s;
        d.patch_stat(&mut s);
        assert_eq!(s, before); // no overlay entry -> stat untouched
    }

    #[test]
    fn site_sched_places_reports_and_finishes() {
        let names = NodeNames::new();
        let mut s = SiteSched::new(Placement::PackFirstFit, names.clone(),
                                   7, 270.0, 1);
        let n = names.intern("vnode-1");
        s.grant(n, 1, t(0.0));
        s.submit_block(&[DispatchJob { job: JobId(40), slots: 1,
                                       epoch: 1 }],
                       t(1.0));
        let starts = s.sweep(t(1.0));
        assert_eq!(starts.len(), 1);
        let (node, lid, seq, secs) = starts[0];
        assert_eq!(node, n);
        assert_eq!(seq, 1);
        // First job on the node pays setup: 15–20s + 270s ± 15%.
        assert!(secs > 240.0, "{secs}");
        assert_eq!(s.started_buf.len(), 1);
        assert_eq!(s.started_buf[0].job, JobId(40));
        // Stale generation is dropped; the real one completes.
        assert!(!s.finish(lid, node, seq + 1, t(2.0)));
        assert!(s.finish(lid, node, seq, t(1.0 + secs)));
        assert!(!s.finish(lid, node, seq, t(2.0))); // not Running anymore
        assert_eq!(s.done_buf.len(), 1);
        assert_eq!(s.done_buf[0].secs, secs);
        let (st, dn, sp) = s.take_reports();
        assert_eq!((st.len(), dn.len(), sp.len()), (1, 1, 0));
        assert!(!s.has_reports());
        // Second job on the same node pays no setup.
        s.submit_block(&[DispatchJob { job: JobId(41), slots: 1,
                                       epoch: 1 }],
                       t(400.0));
        let starts = s.sweep(t(400.0));
        assert!(starts[0].3 < 21.0, "{}", starts[0].3);
    }

    #[test]
    fn zero_capacity_site_spills_its_whole_block() {
        // Edge case (a): a site with no Up capacity returns everything.
        let names = NodeNames::new();
        let mut s = SiteSched::new(Placement::PackFirstFit, names.clone(),
                                   7, 270.0, 1);
        let jobs: Vec<DispatchJob> = (0..3)
            .map(|i| DispatchJob { job: JobId(i), slots: 1, epoch: 1 })
            .collect();
        s.submit_block(&jobs, t(0.0));
        assert!(s.sweep(t(0.0)).is_empty());
        assert_eq!(s.spill_excess(t(0.0)), 3);
        let spilled: Vec<u64> =
            s.spill_buf.iter().map(|d| d.job.0).collect();
        assert_eq!(spilled, vec![0, 1, 2]); // submission order preserved
    }

    #[test]
    fn capacity_loss_spills_only_the_excess_backlog() {
        let names = NodeNames::new();
        let mut s = SiteSched::new(Placement::PackFirstFit, names.clone(),
                                   7, 270.0, 1);
        let n1 = names.intern("vnode-1");
        let n2 = names.intern("vnode-2");
        s.grant(n1, 1, t(0.0));
        s.grant(n2, 1, t(0.0));
        let jobs: Vec<DispatchJob> = (0..4)
            .map(|i| DispatchJob { job: JobId(i), slots: 1, epoch: 1 })
            .collect();
        s.submit_block(&jobs, t(0.0));
        let starts = s.sweep(t(0.0));
        assert_eq!(starts.len(), 2); // 0 and 1 running, 2 and 3 queued
        assert_eq!(s.spill_excess(t(0.0)), 0); // backlog == capacity
        // One node dies: its job requeues locally, capacity halves, and
        // the backlog (3 pending vs capacity 1) spills the two newest.
        s.deregister(n1, t(1.0));
        assert_eq!(s.spill_excess(t(1.0)), 2);
        let spilled: Vec<u64> =
            s.spill_buf.iter().map(|d| d.job.0).collect();
        assert_eq!(spilled, vec![2, 3]);
        // The requeued job restarts with a fresh seq on the survivor
        // once its slot frees.
        let (_, lid1, seq1, secs1) = starts[1];
        assert!(s.finish(lid1, n2, seq1, t(secs1)));
        let restarted = s.sweep(t(secs1 + 1.0));
        assert_eq!(restarted.len(), 1);
        assert!(restarted[0].2 > seq1);
    }
}
