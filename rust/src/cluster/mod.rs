//! The hybrid virtual elastic cluster: public façade + simulation world.
//!
//! This module wires every component into the deployment flow of the
//! paper's §3.1 and the use-case dynamics of §4:
//!
//! 1. a TOSCA template is submitted to the orchestrator,
//! 2. the orchestrator ranks sites (SLAs + monitoring) and delegates to
//!    the IM, which creates networks first, then VMs, then runs
//!    contextualization over SSH reverse tunnels,
//! 3. the front-end comes up as LRMS controller + NFS server + vRouter
//!    central point (the only public IP),
//! 4. CLUES watches the queue: bursting to further sites provisions a
//!    site vRouter there before the first worker,
//! 5. jobs run; the first job on each node pays the one-time udocker
//!    setup; inference is served by the PJRT runtime,
//! 6. idle nodes power off (pending power-offs cancel if jobs arrive),
//!    down-flapping nodes get failed + replaced (vnode-5).
//!
//! Everything advances on the discrete-event queue of [`crate::sim`], so
//! a 5h40m run replays in milliseconds; the PJRT inference calls are real
//! compute, sampled per job according to [`RunConfig::inference_every`].
//!
//! Scale architecture: one [`NodeNames`] interner is shared by the LRMS,
//! CLUES and the metrics recorder, and every per-event structure (node
//! runtime map, events, accounting indices) is keyed by the dense
//! [`NodeId`] — the job-completion hot path performs no string hashing,
//! cloning, or O(nodes) scans. Events are routed through the sharded
//! queue of [`crate::sim::shard`]: every [`Ev`] declares a shard key
//! (its cloud site, or the control shard for orchestrator/CLUES/deploy
//! traffic), so the replay order is the engine's deterministic
//! `(time, shard, seq)` merge. The full cluster world runs in merged
//! (serial) mode — its handlers touch the shared LRMS/CLUES state on
//! every event — while fully site-local worlds (see `benches/scale.rs`)
//! replay their shards in parallel.

use std::collections::{HashMap, HashSet};

use anyhow::Context;

use crate::broker::{ElasticityBroker, PolicyKind, ScenarioEvent,
                    ScenarioPlan};
use crate::clues::{Action, Clues, CluesConfig, PowerState};
use crate::cloudsim::{CloudSite, SiteSpec, VmId};
use crate::ids::{NodeId, NodeNames};
use crate::im::{Im, NodeRole};
use crate::lrms::{HtCondor, JobId, Lrms, NodeHealth, NodeStat, Slurm};
use crate::metrics::{DisplayState, Recorder, ShardSink};
use crate::netsim::{LinkSpec, Network};
use crate::orchestrator::{Sla, UpdateId, UpdateOp, WorkflowEngine};
use crate::runtime::ModelRuntime;
use crate::sim::{run_merged_until, MergedWorld, ShardEvent, ShardKey,
                 ShardedQueue, SimTime};
use crate::tosca::{ClusterTemplate, LrmsKind};
use crate::util::prng::Prng;
use crate::vrouter::Overlay;
use crate::workload::Workload;

/// Per-run configuration.
pub struct RunConfig {
    pub template: ClusterTemplate,
    pub sites: Vec<SiteSpec>,
    pub slas: Vec<Sla>,
    pub workload: Workload,
    pub seed: u64,
    /// Scripted monitor glitches (the vnode-5 transient).
    pub injections: crate::cloudsim::InjectionPlan,
    /// Which broker policy owns the grow-to-which-site decision
    /// (`SlaRank` reproduces the legacy `select_site` exactly).
    pub policy: PolicyKind,
    /// Scripted elasticity scenario — spot-preemption waves, site
    /// outages, price spikes — with times relative to the workload t0
    /// (the same convention as `injections`).
    pub scenario: ScenarioPlan,
    /// Paper default true; false = parallel-provisioning ablation.
    pub serialized_orchestrator: bool,
    /// Run real PJRT inference for one out of every N jobs
    /// (0 = never; 1 = every job). Virtual job time is unaffected.
    pub inference_every: u32,
    /// Simulation horizon (safety stop).
    pub horizon: SimTime,
    /// When set, the recorder streams transitions/job-runs/milestones
    /// to spill files under this directory during the replay instead of
    /// accumulating them in memory; the report's recorder is rebuilt
    /// from the spill at run end. Constant-memory metrics for long
    /// replays — figures and reports are byte-identical either way.
    pub metrics_spill_dir: Option<std::path::PathBuf>,
}

impl RunConfig {
    /// The paper's §4 scenario: CESNET (quota 3) + AWS, SLURM template,
    /// full workload, serialized orchestrator.
    pub fn paper_usecase(scale: f64, seed: u64) -> RunConfig {
        let template = crate::tosca::builtin("slurm").expect("template");
        RunConfig {
            template,
            sites: vec![SiteSpec::cesnet_metacentrum(),
                        SiteSpec::aws_us_east_2()],
            slas: vec![
                Sla { site_name: "CESNET-MCC".into(), priority: 0,
                      max_instances: None },
                Sla { site_name: "AWS".into(), priority: 1,
                      max_instances: None },
            ],
            workload: Workload::paper(scale),
            seed,
            injections: crate::cloudsim::InjectionPlan::default(),
            policy: PolicyKind::SlaRank,
            scenario: ScenarioPlan::default(),
            serialized_orchestrator: true,
            inference_every: 0,
            horizon: SimTime::from_hms(48, 0, 0),
            metrics_spill_dir: None,
        }
    }
}

/// Simulation events. Node references are interned ids; names are
/// resolved only when a milestone or report line is rendered. Every
/// event declares its shard: site-local traffic carries its cloud-site
/// index, orchestrator/CLUES/deploy traffic rides the control shard.
#[derive(Debug, Clone)]
pub enum Ev {
    /// Kick off the initial deployment (FE + initial workers).
    Deploy,
    /// Submit workload block `i`.
    SubmitBlock(usize),
    /// A VM finished booting.
    VmBooted { site: usize, vm: VmId, node: NodeId, failed: bool },
    /// Contextualization finished for a node.
    CtxDone { site: usize, node: NodeId },
    /// A job finished on a node. `gen` is the job's requeue count at
    /// scheduling time, so stale completions from executions that were
    /// requeued away (node failure) are recognized and dropped.
    JobDone { site: usize, job: JobId, node: NodeId, gen: u32 },
    /// CLUES monitor tick.
    CluesTick,
    /// The workflow engine may start queued updates.
    OrchestratorPump,
    /// Provider finished terminating a node's VM.
    TerminationDone { site: usize, node: NodeId, update: Option<UpdateId> },
    /// A running VM hard-crashed (stochastic failure injection).
    VmCrashed { site: usize, vm: VmId, node: NodeId },
    /// The provider reclaimed a running VM's spot capacity (stochastic
    /// per-site hazard; the scripted twin is [`Ev::SpotWave`]).
    VmPreempted { site: usize, vm: VmId, node: NodeId },
    /// Scenario: spot-preemption wave — up to `count` (0 = all) running
    /// workers at `site` are reclaimed at once.
    SpotWave { site: usize, count: u32 },
    /// Scenario: whole-site outage begins / ends.
    OutageStart { site: usize },
    OutageEnd { site: usize },
    /// Scenario: price spike begins / ends at a site.
    PriceSpikeStart { site: usize, factor: f64 },
    PriceSpikeEnd { site: usize },
}

impl ShardEvent for Ev {
    fn shard_key(&self) -> ShardKey {
        match self {
            Ev::Deploy
            | Ev::SubmitBlock(_)
            | Ev::CluesTick
            | Ev::OrchestratorPump => ShardKey::Control,
            Ev::VmBooted { site, .. }
            | Ev::CtxDone { site, .. }
            | Ev::JobDone { site, .. }
            | Ev::TerminationDone { site, .. }
            | Ev::VmCrashed { site, .. }
            | Ev::VmPreempted { site, .. }
            | Ev::SpotWave { site, .. }
            | Ev::OutageStart { site }
            | Ev::OutageEnd { site }
            | Ev::PriceSpikeStart { site, .. }
            | Ev::PriceSpikeEnd { site } => ShardKey::Site(*site as u32),
        }
    }
}

/// Runtime info per deployment node.
#[derive(Debug, Clone, Copy)]
struct NodeRt {
    site: usize,
    vm: VmId,
    role: NodeRole,
    /// One-time udocker setup already paid?
    setup_done: bool,
    requested_at: SimTime,
    joined_at: Option<SimTime>,
}

/// Per-VM-incarnation accounting row (names are reused after
/// termination, so rows — not names — are the unit of accounting).
#[derive(Debug, Clone)]
pub struct PerVm {
    pub name: String,
    pub site: String,
    pub role: NodeRole,
    pub hours: f64,
    pub cost_usd: f64,
    pub busy_hours: f64,
}

/// Final report of a run — everything the figures/tables need.
pub struct RunReport {
    pub recorder: Recorder,
    pub makespan: SimTime,
    pub jobs_completed: u32,
    pub total_cost_usd: f64,
    /// One row per VM incarnation.
    pub per_vm: Vec<PerVm>,
    /// (node, requested_at, joined_at) deployment latencies.
    pub deploy_times: Vec<(String, SimTime, SimTime)>,
    /// Busy (job-executing) seconds per node.
    pub busy_secs: HashMap<String, f64>,
    /// Real PJRT inferences actually executed.
    pub inferences_run: u64,
    /// Sum of inference wall-clock seconds (real, not simulated).
    pub inference_wall_secs: f64,
    /// Events dispatched (DES perf counter).
    pub events: u64,
    /// Wall-clock seconds the simulation took.
    pub wall_secs: f64,
    /// Broker policy that governed worker placement.
    pub policy: &'static str,
    /// VMs lost to preemption waves / site outages / spot reclaims.
    pub preempted_vms: u32,
    /// Jobs requeued by those losses.
    pub preempted_jobs: u32,
    /// Of those, jobs that went on to complete (recovery).
    pub preempt_recovered: u32,
}

impl RunReport {
    /// §4.2 effective utilization: job-execution time over paid time of
    /// the paid *worker* nodes (the paper's "66% of the paid time of
    /// these nodes was used in effective job computation").
    pub fn paid_utilization(&self) -> f64 {
        let (busy, paid) = self
            .per_vm
            .iter()
            .filter(|r| r.cost_usd > 0.0 && r.role == NodeRole::WorkerNode)
            .fold((0.0, 0.0), |(b, p), r| {
                (b + r.busy_hours, p + r.hours)
            });
        if paid == 0.0 { 0.0 } else { busy / paid }
    }
}

/// The simulation world (also the public cluster handle).
pub struct HybridCluster {
    pub cfg: RunConfig,
    pub sites: Vec<CloudSite>,
    pub net: Network,
    pub overlay: Overlay,
    pub lrms: Box<dyn Lrms>,
    pub clues: Clues,
    pub engine: WorkflowEngine,
    pub im: Im,
    /// Multi-site elasticity broker (owns grow-to-which-site).
    pub broker: ElasticityBroker,
    pub recorder: Recorder,
    /// Cluster-wide name⇄id interner (shared with lrms/clues/recorder).
    names: NodeNames,
    nodes: HashMap<NodeId, NodeRt>,
    /// node → in-progress AddWorker update to complete on join.
    update_for_node: HashMap<NodeId, UpdateId>,
    /// node → contextualization duration (sampled at provision).
    ctx_secs: HashMap<NodeId, f64>,
    /// Permanent archive of (node, requested, joined) — survives node
    /// termination, unlike the live `nodes` map.
    deploy_log: Vec<(String, SimTime, SimTime)>,
    /// One accounting record per VM incarnation (ledger row index).
    vm_records: Vec<VmRec>,
    /// node → index into vm_records for the live incarnation.
    live_record: HashMap<NodeId, usize>,
    /// jobs submitted so far / completed.
    jobs_submitted: u32,
    jobs_completed: u32,
    next_file_id: u64,
    rng: Prng,
    fe_site: usize,
    fe_ready: bool,
    initial_pending: u32,
    deploy_update: Option<UpdateId>,
    /// Optional real-inference runtime.
    runtime: Option<ModelRuntime>,
    inferences_run: u64,
    inference_wall_secs: f64,
    clues_ticking: bool,
    /// When the initial cluster came up (workload + injection t=0).
    workload_t0: SimTime,
    /// Jobs requeued by a preemption/outage, awaiting completion.
    preempt_pending: HashSet<JobId>,
    preempted_vms: u32,
    preempted_jobs: u32,
    preempt_recovered: u32,
    /// Active price-spike windows per site: the latest spike's factor
    /// rules while any window is open; list price returns only when
    /// the count drains to zero (overlapping spikes compose).
    price_spikes_active: Vec<u32>,
    /// Scratch buffer for per-tick node snapshots (reused; a 10k-node
    /// tick allocates no per-tick `Vec`).
    stats_scratch: Vec<NodeStat>,
}

#[derive(Debug, Clone)]
struct VmRec {
    name: String,
    site: usize,
    role: NodeRole,
    /// Index of this incarnation's row in the site ledger.
    ledger_idx: usize,
    busy_secs: f64,
}

const FE_NAME: &str = "front-end";

impl HybridCluster {
    /// Build the world (no events run yet).
    pub fn new(cfg: RunConfig) -> anyhow::Result<HybridCluster> {
        let mut net = Network::new();
        let mut sites = Vec::new();
        for (i, spec) in cfg.sites.iter().enumerate() {
            let loc = net.add_location(&spec.name);
            sites.push(CloudSite::new(spec.clone(), i as u8, loc,
                                      cfg.seed ^ (i as u64 + 1)));
        }
        // Underlay links: research-net WAN between academic sites,
        // transatlantic to AWS.
        for i in 0..sites.len() {
            for j in (i + 1)..sites.len() {
                let spec = if sites[i].spec.name == "AWS"
                    || sites[j].spec.name == "AWS"
                {
                    LinkSpec::transatlantic()
                } else {
                    LinkSpec::wan()
                };
                let (a, b) = (sites[i].net_id, sites[j].net_id);
                net.set_link(a, b, spec);
            }
        }
        // One interner shared by every node-identity consumer.
        let names = NodeNames::new();
        let lrms: Box<dyn Lrms> = match cfg.template.lrms {
            LrmsKind::Slurm => Box::new(Slurm::with_names(names.clone())),
            LrmsKind::HtCondor => {
                Box::new(HtCondor::with_names(names.clone()))
            }
        };
        let clues = Clues::with_names(CluesConfig {
            idle_timeout_s: cfg.template.idle_timeout_s,
            min_workers: cfg.template.scalable.min_instances,
            max_workers: cfg.template.scalable.max_instances,
            ..CluesConfig::default()
        }, names.clone());
        let overlay = Overlay::new(cfg.template.vpn_cipher);
        let engine = WorkflowEngine::new(cfg.serialized_orchestrator);
        let im = Im::new(cfg.seed);
        let broker = ElasticityBroker::new(
            cfg.policy,
            &sites,
            &cfg.slas,
            cfg.template.worker.num_cpus,
            cfg.template.worker.mem_gb,
        );
        let runtime = if cfg.inference_every > 0 {
            Some(ModelRuntime::load(crate::runtime::artifacts_dir(), 1)
                .context("loading PJRT runtime (run `make artifacts`)")?)
        } else {
            None
        };
        let rng = Prng::new(cfg.seed ^ 0xC1);
        let n_sites = sites.len();
        // The cluster replays in merged (serial) mode, so its metrics
        // form a single logical shard; spill mode streams it to disk.
        let recorder = match &cfg.metrics_spill_dir {
            Some(dir) => Recorder::with_spill(
                names.clone(),
                ShardSink::create(dir, 0)
                    .context("creating metrics spill sink")?,
            ),
            None => Recorder::with_names(names.clone()),
        };
        Ok(HybridCluster {
            sites,
            net,
            overlay,
            lrms,
            clues,
            engine,
            im,
            broker,
            recorder,
            names,
            nodes: HashMap::new(),
            update_for_node: HashMap::new(),
            ctx_secs: HashMap::new(),
            deploy_log: Vec::new(),
            vm_records: Vec::new(),
            live_record: HashMap::new(),
            jobs_submitted: 0,
            jobs_completed: 0,
            next_file_id: 0,
            rng,
            fe_site: 0,
            fe_ready: false,
            initial_pending: 0,
            deploy_update: None,
            runtime,
            inferences_run: 0,
            inference_wall_secs: 0.0,
            clues_ticking: false,
            workload_t0: SimTime::ZERO,
            preempt_pending: HashSet::new(),
            preempted_vms: 0,
            preempted_jobs: 0,
            preempt_recovered: 0,
            price_spikes_active: vec![0; n_sites],
            stats_scratch: Vec::new(),
            cfg,
        })
    }

    /// Deploy + run the full scenario to completion. Returns the report.
    pub fn run(mut self) -> anyhow::Result<RunReport> {
        let wall0 = std::time::Instant::now();
        let mut q: ShardedQueue<Ev> = ShardedQueue::new(self.sites.len());
        // The paper's timeline (Fig. 9) is relative to the moment the
        // initial cluster is up; workload blocks are scheduled when the
        // InitialDeploy update completes.
        q.schedule_at(SimTime::ZERO, Ev::Deploy);
        let horizon = self.cfg.horizon;
        run_merged_until(&mut self, &mut q, horizon);
        let makespan = q.now();

        // Spill mode: flush the stream and rebuild the in-memory
        // recorder from it, so the report and figures see exactly the
        // data an in-memory run would have accumulated.
        if self.recorder.is_spilling() {
            let files = self
                .recorder
                .finish_spill()
                .expect("is_spilling checked")
                .context("flushing metrics spill")?;
            self.recorder =
                Recorder::merge_spills(self.names.clone(), &[files])
                    .context("merging metrics spill")?;
        }

        // ---- report assembly -------------------------------------------
        let mut per_vm = Vec::new();
        let mut total = 0.0;
        for rec in &self.vm_records {
            let site = &self.sites[rec.site];
            let entry = &site.ledger.entries[rec.ledger_idx];
            let hours = entry.secs(makespan) / 3600.0;
            let cost = entry.cost(makespan);
            total += cost;
            per_vm.push(PerVm {
                name: rec.name.clone(),
                site: site.spec.name.clone(),
                role: rec.role,
                hours,
                cost_usd: cost,
                busy_hours: rec.busy_secs / 3600.0,
            });
        }
        let deploy_times = self.deploy_log.clone();
        let busy_secs: HashMap<String, f64> =
            self.recorder.busy_secs_per_node().into_iter().collect();
        Ok(RunReport {
            recorder: self.recorder,
            makespan,
            jobs_completed: self.jobs_completed,
            total_cost_usd: total,
            per_vm,
            deploy_times,
            busy_secs,
            inferences_run: self.inferences_run,
            inference_wall_secs: self.inference_wall_secs,
            events: q.dispatched(),
            wall_secs: wall0.elapsed().as_secs_f64(),
            policy: self.broker.policy_name(),
            preempted_vms: self.preempted_vms,
            preempted_jobs: self.preempted_jobs,
            preempt_recovered: self.preempt_recovered,
        })
    }

    // ---------------------------------------------------------------
    // Deployment plumbing
    // ---------------------------------------------------------------

    fn worker_instance_type(&self, site: usize) -> String {
        // The shared SiteSpec selector — also what prices the broker's
        // CostMin/SpotAware table, so ranking and billing agree.
        let want = &self.cfg.template.worker;
        self.sites[site]
            .spec
            .worker_instance_type(want.num_cpus, want.mem_gb)
            .name
            .clone()
    }

    fn vrouter_instance_type(&self, site: usize) -> String {
        // Cheapest instance in the catalog (t2.micro at AWS).
        self.sites[site]
            .spec
            .instance_types
            .iter()
            .min_by(|a, b| {
                a.price
                    .usd_per_hour
                    .partial_cmp(&b.price.usd_per_hour)
                    .unwrap()
                    .then(a.vcpus.cmp(&b.vcpus))
            })
            .map(|t| t.name.clone())
            .unwrap()
    }

    /// Provision one node and schedule its boot completion.
    fn provision(&mut self, q: &mut ShardedQueue<Ev>, site: usize, name: &str,
                 role: NodeRole, t: SimTime) -> anyhow::Result<()> {
        let id = self.names.intern(name);
        let itype = match role {
            NodeRole::FrontEnd => self.worker_instance_type(site),
            NodeRole::WorkerNode => self.worker_instance_type(site),
            NodeRole::SiteVRouter => self.vrouter_instance_type(site),
        };
        let (net_id, net_secs) = self
            .im
            .ensure_network(&mut self.sites, site, "evhc")?;
        let _ = net_id;
        let p = self.im.provision_node(
            &mut self.sites,
            site,
            "evhc",
            name,
            role,
            &itype,
            self.cfg.template.lrms,
            t,
        )?;
        self.nodes.insert(id, NodeRt {
            site,
            vm: p.vm,
            role,
            setup_done: false,
            requested_at: t,
            joined_at: None,
        });
        self.live_record.insert(id, self.vm_records.len());
        self.vm_records.push(VmRec {
            name: name.to_string(),
            site,
            role,
            ledger_idx: self.sites[site].ledger.entries.len() - 1,
            busy_secs: 0.0,
        });
        self.recorder.node_state_id(t, id, DisplayState::PoweringOn);
        q.schedule_in(net_secs + p.boot_secs, Ev::VmBooted {
            site,
            vm: p.vm,
            node: id,
            failed: p.boot_fails,
        });
        // Stash ctx duration for CtxDone scheduling at boot time.
        self.ctx_secs.insert(id, p.ctx_secs);
        Ok(())
    }

    /// Does `site` already host a live vRouter (or the CP)?
    fn site_has_router(&self, site: usize) -> bool {
        if site == self.fe_site && self.fe_ready {
            return true;
        }
        self.nodes.values().any(|rt| {
            rt.site == site
                && rt.role == NodeRole::SiteVRouter
                && rt.joined_at.is_some()
        })
    }

    fn vrouter_name(&self, site: usize) -> String {
        format!("vrouter-{}", self.sites[site].spec.name.to_lowercase())
    }

    /// Lowest unused worker index → "vnode-N" (names are reused after
    /// termination, matching the paper's vnode-5 power-off/on cycle).
    fn next_worker(&self) -> (NodeId, String) {
        for i in 1.. {
            let name = format!("vnode-{i}");
            let id = self.names.intern(&name);
            if !self.nodes.contains_key(&id) {
                return (id, name);
            }
        }
        unreachable!()
    }

    fn used_workers_per_site(&self) -> Vec<u32> {
        let mut v = vec![0u32; self.sites.len()];
        for rt in self.nodes.values() {
            // Placeholder entries (PowerOn reserved the name but no site
            // was chosen yet) have site == usize::MAX.
            if rt.role == NodeRole::WorkerNode && rt.site < v.len() {
                v[rt.site] += 1;
            }
        }
        v
    }

    /// Start adding a worker (one orchestrator update). Returns false if
    /// no site has capacity.
    fn start_add_worker(&mut self, q: &mut ShardedQueue<Ev>, name: &str,
                        t: SimTime) -> bool {
        let used = self.used_workers_per_site();
        let cpus = self.cfg.template.worker.num_cpus;
        let queue_depth = self.lrms.pending() as u32;
        let site = if self.cfg.template.hybrid {
            self.broker.select(&self.sites, &used, cpus, queue_depth, t)
        } else {
            // Non-hybrid: only the FE's site may host workers.
            let s = self.fe_site;
            let fits = self.sites[s].used_vms() < self.sites[s].spec.quota
                .max_vms
                && self.sites[s].used_vcpus() + cpus
                    <= self.sites[s].spec.quota.max_vcpus;
            fits.then_some(s)
        };
        let Some(site) = site else {
            self.recorder.milestone(t, format!(
                "no capacity anywhere for {name}"));
            return false;
        };
        // Bursting into a router-less site: vRouter first (plus one more
        // VM of quota), then the worker.
        if site != self.fe_site && !self.site_has_router(site) {
            let vr = self.vrouter_name(site);
            let vr_id = self.names.intern(&vr);
            if !self.nodes.contains_key(&vr_id) {
                if let Err(e) = self.provision(q, site, &vr,
                                               NodeRole::SiteVRouter, t) {
                    self.recorder.milestone(t, format!(
                        "vRouter provision failed at {}: {e}",
                        self.sites[site].spec.name));
                    return false;
                }
                self.recorder.milestone(t, format!(
                    "provisioning {vr} at {}", self.sites[site].spec.name));
            }
        }
        match self.provision(q, site, name, NodeRole::WorkerNode, t) {
            Ok(()) => {
                self.recorder.milestone(t, format!(
                    "provisioning {name} at {}",
                    self.sites[site].spec.name));
                true
            }
            Err(e) => {
                self.recorder.milestone(t, format!(
                    "worker provision failed: {e}"));
                false
            }
        }
    }

    // ---------------------------------------------------------------
    // Job plumbing
    // ---------------------------------------------------------------

    /// The initial cluster is up: anchor the workload timeline here
    /// (the paper's "15:00") and start the CLUES monitor loop.
    fn begin_workload(&mut self, q: &mut ShardedQueue<Ev>, t: SimTime) {
        self.workload_t0 = t;
        self.recorder.milestone(t, format!(
            "initial cluster ready ({} workers) — workload timeline t0",
            self.cfg.template.scalable.count));
        for i in 0..self.cfg.workload.blocks.len() {
            let at = self.cfg.workload.blocks[i].at;
            q.schedule_at(SimTime(t.0 + at.0), Ev::SubmitBlock(i));
        }
        // Scenario events ride the same relative timeline; each lands
        // on its target site's shard.
        for ev in &self.cfg.scenario.events {
            if ev.site() >= self.sites.len() {
                continue; // plan written for a bigger world: ignore
            }
            match *ev {
                ScenarioEvent::SpotWave { site, at, count } => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::SpotWave { site, count });
                }
                ScenarioEvent::SiteOutage { site, at, duration_secs } => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::OutageStart { site });
                    q.schedule_at(SimTime(t.0 + at.0 + duration_secs),
                                  Ev::OutageEnd { site });
                }
                ScenarioEvent::PriceSpike { site, at, duration_secs,
                                            factor } => {
                    q.schedule_at(SimTime(t.0 + at.0),
                                  Ev::PriceSpikeStart { site, factor });
                    q.schedule_at(SimTime(t.0 + at.0 + duration_secs),
                                  Ev::PriceSpikeEnd { site });
                }
            }
        }
        if !self.clues_ticking {
            self.clues_ticking = true;
            q.schedule_in(self.clues.cfg.poll_interval_s, Ev::CluesTick);
        }
    }

    /// A node was lost mid-lifecycle (crash or preemption): complete
    /// whatever update is still in flight for it, or the serialized
    /// engine stalls forever. Handles both CLUES-originated workers
    /// (tracked in `update_for_node`) and *initial* workers, which are
    /// provisioned inside the InitialDeploy update with no per-node
    /// entry — a pre-join loss of one must still drain
    /// `initial_pending`.
    fn settle_update_on_loss(&mut self, q: &mut ShardedQueue<Ev>,
                             node: NodeId, rt: &NodeRt, t: SimTime) {
        if let Some(id) = self.update_for_node.remove(&node) {
            let _ = self.engine.complete(id, t);
            q.schedule_in(0.0, Ev::OrchestratorPump);
        } else if rt.role == NodeRole::WorkerNode
            && rt.joined_at.is_none()
            && self.initial_pending > 0
        {
            self.initial_pending -= 1;
            if self.initial_pending == 0 {
                if let Some(id) = self.deploy_update.take() {
                    let _ = self.engine.complete(id, t);
                    self.begin_workload(q, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }
        }
    }

    /// Forcibly reclaim one node's VM (spot preemption / site outage).
    /// Running jobs requeue and are tracked for the recovery metric; a
    /// node already being decommissioned is left to finish normally,
    /// and the front end is never reclaimed (it is the cluster's fixed
    /// point — LRMS controller + vRouter CP). Returns true if the node
    /// was actually reclaimed.
    fn preempt_node(&mut self, q: &mut ShardedQueue<Ev>, node: NodeId,
                    t: SimTime, reason: &str) -> bool {
        let Some(rt) = self.nodes.get(&node).copied() else {
            return false;
        };
        if rt.role == NodeRole::FrontEnd {
            return false; // the FE survives preemption scenarios
        }
        if rt.site >= self.sites.len() {
            return false; // placeholder: no site chosen, no VM yet
        }
        if self.sites[rt.site].crash_vm(rt.vm, t).is_err() {
            // Already Terminating/Terminated: the in-flight
            // decommission owns the ledger close and update.
            return false;
        }
        let name = self.names.name(node);
        let mut requeued = self
            .lrms
            .set_node_health(&name, NodeHealth::Down, t)
            .unwrap_or_default();
        if let Ok(more) = self.lrms.deregister_node(&name, t) {
            requeued.extend(more);
        }
        for j in requeued {
            if self.preempt_pending.insert(j) {
                self.preempted_jobs += 1;
            }
        }
        self.settle_update_on_loss(q, node, &rt, t);
        self.nodes.remove(&node);
        self.clues.set_state_id(node, PowerState::Failed);
        self.clues.forget_id(node);
        self.recorder.node_state_id(t, node, DisplayState::Failed);
        self.recorder.milestone(t, format!("{name} {reason}"));
        self.preempted_vms += 1;
        true
    }

    /// Nodes at `site` eligible for forcible reclaim, in deterministic
    /// (NodeId) order. The front end survives: it is the cluster's
    /// fixed point (LRMS controller + vRouter CP).
    fn reclaim_victims(&self, site: usize, workers_only: bool)
        -> Vec<NodeId> {
        let mut victims: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|(_, rt)| {
                rt.site == site
                    && rt.role != NodeRole::FrontEnd
                    && (!workers_only
                        || (rt.role == NodeRole::WorkerNode
                            && rt.joined_at.is_some()))
            })
            .map(|(&id, _)| id)
            .collect();
        victims.sort();
        victims
    }

    /// Injection times are relative to the workload t0.
    fn reported_down(&self, node: &str, t: SimTime) -> bool {
        self.cfg.injections.node_reported_down(
            node, SimTime(t.0 - self.workload_t0.0))
    }

    /// One CLUES monitor pass (no `InjectionPlan` clone: the closure
    /// borrows the plan for the duration of the tick).
    fn clues_tick(&mut self, t: SimTime) -> Vec<Action> {
        let w0 = self.workload_t0;
        let inj = &self.cfg.injections;
        self.clues.tick(t, self.lrms.as_ref(), &|n| {
            inj.node_reported_down(n, SimTime(t.0 - w0.0))
        })
    }

    /// Run LRMS scheduling and materialize job executions as events.
    fn pump_jobs(&mut self, q: &mut ShardedQueue<Ev>, t: SimTime) {
        for (job, node) in self.lrms.schedule(t) {
            let mut secs = Workload::sample_job_secs(&mut self.rng);
            // Scheduled jobs always run on a joined node, whose site is
            // known — that site's shard carries the completion event.
            let mut site = 0usize;
            if let Some(rt) = self.nodes.get_mut(&node) {
                site = rt.site;
                if !rt.setup_done {
                    // One-time udocker install + image pull + container
                    // create (paper: ~4 min 30 s).
                    secs += self.cfg.workload.sample_setup_secs(
                        &mut self.rng);
                    rt.setup_done = true;
                }
            }
            self.recorder.node_state_id(t, node, DisplayState::Used);
            // Real inference (sampled): wall-clock compute, virtual time
            // stays the paper's measured job duration.
            if let Some(rtm) = &self.runtime {
                let every = self.cfg.inference_every.max(1) as u64;
                if self.next_file_id % every == 0 {
                    let w0 = std::time::Instant::now();
                    if rtm.infer_file(self.next_file_id).is_ok() {
                        self.inferences_run += 1;
                        self.inference_wall_secs +=
                            w0.elapsed().as_secs_f64();
                    }
                }
            }
            self.next_file_id += 1;
            let gen = self.lrms.job(job).map(|j| j.requeues).unwrap_or(0);
            q.schedule_in(secs, Ev::JobDone { site, job, node, gen });
        }
    }

    fn workload_done(&self) -> bool {
        let total: u32 = self.cfg.workload.total_jobs();
        self.jobs_completed >= total
    }

    // ---------------------------------------------------------------
    // CLUES action execution
    // ---------------------------------------------------------------

    fn apply_clues_actions(&mut self, q: &mut ShardedQueue<Ev>,
                           actions: Vec<Action>, t: SimTime) {
        for action in actions {
            match action {
                Action::PowerOn { count } => {
                    for _ in 0..count {
                        let (id, name) = self.next_worker();
                        // Reserve the name immediately so subsequent
                        // PowerOns pick fresh ones.
                        self.nodes.insert(id, NodeRt {
                            site: usize::MAX,
                            vm: VmId(u64::MAX),
                            role: NodeRole::WorkerNode,
                            setup_done: false,
                            requested_at: t,
                            joined_at: None,
                        });
                        self.clues.track_id(id, PowerState::PoweringOn);
                        self.recorder.node_state_id(
                            t, id, DisplayState::PoweringOn);
                        self.engine.submit(UpdateOp::AddWorker {
                            name,
                        }, t);
                    }
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
                Action::PowerOff { node } => {
                    let id = self.names.intern(&node);
                    self.engine.submit(UpdateOp::RemoveWorker {
                        name: node,
                    }, t);
                    self.recorder.node_state_id(t, id,
                                                DisplayState::PoweringOff);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
                Action::CancelPowerOff { node } => {
                    // O(1) keyed lookup instead of scanning the whole
                    // update history.
                    let id = self.engine.find_queued_remove(&node);
                    match id {
                        Some(id) if self.engine.cancel(id, t).is_ok() => {
                            // Rescued: the node never left.
                            let nid = self.names.intern(&node);
                            self.clues.set_state_id(nid, PowerState::On);
                            let idle = self
                                .lrms
                                .node_stat(nid)
                                .map(|s| s.is_idle())
                                .unwrap_or(false);
                            self.recorder.node_state_id(t, nid,
                                if idle { DisplayState::Idle }
                                else { DisplayState::Used });
                            self.recorder.milestone(t, format!(
                                "power-off of {node} cancelled \
                                 (jobs arrived early)"));
                        }
                        _ => {
                            // Too late (vnode-3): it will power off.
                        }
                    }
                }
                Action::MarkFailed { node } => {
                    let id = self.names.intern(&node);
                    self.recorder.node_state_id(t, id,
                                                DisplayState::Failed);
                    self.recorder.milestone(t, format!(
                        "{node} detected as off — marked failed, \
                         powering off to avoid cost"));
                    // Requeue its jobs and power it off.
                    let _ = self.lrms.set_node_health(&node,
                                                      NodeHealth::Down, t);
                    self.engine.submit(UpdateOp::RemoveWorker {
                        name: node,
                    }, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }
        }
    }

    /// Start any updates the (possibly serialized) engine allows.
    fn pump_orchestrator(&mut self, q: &mut ShardedQueue<Ev>, t: SimTime) {
        for update in self.engine.startable(t) {
            match &update.op {
                UpdateOp::AddWorker { name } => {
                    let id = self.names.intern(name);
                    if !self.start_add_worker(q, name, t) {
                        // No capacity: finish the update immediately and
                        // stop tracking the phantom node. Re-pump so
                        // updates queued behind this one are not starved.
                        let _ = self.engine.complete(update.id, t);
                        self.nodes.remove(&id);
                        self.clues.forget_id(id);
                        self.recorder.node_state_id(t, id,
                                                    DisplayState::Off);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                    } else {
                        self.update_for_node.insert(id, update.id);
                    }
                }
                UpdateOp::RemoveWorker { name } => {
                    let id = self.names.intern(name);
                    let Some(rt) = self.nodes.get(&id).copied() else {
                        let _ = self.engine.complete(update.id, t);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                        continue;
                    };
                    let _ = self.lrms.deregister_node(name, t);
                    match self.im.decommission_node(
                        &mut self.sites, rt.site, rt.vm, name, t) {
                        Ok(secs) => {
                            q.schedule_in(secs, Ev::TerminationDone {
                                site: rt.site,
                                node: id,
                                update: Some(update.id),
                            });
                        }
                        Err(_) => {
                            let _ = self.engine.complete(update.id, t);
                            q.schedule_in(0.0, Ev::OrchestratorPump);
                        }
                    }
                }
                UpdateOp::InitialDeploy => {
                    self.deploy_update = Some(update.id);
                    let used = self.used_workers_per_site();
                    // FE placement is always SLA-ranked (the fixed
                    // point); the configured policy governs workers.
                    let fe_site = self.broker.select_front_end(
                        &self.sites, &used,
                        self.cfg.template.front_end.num_cpus, t)
                        .unwrap_or(0);
                    self.fe_site = fe_site;
                    self.broker.set_front_end(fe_site, &self.net,
                                              &self.sites);
                    if let Err(e) = self.provision(q, fe_site, FE_NAME,
                                                   NodeRole::FrontEnd, t) {
                        self.recorder.milestone(t, format!(
                            "FATAL: cannot provision front-end: {e}"));
                        let _ = self.engine.complete(update.id, t);
                    } else {
                        self.recorder.milestone(t, format!(
                            "deploying front-end at {}",
                            self.sites[fe_site].spec.name));
                    }
                }
            }
        }
    }
}

impl MergedWorld for HybridCluster {
    type Event = Ev;

    fn handle(&mut self, t: SimTime, ev: Ev, q: &mut ShardedQueue<Ev>) {
        match ev {
            Ev::Deploy => {
                self.engine.submit(UpdateOp::InitialDeploy, t);
                self.pump_orchestrator(q, t);
            }

            Ev::SubmitBlock(i) => {
                let jobs = self.cfg.workload.blocks[i].jobs;
                // One bulk core call per block (a 100k-job block is a
                // single submit), not one trait dispatch per job.
                self.lrms.submit_batch(jobs, 1, t);
                self.jobs_submitted += jobs;
                self.recorder.milestone(t, format!(
                    "block {} submitted: {jobs} jobs", i + 1));
                self.pump_jobs(q, t);
                // Immediate CLUES reaction on new work.
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
            }

            Ev::VmBooted { site, vm, node, failed } => {
                if failed {
                    let _ = self.sites[site].complete_boot(vm, true, t);
                    self.recorder.node_state_id(t, node,
                                                DisplayState::Failed);
                    self.recorder.milestone(t, format!(
                        "{} failed to boot", self.names.name(node)));
                    // Retry through CLUES on the next tick (the node
                    // vanishes; CLUES sees the deficit again).
                    if let Some(id) = self.update_for_node.remove(&node) {
                        let _ = self.engine.complete(id, t);
                        q.schedule_in(0.0, Ev::OrchestratorPump);
                    }
                    self.nodes.remove(&node);
                    self.clues.forget_id(node);
                    return;
                }
                let _ = self.sites[site].complete_boot(vm, false, t);
                // Stochastic crash injection: sample a time-to-failure
                // from the site's failure model.
                if let Some(secs) = self.sites[site]
                    .spec
                    .failure
                    .sample_crash_in(&mut self.rng)
                {
                    q.schedule_in(secs, Ev::VmCrashed {
                        site,
                        vm,
                        node,
                    });
                }
                // Spot capacity carries its own reclaim hazard.
                if let Some(secs) = self.sites[site]
                    .spec
                    .failure
                    .sample_preempt_in(&mut self.rng)
                {
                    q.schedule_in(secs, Ev::VmPreempted {
                        site,
                        vm,
                        node,
                    });
                }
                // Contextualization starts now (Ansible over the SSH
                // reverse tunnel fabric).
                let is_fe = self.names.with_name(node, |n| n == FE_NAME);
                if !is_fe {
                    let name = self.names.name(node);
                    let _ = self.im.connect_node(&name, t);
                }
                let ctx = self.ctx_secs.get(&node).copied().unwrap_or(300.0);
                q.schedule_in(ctx, Ev::CtxDone { site, node });
            }

            Ev::CtxDone { site: _, node } => {
                let Some(rt) = self.nodes.get_mut(&node) else { return };
                rt.joined_at = Some(t);
                let (site, role, requested_at) =
                    (rt.site, rt.role, rt.requested_at);
                let name = self.names.name(node);
                self.deploy_log.push((name.clone(), requested_at, t));
                match role {
                    NodeRole::FrontEnd => {
                        self.fe_ready = true;
                        self.im.establish_master(FE_NAME);
                        // FE hosts the vRouter central point + CA.
                        let base = self.sites[site]
                            .networks
                            .get(crate::cloudsim::NetworkId(0))
                            .map(|n| n.cidr_base)
                            .unwrap_or(0x0A00_0000);
                        let loc = self.sites[site].net_id;
                        let _ = self.overlay.add_central_point(
                            FE_NAME, loc, base, t);
                        self.recorder.milestone(t,
                            "front-end ready (LRMS controller + NFS + \
                             vRouter CP)".to_string());
                        self.recorder.node_state_id(t, node,
                                                    DisplayState::Used);
                        // Initial workers, all within the same
                        // InitialDeploy update.
                        self.initial_pending =
                            self.cfg.template.scalable.count;
                        if self.initial_pending == 0 {
                            if let Some(id) = self.deploy_update.take() {
                                let _ = self.engine.complete(id, t);
                                self.begin_workload(q, t);
                                q.schedule_in(0.0, Ev::OrchestratorPump);
                            }
                        }
                        for _ in 0..self.cfg.template.scalable.count {
                            let (wid, wname) = self.next_worker();
                            self.clues.track_id(wid, PowerState::PoweringOn);
                            // Initial workers are provisioned directly by
                            // the IM inside the initial update.
                            if !self.start_add_worker(q, &wname, t) {
                                self.initial_pending -= 1;
                            }
                        }
                    }
                    NodeRole::SiteVRouter => {
                        // Register + connect the site router to the CP.
                        let loc = self.sites[site].net_id;
                        let base = self
                            .im
                            .networks
                            .get(&site)
                            .and_then(|nid| {
                                self.sites[site].networks.get(*nid)
                            })
                            .map(|n| n.cidr_base)
                            .unwrap_or(0x0A01_0000);
                        let _ = self
                            .im
                            .retrieve_certificate(&mut self.overlay,
                                                  &name, t);
                        // add_site_router issues the cert itself if the
                        // callback did not; remove double issue.
                        if self.overlay.element(&name).is_none() {
                            if self.overlay.ca.verify(&name) {
                                let _ = self.overlay.ca.revoke(&name);
                            }
                            let _ = self.overlay.add_site_router(
                                &name, loc, base, t);
                        }
                        self.recorder.milestone(t, format!(
                            "{name} connected to the CP (overlay up at \
                             {})", self.sites[site].spec.name));
                        self.recorder.node_state_id(t, node,
                                                    DisplayState::Used);
                    }
                    NodeRole::WorkerNode => {
                        // Join the LRMS; node becomes schedulable.
                        self.lrms.register_node(
                            &name, self.clues.cfg.slots_per_worker, t);
                        self.clues.track_id(node, PowerState::On);
                        self.clues.set_state_id(node, PowerState::On);
                        self.recorder.node_state_id(t, node,
                                                    DisplayState::Idle);
                        self.recorder.milestone(t, format!(
                            "{name} joined the cluster"));
                        if let Some(id) = self.update_for_node.remove(&node)
                        {
                            let _ = self.engine.complete(id, t);
                            q.schedule_in(0.0, Ev::OrchestratorPump);
                        }
                        if self.initial_pending > 0 {
                            self.initial_pending -= 1;
                            if self.initial_pending == 0 {
                                if let Some(id) = self.deploy_update.take() {
                                    let _ = self.engine.complete(id, t);
                                    self.begin_workload(q, t);
                                    q.schedule_in(0.0,
                                                  Ev::OrchestratorPump);
                                }
                            }
                        }
                        self.pump_jobs(q, t);
                    }
                }
            }

            Ev::JobDone { site: _, job, node, gen } => {
                // Drop stale completions: the execution this event
                // belongs to was requeued away (node went down).
                let live = self.lrms.job(job).map(|j| {
                    j.requeues == gen
                        && j.state == crate::lrms::JobState::Running
                        && j.node == Some(node)
                }).unwrap_or(false);
                if !live {
                    return;
                }
                let _ = self.lrms.on_job_finished(job, true, t);
                self.jobs_completed += 1;
                if self.preempt_pending.remove(&job) {
                    self.preempt_recovered += 1;
                }
                if let Some(stat) = self.lrms.node_stat(node) {
                    if stat.used_slots == 0 {
                        self.recorder.node_state_id(t, node,
                                                    DisplayState::Idle);
                    }
                }
                // Record the run interval (start = end - duration is not
                // tracked; use LRMS job record).
                if let Some(j) = self.lrms.job(job) {
                    if let (Some(s), Some(e)) = (j.started_at, j.finished_at)
                    {
                        self.recorder.job_run_id(node, s, e);
                        if let Some(&ri) = self.live_record.get(&node) {
                            self.vm_records[ri].busy_secs += e.0 - s.0;
                        }
                    }
                }
                self.pump_jobs(q, t);
            }

            Ev::CluesTick => {
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                // Recovery path for transient flaps: if the monitor reads
                // the node as up again and the LRMS had it Down, revive.
                // The snapshot buffer is owned scratch (taken off self),
                // so the loop body may mutate the LRMS while iterating —
                // and the tick allocates nothing at steady state.
                let mut stats = std::mem::take(&mut self.stats_scratch);
                self.lrms.node_stats_into(&mut stats);
                for s in &stats {
                    if s.health != NodeHealth::Down {
                        continue;
                    }
                    let id = s.id;
                    let name = self.names.name(id);
                    // Only revive if CLUES has not already failed it.
                    if !self.reported_down(&name, t)
                        && self.clues.state_id(id) == Some(PowerState::On)
                    {
                        let _ = self.lrms.set_node_health(
                            &name, NodeHealth::Up, t);
                    }
                }
                self.stats_scratch = stats;
                self.pump_jobs(q, t);
                // Keep ticking while there is anything left to manage.
                let all_workers_off = self
                    .nodes
                    .values()
                    .filter(|rt| rt.role == NodeRole::WorkerNode)
                    .count() == 0;
                if !(self.workload_done() && all_workers_off) {
                    q.schedule_in(self.clues.cfg.poll_interval_s,
                                  Ev::CluesTick);
                } else {
                    self.recorder.milestone(t,
                        "workload complete, all workers released"
                            .to_string());
                }
            }

            Ev::OrchestratorPump => {
                self.pump_orchestrator(q, t);
            }

            Ev::VmCrashed { site, vm, node } => {
                // Stale if the node was already replaced or terminated.
                let Some(rt) = self.nodes.get(&node).copied() else {
                    return;
                };
                if rt.vm != vm || rt.site != site {
                    return;
                }
                let _ = self.sites[site].crash_vm(vm, t);
                // The LRMS sees the node die: requeue its jobs.
                let name = self.names.name(node);
                let _ = self.lrms.set_node_health(&name, NodeHealth::Down,
                                                  t);
                let _ = self.lrms.deregister_node(&name, t);
                // A crash before the node joined leaves its update in
                // flight (per-node AddWorker or the InitialDeploy it
                // was part of); complete it so the serialized engine
                // cannot stall.
                self.settle_update_on_loss(q, node, &rt, t);
                self.nodes.remove(&node);
                self.clues.set_state_id(node, PowerState::Failed);
                self.clues.forget_id(node);
                self.recorder.node_state_id(t, node, DisplayState::Failed);
                self.recorder.milestone(t, format!(
                    "{name} crashed (provider-side failure)"));
                // CLUES replaces it on its next tick if jobs remain.
                self.pump_jobs(q, t);
            }

            Ev::VmPreempted { site, vm, node } => {
                // Stale if the node was already replaced or terminated.
                let live = self.nodes.get(&node)
                    .map(|rt| rt.vm == vm && rt.site == site)
                    .unwrap_or(false);
                if !live {
                    return;
                }
                self.preempt_node(q, node, t,
                                  "preempted (spot capacity reclaimed)");
                self.pump_jobs(q, t);
            }

            Ev::SpotWave { site, count } => {
                let victims = self.reclaim_victims(site, true);
                let n = if count == 0 {
                    victims.len()
                } else {
                    (count as usize).min(victims.len())
                };
                self.recorder.milestone(t, format!(
                    "spot-preemption wave at {}: reclaiming {n} of {} \
                     workers", self.sites[site].spec.name, victims.len()));
                for id in victims.into_iter().take(n) {
                    self.preempt_node(q, id, t,
                                      "preempted (spot wave)");
                }
                // Immediate CLUES pass so replacements start promptly
                // (the broker decides where they land).
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                self.pump_jobs(q, t);
            }

            Ev::OutageStart { site } => {
                self.broker.set_outage(site, true);
                self.recorder.milestone(t, format!(
                    "site outage: {} dark", self.sites[site].spec.name));
                for id in self.reclaim_victims(site, false) {
                    self.preempt_node(q, id, t, "lost to site outage");
                }
                let actions = self.clues_tick(t);
                self.apply_clues_actions(q, actions, t);
                self.pump_jobs(q, t);
            }

            Ev::OutageEnd { site } => {
                self.broker.set_outage(site, false);
                self.recorder.milestone(t, format!(
                    "site outage over: {} eligible again",
                    self.sites[site].spec.name));
            }

            Ev::PriceSpikeStart { site, factor } => {
                // The broker reads the site's factor through its
                // signals, so billing and policy stay in sync by
                // construction. Overlapping windows compose: the
                // latest spike's factor rules until every open window
                // has ended.
                self.price_spikes_active[site] += 1;
                self.sites[site].set_price_factor(factor);
                self.recorder.milestone(t, format!(
                    "price spike at {}: {factor}x list for new launches",
                    self.sites[site].spec.name));
            }

            Ev::PriceSpikeEnd { site } => {
                self.price_spikes_active[site] =
                    self.price_spikes_active[site].saturating_sub(1);
                if self.price_spikes_active[site] == 0 {
                    self.sites[site].set_price_factor(1.0);
                    self.recorder.milestone(t, format!(
                        "price spike over at {}",
                        self.sites[site].spec.name));
                } else {
                    self.recorder.milestone(t, format!(
                        "price spike window closed at {} (another spike \
                         still active)", self.sites[site].spec.name));
                }
            }

            Ev::TerminationDone { site: _, node, update } => {
                if let Some(rt) = self.nodes.remove(&node) {
                    let _ = self.sites[rt.site]
                        .complete_termination(rt.vm, t);
                }
                self.clues.set_state_id(node, PowerState::Off);
                self.clues.forget_id(node);
                self.recorder.node_state_id(t, node, DisplayState::Off);
                self.recorder.milestone(t, format!(
                    "{} powered off", self.names.name(node)));
                if let Some(id) = update {
                    let _ = self.engine.complete(id, t);
                    q.schedule_in(0.0, Ev::OrchestratorPump);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(scale: f64) -> RunConfig {
        let mut cfg = RunConfig::paper_usecase(scale, 42);
        cfg.inference_every = 0; // no PJRT in unit tests
        cfg
    }

    #[test]
    fn scaled_usecase_completes_all_jobs() {
        let cfg = small_cfg(0.01); // ~36 jobs
        let total = cfg.workload.total_jobs();
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.jobs_completed, total);
        assert!(report.makespan.0 > 0.0);
        // Front-end plus at least the two initial CESNET workers existed.
        let names = report.recorder.node_names();
        assert!(names.iter().any(|n| n == "front-end"), "{names:?}");
        assert!(names.iter().any(|n| n == "vnode-1"), "{names:?}");
        assert!(names.iter().any(|n| n == "vnode-2"), "{names:?}");
    }

    #[test]
    fn spill_mode_metrics_match_in_memory_run() {
        let mem = HybridCluster::new(small_cfg(0.01)).unwrap()
            .run().unwrap();
        let dir = std::env::temp_dir().join("evhc_cluster_spill_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = small_cfg(0.01);
        cfg.metrics_spill_dir = Some(dir.clone());
        let spilled = HybridCluster::new(cfg).unwrap().run().unwrap();
        // Same seed, deterministic world: the streamed-and-merged
        // recorder must be byte-identical to the in-memory one.
        assert_eq!(spilled.makespan.0, mem.makespan.0);
        assert_eq!(spilled.jobs_completed, mem.jobs_completed);
        assert_eq!(spilled.recorder.milestones, mem.recorder.milestones);
        assert_eq!(spilled.recorder.node_names(), mem.recorder.node_names());
        let until = mem.makespan;
        assert_eq!(spilled.recorder.fig10_usage(60.0, until).to_csv(),
                   mem.recorder.fig10_usage(60.0, until).to_csv());
        assert_eq!(spilled.recorder.fig11_states(60.0, until).to_csv(),
                   mem.recorder.fig11_states(60.0, until).to_csv());
        assert_eq!(spilled.busy_secs, mem.busy_secs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bursts_to_aws_when_cesnet_full() {
        // Enough work to demand more than CESNET's quota (FE + 2 WNs).
        let report = HybridCluster::new(small_cfg(0.05)).unwrap()
            .run().unwrap();
        // Some worker must have landed at AWS, which requires a vRouter.
        let aws_vms: Vec<&PerVm> = report
            .per_vm
            .iter()
            .filter(|r| r.site == "AWS")
            .collect();
        assert!(
            aws_vms.iter().any(|r| r.name.starts_with("vnode-")),
            "expected AWS workers, got {:?}", report.per_vm
        );
        assert!(
            aws_vms.iter().any(|r| r.name.starts_with("vrouter-")),
            "expected a site vRouter at AWS, got {:?}", report.per_vm
        );
        // And bursting costs money.
        assert!(report.total_cost_usd > 0.0);
    }

    #[test]
    fn workers_power_off_after_workload() {
        let report = HybridCluster::new(small_cfg(0.01)).unwrap()
            .run().unwrap();
        // Final state of every worker node is Off.
        let final_states = report.recorder.states_at(report.makespan);
        for (node, state) in final_states {
            if node.starts_with("vnode-") {
                assert_eq!(state, DisplayState::Off, "{node}");
            }
        }
    }

    #[test]
    fn deploy_times_recorded_for_all_joined_nodes() {
        let report = HybridCluster::new(small_cfg(0.02)).unwrap()
            .run().unwrap();
        assert!(!report.deploy_times.is_empty());
        for (node, req, joined) in &report.deploy_times {
            assert!(joined.0 > req.0, "{node} joined before requested?");
            // Sanity: between 2 and 40 minutes.
            let mins = (joined.0 - req.0) / 60.0;
            assert!(mins > 2.0 && mins < 40.0, "{node}: {mins} min");
        }
    }

    #[test]
    fn serialized_orchestrator_staggers_aws_joins() {
        let mut cfg = small_cfg(0.05);
        cfg.serialized_orchestrator = true;
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        let mut joins: Vec<f64> = report
            .deploy_times
            .iter()
            .filter(|(n, _, _)| n.starts_with("vnode-"))
            .map(|(_, _, j)| j.0)
            .collect();
        joins.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // With serialization, consecutive joins of the burst nodes must
        // be separated by at least a boot+ctx period (~10 min), not
        // simultaneous. Initial 2 CESNET nodes join close together (same
        // InitialDeploy update), so check the tail (AWS bursts).
        if joins.len() >= 4 {
            let gap = joins[3] - joins[2];
            assert!(gap > 300.0, "burst joins too close: {joins:?}");
        }
    }

    #[test]
    fn parallel_ablation_is_faster_to_scale() {
        let mut ser = small_cfg(0.05);
        ser.serialized_orchestrator = true;
        let mut par = small_cfg(0.05);
        par.serialized_orchestrator = false;
        let rs = HybridCluster::new(ser).unwrap().run().unwrap();
        let rp = HybridCluster::new(par).unwrap().run().unwrap();
        assert_eq!(rs.jobs_completed, rp.jobs_completed);
        assert!(
            rp.makespan.0 <= rs.makespan.0 + 1.0,
            "parallel {} !<= serialized {}", rp.makespan.0, rs.makespan.0
        );
    }

    #[test]
    fn vnode5_transient_flap_causes_fail_and_replace() {
        let mut cfg = small_cfg(0.1);
        // Flap vnode-2 well after it has joined (initial workers join
        // ~10 min in) and while work is still flowing.
        cfg.injections = crate::cloudsim::InjectionPlan {
            transient_downs: vec![crate::cloudsim::TransientDown {
                node_name: "vnode-2".into(),
                start: SimTime(1200.0),
                duration_secs: 300.0,
            }],
        };
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        // The node must have gone through Failed at some point.
        let failed = report
            .recorder
            .transitions_named()
            .iter()
            .any(|(_, n, s)| n == "vnode-2" && *s == DisplayState::Failed);
        assert!(failed, "vnode-2 never marked failed");
        // All jobs still completed (requeues made up for it).
        assert_eq!(report.jobs_completed, report.recorder.job_runs.len()
                   as u32);
    }

    #[test]
    fn non_hybrid_stays_on_premises() {
        let mut cfg = small_cfg(0.05);
        cfg.template.hybrid = false;
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert!(report.per_vm.iter().all(|r| r.site != "AWS"),
                "{:?}", report.per_vm);
        // Still finishes everything, just slower.
        assert!(report.jobs_completed > 0);
    }

    #[test]
    fn spot_wave_preempts_and_recovers_jobs() {
        let mut cfg = small_cfg(0.1);
        // Reclaim every running CESNET worker mid-block-1: vnode-1 and
        // vnode-2 joined before t0 and are busy until ~t0+800.
        cfg.scenario = ScenarioPlan::new().spot_wave(0, 600.0, 0);
        let total = cfg.workload.total_jobs();
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.jobs_completed, total);
        assert!(report.preempted_vms >= 1,
                "wave reclaimed nothing");
        // Every preempted job was requeued and finished elsewhere.
        assert_eq!(report.preempt_recovered, report.preempted_jobs);
        assert_eq!(report.policy, "sla-rank");
        assert!(report.recorder.milestones.iter().any(
            |(_, m)| m.contains("spot-preemption wave")));
    }

    #[test]
    fn site_outage_bursts_to_surviving_site() {
        let mut cfg = small_cfg(0.1);
        // CESNET goes dark shortly after the run starts; the broker
        // must route every replacement worker to AWS until it is back.
        cfg.scenario = ScenarioPlan::new().site_outage(0, 600.0, 3600.0);
        let total = cfg.workload.total_jobs();
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.jobs_completed, total);
        assert!(report.preempted_vms >= 1, "outage killed nothing");
        assert!(report.per_vm.iter().any(
            |r| r.site == "AWS" && r.name.starts_with("vnode-")),
            "no AWS replacements: {:?}", report.per_vm);
        assert!(report.recorder.milestones.iter().any(
            |(_, m)| m.contains("site outage")));
    }

    #[test]
    fn price_spike_inflates_burst_cost() {
        let base = HybridCluster::new(small_cfg(0.05)).unwrap()
            .run().unwrap();
        let mut cfg = small_cfg(0.05);
        // 10x AWS prices for the whole burst window.
        cfg.scenario = ScenarioPlan::new()
            .price_spike(1, 0.0, 1_000_000.0, 10.0);
        let spiked = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(base.jobs_completed, spiked.jobs_completed);
        // SlaRank ignores price, so the placements match — only the
        // bill changes. (The first burst VM can open before the spike
        // event lands, so the factor is well below the full 10x.)
        assert!(spiked.total_cost_usd > base.total_cost_usd * 1.5,
                "spiked {} !>> base {}", spiked.total_cost_usd,
                base.total_cost_usd);
    }

    #[test]
    fn alternative_policies_complete_the_workload() {
        for kind in [PolicyKind::CostMin, PolicyKind::LatencyMin,
                     PolicyKind::SpotAware] {
            let mut cfg = small_cfg(0.05);
            cfg.policy = kind;
            let total = cfg.workload.total_jobs();
            let report = HybridCluster::new(cfg).unwrap().run().unwrap();
            assert_eq!(report.jobs_completed, total, "{kind:?}");
            assert_eq!(report.policy, kind.label());
        }
    }

    #[test]
    fn paid_utilization_between_zero_and_one() {
        let report = HybridCluster::new(small_cfg(0.05)).unwrap()
            .run().unwrap();
        let u = report.paid_utilization();
        assert!((0.0..=1.0).contains(&u), "{u}");
        // At 5% scale boot/idle overhead dominates; the full-scale
        // bench checks the paper's ~66%.
        assert!(u > 0.01, "paid nodes barely used: {u}");
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;

    #[test]
    fn nonhybrid_engine_drains() {
        let mut cfg = RunConfig::paper_usecase(0.05, 42);
        cfg.template.hybrid = false;
        cfg.inference_every = 0;
        let mut world = HybridCluster::new(cfg).unwrap();
        let mut q: ShardedQueue<Ev> = ShardedQueue::new(world.sites.len());
        q.schedule_at(SimTime::ZERO, Ev::Deploy);
        run_merged_until(&mut world, &mut q, SimTime::from_hms(47, 0, 0));
        let updates = world.engine.updates();
        let stuck: Vec<_> = updates.iter()
            .filter(|u| !matches!(u.state,
                crate::orchestrator::UpdateState::Done
                | crate::orchestrator::UpdateState::Cancelled))
            .collect();
        assert!(stuck.is_empty(),
            "stuck updates: {:#?}\nnodes: {:?}\nin_progress: {}",
            stuck, world.nodes.keys().collect::<Vec<_>>(),
            world.engine.in_progress());
    }
}
