//! The hybrid virtual elastic cluster: public façade + simulation world.
//!
//! This module wires every component into the deployment flow of the
//! paper's §3.1 and the use-case dynamics of §4:
//!
//! 1. a TOSCA template is submitted to the orchestrator,
//! 2. the orchestrator ranks sites (SLAs + monitoring) and delegates to
//!    the IM, which creates networks first, then VMs, then runs
//!    contextualization over SSH reverse tunnels,
//! 3. the front-end comes up as LRMS controller + NFS server + vRouter
//!    central point (the only public IP),
//! 4. CLUES watches the queue: bursting to further sites provisions a
//!    site vRouter there before the first worker,
//! 5. jobs run; the first job on each node pays the one-time udocker
//!    setup; inference is served by the PJRT runtime,
//! 6. idle nodes power off (pending power-offs cancel if jobs arrive),
//!    down-flapping nodes get failed + replaced (vnode-5).
//!
//! ## Site-partitioned world
//!
//! The world is split along the paper's own control/site boundary:
//!
//! * [`SiteWorld`] (one per cloud site, its own shard) owns everything
//!   site-local: the [`CloudSite`] (VM table, ledger, pricing,
//!   networks), in-flight boot/contextualization timers, job-execution
//!   timers for jobs running on its nodes, the site's completed-run
//!   report buffer (the LRMS partition slice the controller has not
//!   heard about yet), and a per-shard [`Recorder`].
//! * [`ControlWorld`] (the control shard) owns the cross-site state:
//!   the orchestrator workflow engine, the LRMS controller, CLUES, the
//!   elasticity broker, the vRouter overlay/CA, the IM tunnel fabric,
//!   the workload queue, accounting, and its own recorder shard.
//!
//! **Ownership contract.** A site handler may touch only its own
//! `SiteWorld` (and the read-only shared name interner); it talks to
//! the control plane exclusively through buffered control emissions
//! ([`crate::sim::shard::SiteCtx::emit_control_in`]) that are at least
//! [`RunConfig::control_latency_s`] in the future — the WAN latency a
//! real site→front-end notification pays, and the engine lookahead
//! that makes parallel site windows safe. The control plane, which
//! dispatches serially at barrier points, may read and mutate any site
//! (that is the [`crate::sim::shard::ControlPlane`] contract): it
//! provisions VMs, reclaims them in scenario waves, and schedules
//! commands into site shards (`BootDone`, `JobTimer`, `CrashTimer`,
//! `TerminationDone`). Cross-boundary effects are therefore always
//! events; no site handler ever reaches into another shard's state.
//!
//! **Cross-shard event vocabulary.** Control → site commands:
//! [`Ev::BootDone`] (VM boot completes at the site),
//! [`Ev::JobTimer`] (a scheduled job's execution ends on a site node),
//! [`Ev::CrashTimer`] (sampled stochastic crash/spot-reclaim),
//! [`Ev::TerminationDone`] (provider finishes a decommission).
//! Site → control emissions: [`Ev::NodeReady`] (contextualization
//! done), [`Ev::BootFailed`], [`Ev::NodeLost`] (crash/preempt),
//! [`Ev::NodeOff`] (termination complete), and [`Ev::JobBatch`] — the
//! site's completed-run report, batched on a
//! [`RunConfig::report_interval_s`] grid so a busy site sends one
//! controller RPC per grid slot instead of one per job.
//!
//! **WAN chaos & self-healing.** The control↔site boundary can be
//! subjected to deterministic fault injection ([`WanFaultPlan`], plus
//! the per-site steady `message_loss_prob` of
//! [`crate::cloudsim::FailureModel`]): site→control messages are
//! dropped, duplicated
//! or delayed by per-message decisions drawn from a stream keyed by
//! `(site, seq)`, so all three engines see identical faults. The
//! recovery contract layered on top:
//!
//! * *Retransmission.* Reliable site reports (joins, boot failures,
//!   losses, power-offs, job batches) that the fault layer drops are
//!   retransmitted by the site after an ack-timeout backoff
//!   (`FailureModel::ack_timeout_s`, doubling to a cap); every job
//!   completes under any sub-total loss rate.
//! * *Provisioning retries.* A `BootFailed` worker is re-provisioned
//!   under [`RetryPolicy`]: bounded attempts, exponential backoff with
//!   deterministic jitter, failover to the next broker-ranked site
//!   after `failover_after` attempts at the original one.
//! * *Heartbeats & quarantine.* The control plane probes every remote
//!   site each CLUES tick; `quarantine_after` consecutive unanswered
//!   probes trip a per-site circuit breaker ([`SiteHealthTracker`]):
//!   the broker treats the site as dark, its leased jobs requeue
//!   elsewhere, and its nodes are held down until the site reports in
//!   again (half-open → closed on two proofs of life).
//! * *Partitions.* Scripted WAN partitions (a
//!   [`crate::broker::ScenarioEvent::WanPartition`] or a
//!   [`FaultWindow`] with `partition`) drop everything both ways for
//!   the window, take the site's vRouter down, and exclude the site
//!   from broker placement until the heal. Correlated *regional*
//!   outages — a [`WanFaultPlan`] region group or a
//!   [`crate::broker::ScenarioEvent::RegionalOutage`] — are one
//!   backbone failure hitting several sites at once; they resolve
//!   into ordinary per-site partition windows before the fault layer
//!   sees them, so the `(site, seq)` fault streams (and with them
//!   cross-engine byte-identity) are untouched by the correlation.
//! * *Health-scored placement.* Each CLUES tick under chaos folds the
//!   fault telemetry a site accumulated since the previous tick —
//!   messages dropped, retransmissions, provisioning retries, open
//!   quarantine — into an exponentially-decayed health score in
//!   `[0, 1]` (see `cluster::control::ewma_health`), published to the
//!   broker via [`crate::broker::SiteSignals::health`]. A fault-free
//!   site holds exactly 1.0, so every policy that ignores health is
//!   bit-identical to its pre-health behavior, and the
//!   [`crate::broker::HealthAware`] policy is decision-identical to
//!   `SlaRank` on fault-free runs (property-proven). Under faults,
//!   `HealthAware` charges one SLA-priority step per 1/16th of lost
//!   health (a ~6% deadband absorbs isolated blips), de-ranking a
//!   degrading site *before* its circuit breaker opens; calm ticks
//!   decay the score geometrically back toward 1.0. Per-site health
//!   floors and first-de-rank times land in [`RunReport`] and the
//!   determinism digest.
//!
//! All recovery work is accounted in [`RunReport`]
//! (`messages_dropped`, `provision_retries`, `quarantine_windows`,
//! `lease_recovered_jobs`, …) and folded into the determinism digest.
//! When no fault source is configured, every chaos code path is
//! skipped and pre-chaos runs keep their digests bit for bit.
//!
//! **Engines.** [`RunConfig::engine`] selects the replay engine:
//! [`Engine::Serial`] (single-queue deterministic merge, the
//! reference), [`Engine::Sharded`] (parallel site windows between
//! control barriers) or [`Engine::Stealing`] (work-stealing window
//! chains). All three produce byte-identical recorders, fig10/fig11
//! CSV, spill files and `RunReport`s by the sharded-engine equivalence
//! contract (`tests/broker_policies.rs` proves it on randomized
//! paper-use-case configs including broker failure scenarios). The
//! metrics layer records one [`Recorder`] per shard (control = spill
//! shard 0, site *i* = shard *i+1*), merged deterministically at run
//! end — or streamed to per-shard spill files when
//! [`RunConfig::metrics_spill_dir`] is set.
//!
//! **Partitioned dispatch.** [`RunConfig::dispatch`] selects who
//! schedules. The default ([`DispatchMode::Centralized`]) keeps the
//! paper's shape — one control-shard LRMS placing every job — which
//! control-couples the workload: every placement is a barrier-side
//! decision, so the parallel engines run at window-overhead parity
//! with serial. [`DispatchMode::Partitioned`] moves scheduling inside
//! the site shards: each [`SiteWorld`] owns a [`SiteSched`] — a
//! private `BatchCore` slice over its local nodes, placing jobs during
//! the site's parallel window — and the control side shrinks to a
//! [`Dispatcher`] that routes workload blocks to sites (broker-ranked
//! via `route_candidates`, credit-bounded by registered capacity,
//! outage/quarantine-aware) and arbitrates cross-site spillover at
//! barriers. Integrity is a two-phase lease: every route bumps the
//! job's epoch, every site report echoes it, and stale epochs/seqs are
//! dropped — so re-routing (spill, quarantine) can never double-place
//! or double-count a job, even against zombie executions on a
//! quarantined site. `tests/partitioned_dispatch.rs` holds the
//! equivalence suite: three-engine byte-identity in partitioned mode
//! and completion-set equivalence against the centralized reference.
//!
//! **Observability contract.** [`RunConfig::obs`] turns on the
//! [`crate::obs`] layer: causal job/node/chaos/broker spans buffered
//! per shard ([`crate::obs::TraceShard`], merged like the recorders)
//! and on-clock gauges sampled each CluesTick
//! ([`crate::obs::MetricsRegistry`]). Both are *sim-clock* data:
//! recording is purely passive (no randomness, no scheduled events, no
//! feedback into any decision), so enabling them leaves
//! [`RunReport::determinism_digest`] bit-identical and their exported
//! streams are byte-identical across all three engines. The *wall
//! clock* half — [`RunReport::profile`], from the sharded engines'
//! profiler — is nondeterministic by nature and never enters a digest.

mod control;
mod dispatch;
mod faults;
mod site;

pub use control::ControlWorld;
pub use dispatch::{DispatchConfig, DispatchJob, DispatchLrmsView,
                   DispatchMode, DispatchRun, Dispatcher, DoneOutcome,
                   SiteSched, StartOutcome};
pub use faults::{BreakerState, FaultWindow, RetryPolicy,
                 SiteHealthTracker, WanFaultPlan};
pub use site::SiteWorld;

use std::collections::HashMap;

use anyhow::Context;

use crate::broker::{ElasticityBroker, PolicyKind, ScenarioPlan};
use crate::clues::{Clues, CluesConfig};
use crate::cloudsim::{CloudSite, SiteSpec, VmId};
use crate::ids::{NodeId, NodeNames};
use crate::im::{Im, NodeRole};
use crate::lrms::core::Placement;
use crate::lrms::{HtCondor, JobId, Lrms, Slurm};
use crate::metrics::{Recorder, ShardSink};
use crate::netsim::{LinkSpec, Network};
use crate::obs::{EngineProfile, MetricsSeries, ObsConfig, Trace,
                 TraceShard};
use crate::orchestrator::{Sla, UpdateId, WorkflowEngine};
use crate::runtime::ModelRuntime;
use crate::sim::shard::{default_threads, run_sharded_profiled,
                        run_sharded_serial,
                        run_sharded_stealing_profiled, StealConfig};
use crate::sim::{ShardEvent, ShardKey, ShardedQueue, SimTime};
use crate::tosca::{ClusterTemplate, LrmsKind};
use crate::util::prng::Prng;
use crate::vrouter::Overlay;
use crate::workload::trace::{TraceSource, WATERMARK_UNBOUNDED};
use crate::workload::Workload;

/// Which replay engine drives [`HybridCluster::run`]. All three produce
/// byte-identical output (recorders, figures, spill files, reports);
/// they differ only in how site-shard windows are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Single-queue deterministic merge (the reference semantics).
    Serial,
    /// Parallel site windows between control barriers, fixed per-thread
    /// shard chunks. `threads: 0` = auto (one per site, capped by the
    /// machine).
    Sharded { threads: usize },
    /// Work-stealing shard replay (hot shards never serialize behind
    /// cold ones). `threads: 0` = auto.
    Stealing { threads: usize },
}

impl Engine {
    /// The three engines, in reference-first order (bench sweeps).
    pub const ALL: [Engine; 3] = [
        Engine::Serial,
        Engine::Sharded { threads: 0 },
        Engine::Stealing { threads: 0 },
    ];

    pub fn label(self) -> &'static str {
        match self {
            Engine::Serial => "serial",
            Engine::Sharded { .. } => "sharded",
            Engine::Stealing { .. } => "stealing",
        }
    }
}

/// Per-run configuration.
pub struct RunConfig {
    pub template: ClusterTemplate,
    pub sites: Vec<SiteSpec>,
    pub slas: Vec<Sla>,
    pub workload: Workload,
    pub seed: u64,
    /// Scripted monitor glitches (the vnode-5 transient).
    pub injections: crate::cloudsim::InjectionPlan,
    /// Which broker policy owns the grow-to-which-site decision
    /// (`SlaRank` reproduces the legacy `select_site` exactly).
    pub policy: PolicyKind,
    /// Scripted elasticity scenario — spot-preemption waves, site
    /// outages, price spikes, WAN partitions — with times relative to
    /// the workload t0 (the same convention as `injections`).
    pub scenario: ScenarioPlan,
    /// Scripted WAN fault plan for the control↔site boundary (loss,
    /// duplication, jitter, partitions), times relative to the
    /// workload t0. Empty = no scripted faults.
    pub faults: WanFaultPlan,
    /// Retry/backoff/failover/quarantine knobs for the self-healing
    /// layer (only consulted when any fault source is configured).
    pub retry: RetryPolicy,
    /// Paper default true; false = parallel-provisioning ablation.
    pub serialized_orchestrator: bool,
    /// Run real PJRT inference for one out of every N jobs
    /// (0 = never; 1 = every job). Virtual job time is unaffected.
    pub inference_every: u32,
    /// Simulation horizon (safety stop).
    pub horizon: SimTime,
    /// When set, every shard's recorder streams its
    /// transitions/job-runs/milestones to spill files under this
    /// directory during the replay instead of accumulating them in
    /// memory; the report's recorder is rebuilt from the spills at run
    /// end. Constant-memory metrics for long replays — figures and
    /// reports are byte-identical either way.
    pub metrics_spill_dir: Option<std::path::PathBuf>,
    /// Replay engine (Serial is the reference; all engines produce
    /// byte-identical output).
    pub engine: Engine,
    /// One-way WAN latency of a site→control notification, seconds.
    /// This is also the sharded engines' lookahead: site handlers emit
    /// control events exactly this far in the future, which is what
    /// makes parallel site windows safe. 0 degrades the parallel
    /// engines to exact single-queue stepping (still byte-identical).
    pub control_latency_s: f64,
    /// Completed-job report batching grid, seconds: a site flushes its
    /// completed-run buffer to the controller at the next multiple of
    /// this interval (≤ 0 = report at the completion itself). Batching
    /// bounds control-shard traffic on busy sites — the controller
    /// learns of a completion at most `report_interval_s +
    /// control_latency_s` after it happens, just like a real remote
    /// LRMS node talking to its controller.
    pub report_interval_s: f64,
    /// Observability switches (causal tracing + on-clock metrics).
    /// Both off by default; turning them on records sim-clock streams
    /// that are byte-identical across engines and digest-neutral (the
    /// [`crate::obs`] contract).
    pub obs: ObsConfig,
    /// Who places jobs onto nodes. `Centralized` (the default) is the
    /// paper's shape — one control-shard LRMS scheduling everything.
    /// `Partitioned` moves scheduling into the site shards: each
    /// [`SiteWorld`] places jobs locally with its own [`SiteSched`]
    /// slice, and the control plane shrinks to a [`Dispatcher`] that
    /// routes queue blocks (broker-ranked, credit-bounded) and
    /// arbitrates cross-site spillover at barriers under a two-phase
    /// lease, so no job is ever double-placed. Either mode is
    /// byte-identical across the three engines; the two modes'
    /// timelines legitimately differ (block routing and WAN report
    /// lag), so digests are compared within a mode, not across modes.
    pub dispatch: DispatchMode,
    /// Partitioned-dispatch tuning (headroom batching); ignored under
    /// `Centralized`. The knob used is echoed in
    /// [`RunReport::max_blocks_per_barrier`].
    pub dispatch_cfg: DispatchConfig,
    /// Streaming workload source. `None` (the default) streams
    /// `workload` through a
    /// [`crate::workload::trace::SynthSource`], so the streaming path
    /// is the *only* submission path and synthetic vs trace-driven
    /// runs are byte-identical by construction. Set a boxed
    /// [`TraceSource`] (CSV parser, arrival generator) to replay a
    /// trace instead; `workload` then only contributes the per-node
    /// setup-time model.
    pub source: Option<Box<dyn TraceSource>>,
    /// Arrival look-ahead watermark, in jobs: the control plane keeps
    /// pulling blocks from the source until at least this many jobs are
    /// buffered ahead of the clock, and tops back up as submission
    /// events drain the buffer — frontend memory is O(watermark + one
    /// block) regardless of trace length.
    /// [`WATERMARK_UNBOUNDED`] (the default) buffers the whole trace up
    /// front, which reproduces the pre-streaming event schedule
    /// bit-for-bit; large streamed runs set a finite watermark.
    pub ingest_watermark_jobs: u32,
}

impl RunConfig {
    /// The paper's §4 scenario: CESNET (quota 3) + AWS, SLURM template,
    /// full workload, serialized orchestrator.
    pub fn paper_usecase(scale: f64, seed: u64) -> RunConfig {
        let template = crate::tosca::builtin("slurm").expect("template");
        RunConfig {
            template,
            sites: RunConfig::paper_site_specs(2),
            slas: vec![
                Sla { site_name: "CESNET-MCC".into(), priority: 0,
                      max_instances: None },
                Sla { site_name: "AWS".into(), priority: 1,
                      max_instances: None },
            ],
            workload: Workload::paper(scale),
            seed,
            injections: crate::cloudsim::InjectionPlan::default(),
            policy: PolicyKind::SlaRank,
            scenario: ScenarioPlan::default(),
            faults: WanFaultPlan::default(),
            retry: RetryPolicy::default(),
            serialized_orchestrator: true,
            inference_every: 0,
            horizon: SimTime::from_hms(48, 0, 0),
            metrics_spill_dir: None,
            engine: Engine::Serial,
            control_latency_s: 0.1,
            report_interval_s: 1.0,
            obs: ObsConfig::default(),
            dispatch: DispatchMode::Centralized,
            dispatch_cfg: DispatchConfig::default(),
            source: None,
            ingest_watermark_jobs: WATERMARK_UNBOUNDED,
        }
    }

    /// The paper use case over `n_sites` sites: CESNET + AWS (the
    /// paper pair), the AWS spot market from 3 sites up, opportunistic
    /// OpenNebula sites beyond — the site ladder the benches and
    /// scenario tests sweep over 2–8 sites.
    pub fn paper_usecase_sites(scale: f64, seed: u64, n_sites: usize)
        -> RunConfig {
        let mut cfg = RunConfig::paper_usecase(scale, seed);
        cfg.sites = RunConfig::paper_site_specs(n_sites);
        cfg
    }

    /// The shared site ladder (see [`RunConfig::paper_usecase_sites`]).
    pub fn paper_site_specs(n_sites: usize) -> Vec<SiteSpec> {
        let mut sites = vec![SiteSpec::cesnet_metacentrum(),
                             SiteSpec::aws_us_east_2()];
        if n_sites >= 3 {
            sites.push(SiteSpec::aws_spot_us_east_2());
        }
        for i in 3..n_sites {
            sites.push(SiteSpec::opennebula(&format!("ON-{i}")));
        }
        sites.truncate(n_sites.max(1));
        sites
    }
}

/// One completed job execution, as reported by a site shard to the
/// controller in an [`Ev::JobBatch`]. `gen` is the job's requeue count
/// at scheduling time, so stale completions from executions that were
/// requeued away (node failure) are recognized and dropped.
#[derive(Debug, Clone)]
pub struct JobRun {
    pub job: JobId,
    pub node: NodeId,
    pub gen: u32,
}

/// Simulation events. Node references are interned ids; names are
/// resolved only when a milestone or report line is rendered. Every
/// event declares its shard: the control shard carries orchestrator /
/// CLUES / broker / scenario traffic plus all site→control emissions,
/// each cloud site's shard carries that site's local timers and the
/// control→site commands.
#[derive(Debug, Clone)]
pub enum Ev {
    // ---- control shard --------------------------------------------
    /// Kick off the initial deployment (FE + initial workers).
    Deploy,
    /// Submit workload block `i`.
    SubmitBlock(usize),
    /// CLUES monitor tick.
    CluesTick,
    /// The workflow engine may start queued updates.
    OrchestratorPump,
    /// Site → control: a node finished contextualization and joins.
    /// Carries the VM incarnation so a notification that crossed the
    /// WAN while the node name was reclaimed and reused cannot be
    /// misattributed to the successor.
    NodeReady { site: usize, vm: VmId, node: NodeId },
    /// Site → control: a VM failed to boot (same staleness rule).
    BootFailed { site: usize, vm: VmId, node: NodeId },
    /// Site → control: a running VM was lost (crash or spot reclaim).
    NodeLost { site: usize, vm: VmId, node: NodeId, preempted: bool },
    /// Site → control: the provider finished terminating a node's VM.
    NodeOff { site: usize, vm: VmId, node: NodeId,
              update: Option<UpdateId> },
    /// Site → control: batched completed-run report.
    JobBatch { site: usize, done: Vec<JobRun> },
    /// Scenario: spot-preemption wave — up to `count` (0 = all) running
    /// workers at `site` are reclaimed at once.
    SpotWave { site: usize, count: u32 },
    /// Scenario: whole-site outage begins / ends.
    OutageStart { site: usize },
    OutageEnd { site: usize },
    /// Scenario: price spike begins / ends at a site.
    PriceSpikeStart { site: usize, factor: f64 },
    PriceSpikeEnd { site: usize },
    /// Chaos: a scripted WAN partition of `site` begins / ends
    /// (control-side marker — broker avoidance, vRouter down/up; the
    /// site-side total loss is enforced by its installed windows).
    WanPartitionStart { site: usize },
    WanPartitionEnd { site: usize },
    /// Chaos: a backed-off provisioning retry for `node` is due.
    RetryProvision { node: NodeId },
    /// Site → control: heartbeat reply (unreliable on purpose — its
    /// loss is the missed-heartbeat signal the breaker counts).
    SiteHeartbeat { site: usize },
    /// Site → control (partitioned dispatch): batched barrier emission
    /// of local execution starts, completions, and spillover — jobs the
    /// site cannot hold, returned for re-routing under the two-phase
    /// lease (every entry echoes its lease epoch; see
    /// [`DispatchRun`]/[`DispatchJob`]).
    SiteJobReport { site: usize, started: Vec<DispatchRun>,
                    done: Vec<DispatchRun>, spilled: Vec<DispatchJob> },

    // ---- site shards ----------------------------------------------
    /// Control → site: a VM finishes booting (failed per the ticket);
    /// on success contextualization takes `ctx_secs` more.
    BootDone { site: usize, vm: VmId, node: NodeId, failed: bool,
               ctx_secs: f64 },
    /// Site-local: contextualization timer fires.
    CtxTimer { site: usize, vm: VmId, node: NodeId },
    /// Control → site: a scheduled job's execution ends on `node`.
    JobTimer { site: usize, job: JobId, node: NodeId, gen: u32 },
    /// Site-local: flush the completed-run buffer to the controller.
    FlushTimer { site: usize },
    /// Control → site: sampled stochastic crash (`preempt` = spot
    /// reclaim) timer for a VM incarnation.
    CrashTimer { site: usize, vm: VmId, node: NodeId, preempt: bool },
    /// Control → site: the provider finishes a decommission.
    TerminationDone { site: usize, vm: VmId, node: NodeId,
                      update: Option<UpdateId> },
    /// Control → site: liveness probe (the site answers with an
    /// unreliable [`Ev::SiteHeartbeat`]).
    HeartbeatPing { site: usize },
    /// Site-local: ack timeout for a dropped reliable report expired —
    /// retransmit it through a fresh fault decision.
    Retransmit { site: usize, ev: Box<Ev>, attempt: u32 },
    /// Control → site (partitioned dispatch): a routed block of leased
    /// jobs for the site's local scheduler slice.
    JobBlock { site: usize, jobs: Vec<DispatchJob> },
    /// Control → site (partitioned dispatch): a worker node joined and
    /// is granted to the site's scheduler slice.
    SiteNodeUp { site: usize, node: NodeId, slots: u32 },
}

impl ShardEvent for Ev {
    fn shard_key(&self) -> ShardKey {
        match self {
            Ev::Deploy
            | Ev::SubmitBlock(_)
            | Ev::CluesTick
            | Ev::OrchestratorPump
            | Ev::NodeReady { .. }
            | Ev::BootFailed { .. }
            | Ev::NodeLost { .. }
            | Ev::NodeOff { .. }
            | Ev::JobBatch { .. }
            | Ev::SpotWave { .. }
            | Ev::OutageStart { .. }
            | Ev::OutageEnd { .. }
            | Ev::PriceSpikeStart { .. }
            | Ev::PriceSpikeEnd { .. }
            | Ev::WanPartitionStart { .. }
            | Ev::WanPartitionEnd { .. }
            | Ev::RetryProvision { .. }
            | Ev::SiteHeartbeat { .. }
            | Ev::SiteJobReport { .. } => ShardKey::Control,
            Ev::BootDone { site, .. }
            | Ev::CtxTimer { site, .. }
            | Ev::JobTimer { site, .. }
            | Ev::FlushTimer { site }
            | Ev::CrashTimer { site, .. }
            | Ev::TerminationDone { site, .. }
            | Ev::HeartbeatPing { site }
            | Ev::Retransmit { site, .. }
            | Ev::JobBlock { site, .. }
            | Ev::SiteNodeUp { site, .. } => {
                ShardKey::Site(*site as u32)
            }
        }
    }
}

/// Per-VM-incarnation accounting row (names are reused after
/// termination, so rows — not names — are the unit of accounting).
#[derive(Debug, Clone)]
pub struct PerVm {
    pub name: String,
    pub site: String,
    pub role: NodeRole,
    pub hours: f64,
    pub cost_usd: f64,
    pub busy_hours: f64,
}

/// Final report of a run — everything the figures/tables need.
pub struct RunReport {
    pub recorder: Recorder,
    pub makespan: SimTime,
    pub jobs_completed: u32,
    pub total_cost_usd: f64,
    /// One row per VM incarnation.
    pub per_vm: Vec<PerVm>,
    /// (node, requested_at, joined_at) deployment latencies.
    pub deploy_times: Vec<(String, SimTime, SimTime)>,
    /// Busy (job-executing) seconds per node.
    pub busy_secs: HashMap<String, f64>,
    /// Real PJRT inferences actually executed.
    pub inferences_run: u64,
    /// Sum of inference wall-clock seconds (real, not simulated).
    pub inference_wall_secs: f64,
    /// Events dispatched (DES perf counter).
    pub events: u64,
    /// Wall-clock seconds the simulation took.
    pub wall_secs: f64,
    /// Broker policy that governed worker placement.
    pub policy: &'static str,
    /// VMs lost to preemption waves / site outages / spot reclaims.
    pub preempted_vms: u32,
    /// Jobs requeued by those losses.
    pub preempted_jobs: u32,
    /// Of those, jobs that went on to complete (recovery).
    pub preempt_recovered: u32,
    /// Site→control messages the WAN chaos layer dropped.
    pub messages_dropped: u64,
    /// Site→control messages delivered twice (duplication fault).
    pub messages_duplicated: u64,
    /// Reliable reports retransmitted after an ack timeout.
    pub messages_retransmitted: u64,
    /// Per-site breakdown of [`RunReport::messages_dropped`]
    /// (index = site index).
    pub site_messages_dropped: Vec<u64>,
    /// Per-site breakdown of [`RunReport::messages_duplicated`].
    pub site_messages_duplicated: Vec<u64>,
    /// Per-site breakdown of [`RunReport::messages_retransmitted`].
    pub site_messages_retransmitted: Vec<u64>,
    /// Backed-off provisioning retries scheduled after boot failures.
    pub provision_retries: u32,
    /// Retries that landed at a different site than the original.
    pub provision_failovers: u32,
    /// Circuit-breaker quarantine windows opened.
    pub quarantine_windows: u32,
    /// Total time sites spent quarantined (open windows close at the
    /// makespan), seconds.
    pub quarantine_secs: f64,
    /// Jobs requeued when a quarantine revoked their node's lease.
    pub lease_requeued_jobs: u32,
    /// Of those, jobs that went on to complete elsewhere.
    pub lease_recovered_jobs: u32,
    /// Final health score per site (exactly 1.0 when chaos is off or
    /// the site never degraded).
    pub site_health: Vec<f64>,
    /// Lowest health each site reached (trajectory floor).
    pub site_health_min: Vec<f64>,
    /// When each site's health first crossed the placement de-rank
    /// threshold (seconds), if ever.
    pub site_deranked_at: Vec<Option<f64>>,
    /// When each site's circuit breaker first opened (seconds), if
    /// ever. Adaptive placement is working when the de-rank time beats
    /// this.
    pub site_first_quarantine_at: Vec<Option<f64>>,
    /// Correlated per-site partition windows installed (fault-plan
    /// region groups + scenario regional outages, one per member).
    pub regional_windows: u32,
    /// High-water mark of arrival jobs buffered ahead of the clock by
    /// the streaming frontend — the constant-memory bound the trace
    /// tests assert (≤ watermark + one block). Deterministic, but a
    /// function of [`RunConfig::ingest_watermark_jobs`] rather than of
    /// the replay outcome, so it stays out of the digest: the same
    /// trace replayed under different watermarks digests identically
    /// in everything the cluster *did*.
    pub peak_buffered_jobs: u64,
    /// Echo of [`DispatchConfig::max_blocks_per_barrier`] (1 under
    /// centralized dispatch or the default knob). Pure configuration,
    /// not a replay outcome — excluded from the digest.
    pub max_blocks_per_barrier: u32,
    /// Merged causal trace — `Some` iff [`RunConfig::obs`] enabled
    /// tracing. Sim-clock data: byte-identical across engines, never
    /// part of the digest (passive recording cannot perturb the run).
    pub trace: Option<Trace>,
    /// On-clock metrics series — `Some` iff [`RunConfig::obs`] enabled
    /// metrics. Same sim-clock contract as `trace`.
    pub metrics: Option<MetricsSeries>,
    /// Wall-clock engine profile — `Some` for the parallel engines,
    /// `None` for [`Engine::Serial`]. Nondeterministic by nature and
    /// therefore excluded from [`RunReport::determinism_digest`].
    pub profile: Option<EngineProfile>,
}

/// Canonical bit-exact digest of everything a deterministic replay
/// must reproduce — wall-clock fields excluded. Every cross-engine /
/// cross-replay equality check (unit tests, the engine-equivalence
/// property, the bench asserts) compares this one value, so the
/// byte-identity contract lives in exactly one place: a new
/// [`RunReport`] field that matters for determinism belongs here.
#[derive(Debug, Clone, PartialEq)]
pub struct RunDigest {
    pub jobs_completed: u32,
    pub makespan_bits: u64,
    pub cost_bits: u64,
    pub events: u64,
    pub preempted_vms: u32,
    pub preempted_jobs: u32,
    pub preempt_recovered: u32,
    pub messages_dropped: u64,
    pub messages_duplicated: u64,
    pub messages_retransmitted: u64,
    /// Per-site (dropped, duplicated, retransmitted) chaos counters.
    pub site_messages: Vec<(u64, u64, u64)>,
    pub provision_retries: u32,
    pub provision_failovers: u32,
    pub quarantine_windows: u32,
    pub quarantine_secs_bits: u64,
    pub lease_requeued_jobs: u32,
    pub lease_recovered_jobs: u32,
    /// Per-site (final health, floor, first de-rank, first quarantine)
    /// trajectories, bit-exact.
    pub site_health: Vec<(u64, u64, Option<u64>, Option<u64>)>,
    pub regional_windows: u32,
    pub policy: &'static str,
    /// (name, site, hours, cost, busy hours) per VM incarnation.
    pub per_vm: Vec<(String, String, u64, u64, u64)>,
    /// (node, requested, joined) bit-exact deployment latencies.
    pub deploy_times: Vec<(String, u64, u64)>,
    /// The full milestone log.
    pub milestones: Vec<(u64, String)>,
    /// Busy seconds per node, name-sorted.
    pub busy_secs: Vec<(String, u64)>,
}

impl RunReport {
    /// See [`RunDigest`].
    pub fn determinism_digest(&self) -> RunDigest {
        RunDigest {
            jobs_completed: self.jobs_completed,
            makespan_bits: self.makespan.0.to_bits(),
            cost_bits: self.total_cost_usd.to_bits(),
            events: self.events,
            preempted_vms: self.preempted_vms,
            preempted_jobs: self.preempted_jobs,
            preempt_recovered: self.preempt_recovered,
            messages_dropped: self.messages_dropped,
            messages_duplicated: self.messages_duplicated,
            messages_retransmitted: self.messages_retransmitted,
            site_messages: (0..self.site_messages_dropped.len())
                .map(|s| (self.site_messages_dropped[s],
                          self.site_messages_duplicated[s],
                          self.site_messages_retransmitted[s]))
                .collect(),
            provision_retries: self.provision_retries,
            provision_failovers: self.provision_failovers,
            quarantine_windows: self.quarantine_windows,
            quarantine_secs_bits: self.quarantine_secs.to_bits(),
            lease_requeued_jobs: self.lease_requeued_jobs,
            lease_recovered_jobs: self.lease_recovered_jobs,
            site_health: (0..self.site_health.len())
                .map(|s| (self.site_health[s].to_bits(),
                          self.site_health_min[s].to_bits(),
                          self.site_deranked_at[s].map(f64::to_bits),
                          self.site_first_quarantine_at[s]
                              .map(f64::to_bits)))
                .collect(),
            regional_windows: self.regional_windows,
            policy: self.policy,
            per_vm: self
                .per_vm
                .iter()
                .map(|v| (v.name.clone(), v.site.clone(),
                          v.hours.to_bits(), v.cost_usd.to_bits(),
                          v.busy_hours.to_bits()))
                .collect(),
            deploy_times: self
                .deploy_times
                .iter()
                .map(|(n, a, b)| (n.clone(), a.0.to_bits(), b.0.to_bits()))
                .collect(),
            milestones: self
                .recorder
                .milestones
                .iter()
                .map(|(t, m)| (t.0.to_bits(), m.clone()))
                .collect(),
            busy_secs: self
                .busy_secs
                .iter()
                .map(|(n, s)| (n.clone(), s.to_bits()))
                .collect::<std::collections::BTreeMap<_, _>>()
                .into_iter()
                .collect(),
        }
    }

    /// §4.2 effective utilization: job-execution time over paid time of
    /// the paid *worker* nodes (the paper's "66% of the paid time of
    /// these nodes was used in effective job computation").
    pub fn paid_utilization(&self) -> f64 {
        let (busy, paid) = self
            .per_vm
            .iter()
            .filter(|r| r.cost_usd > 0.0 && r.role == NodeRole::WorkerNode)
            .fold((0.0, 0.0), |(b, p), r| {
                (b + r.busy_hours, p + r.hours)
            });
        if paid == 0.0 { 0.0 } else { busy / paid }
    }
}

pub(crate) const FE_NAME: &str = "front-end";

/// The simulation world (also the public cluster handle): the control
/// plane plus one [`SiteWorld`] per cloud site.
pub struct HybridCluster {
    pub control: ControlWorld,
    pub sites: Vec<SiteWorld>,
}

impl HybridCluster {
    /// Build the world (no events run yet). Scenario plans, fault
    /// plans and failure-model fields are validated here: a plan
    /// written for a bigger world (out-of-range site index) or with
    /// nonsensical probabilities is a configuration error, reported
    /// before anything runs. (Fault plans targeting the front-end site
    /// can only be checked once the FE is placed — that check happens
    /// at workload start and fails the run.)
    pub fn new(cfg: RunConfig) -> anyhow::Result<HybridCluster> {
        let n = cfg.sites.len();
        cfg.scenario
            .validate(n)
            .context("invalid scenario plan")?;
        // Fault-plan rejections name the offending site, so the
        // interner is fed the roster before validation runs.
        let site_names = crate::ids::SiteNames::new();
        for spec in &cfg.sites {
            site_names.intern(&spec.name);
        }
        cfg.faults
            .validate_named(n, &site_names)
            .context("invalid WAN fault plan")?;
        cfg.retry.validate().context("invalid retry policy")?;
        for (i, spec) in cfg.sites.iter().enumerate() {
            let f = &spec.failure;
            if !f.message_loss_prob.is_finite()
                || !(0.0..1.0).contains(&f.message_loss_prob)
            {
                anyhow::bail!(
                    "site {i} ({}): message_loss_prob must be in \
                     [0, 1) (got {}) — total steady loss can never \
                     deliver anything", spec.name, f.message_loss_prob);
            }
            if !f.ack_timeout_s.is_finite() || f.ack_timeout_s <= 0.0 {
                anyhow::bail!(
                    "site {i} ({}): ack_timeout_s must be positive \
                     (got {})", spec.name, f.ack_timeout_s);
            }
        }
        let mut net = Network::new();
        let mut clouds = Vec::new();
        for (i, spec) in cfg.sites.iter().enumerate() {
            let loc = net.add_location(&spec.name);
            clouds.push(CloudSite::new(spec.clone(), i as u8, loc,
                                       cfg.seed ^ (i as u64 + 1)));
        }
        // Underlay links: research-net WAN between academic sites,
        // transatlantic to AWS.
        for i in 0..clouds.len() {
            for j in (i + 1)..clouds.len() {
                let spec = if clouds[i].spec.name.starts_with("AWS")
                    || clouds[j].spec.name.starts_with("AWS")
                {
                    LinkSpec::transatlantic()
                } else {
                    LinkSpec::wan()
                };
                let (a, b) = (clouds[i].net_id, clouds[j].net_id);
                net.set_link(a, b, spec);
            }
        }
        // One interner shared by every node-identity consumer.
        let names = NodeNames::new();
        let lrms: Box<dyn Lrms> = match cfg.template.lrms {
            LrmsKind::Slurm => Box::new(Slurm::with_names(names.clone())),
            LrmsKind::HtCondor => {
                Box::new(HtCondor::with_names(names.clone()))
            }
        };
        let clues = Clues::with_names(CluesConfig {
            idle_timeout_s: cfg.template.idle_timeout_s,
            min_workers: cfg.template.scalable.min_instances,
            max_workers: cfg.template.scalable.max_instances,
            ..CluesConfig::default()
        }, names.clone());
        let overlay = Overlay::new(cfg.template.vpn_cipher);
        let engine = WorkflowEngine::new(cfg.serialized_orchestrator);
        let im = Im::new(cfg.seed);
        let broker = ElasticityBroker::new(
            cfg.policy,
            &clouds,
            &cfg.slas,
            cfg.template.worker.num_cpus,
            cfg.template.worker.mem_gb,
        );
        let runtime = if cfg.inference_every > 0 {
            Some(ModelRuntime::load(crate::runtime::artifacts_dir(), 1)
                .context("loading PJRT runtime (run `make artifacts`)")?)
        } else {
            None
        };
        let rng = Prng::new(cfg.seed ^ 0xC1);
        let n_sites = clouds.len();
        let control_latency = cfg.control_latency_s.max(0.0);
        let report_grid = cfg.report_interval_s;

        // One recorder per shard: control = spill shard 0, site i =
        // spill shard i + 1 (the same slice order the merges use).
        let (control_rec, site_recs) = match &cfg.metrics_spill_dir {
            Some(dir) => {
                let c = Recorder::with_spill(
                    names.clone(),
                    ShardSink::create(dir, 0)
                        .context("creating control metrics spill sink")?,
                );
                let mut v = Vec::with_capacity(n_sites);
                for i in 0..n_sites {
                    v.push(Recorder::with_spill(
                        names.clone(),
                        ShardSink::create(dir, (i + 1) as u32)
                            .context("creating site metrics spill sink")?,
                    ));
                }
                (c, v)
            }
            None => (
                Recorder::with_names(names.clone()),
                (0..n_sites)
                    .map(|_| Recorder::with_names(names.clone()))
                    .collect(),
            ),
        };

        // The chaos layer is enabled only when some fault source is
        // configured; otherwise the per-message decision path (and its
        // seq counter) is skipped entirely, so pre-chaos runs keep
        // their event streams — and digests — bit for bit.
        let chaos_enabled = !cfg.faults.is_empty()
            || cfg.scenario.events.iter().any(|e| {
                matches!(
                    e,
                    crate::broker::ScenarioEvent::WanPartition { .. }
                    | crate::broker::ScenarioEvent::RegionalOutage { .. })
            })
            || cfg.sites.iter().any(|s| s.failure.message_loss_prob > 0.0);
        let fault_seed = cfg.seed ^ cfg.faults.seed.rotate_left(17);

        // Partitioned dispatch: each site owns a scheduler slice with
        // the template's placement policy and its own duration stream
        // (advanced in site event order, so engines sample identically).
        let placement = match cfg.template.lrms {
            LrmsKind::Slurm => Placement::PackFirstFit,
            LrmsKind::HtCondor => Placement::SpreadMostFree,
        };
        let setup_mean = cfg.workload.setup_secs;
        let partitioned = cfg.dispatch == DispatchMode::Partitioned;
        let sites: Vec<SiteWorld> = clouds
            .into_iter()
            .zip(site_recs)
            .enumerate()
            .map(|(i, (cloud, recorder))| {
                let faults = faults::SiteFaultState::new(
                    i,
                    fault_seed,
                    cloud.spec.failure.message_loss_prob,
                    cloud.spec.failure.ack_timeout_s,
                    chaos_enabled,
                );
                // Trace shard i + 1 (the control plane owns shard 0),
                // mirroring the recorder layout.
                let trace =
                    TraceShard::new((i + 1) as u32, cfg.obs.trace);
                let sched = partitioned.then(|| {
                    SiteSched::new(
                        placement,
                        names.clone(),
                        cfg.seed
                            ^ 0xD15B
                            ^ (i as u64 + 1)
                                .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        setup_mean,
                        cfg.dispatch_cfg.max_blocks_per_barrier,
                    )
                });
                SiteWorld::new(
                    i, cloud, recorder, names.clone(), control_latency,
                    report_grid, faults, trace, sched)
            })
            .collect();

        let control = ControlWorld::build(
            cfg, net, overlay, lrms, clues, engine, im, broker,
            control_rec, names, runtime, rng, n_sites, control_latency,
        );
        Ok(HybridCluster { control, sites })
    }

    /// Deploy + run the full scenario to completion under the
    /// configured [`Engine`]. Returns the report.
    pub fn run(self) -> anyhow::Result<RunReport> {
        let wall0 = std::time::Instant::now();
        let HybridCluster { mut control, mut sites } = self;
        let mut q: ShardedQueue<Ev> = ShardedQueue::new(sites.len());
        // The paper's timeline (Fig. 9) is relative to the moment the
        // initial cluster is up; workload blocks are scheduled when the
        // InitialDeploy update completes.
        q.schedule_at(SimTime::ZERO, Ev::Deploy);
        let horizon = control.cfg.horizon;
        // Parallel engines run through their profiled variants; the
        // wall-clock profile is engine telemetry only (never digested).
        let profile = match control.cfg.engine {
            Engine::Serial => {
                run_sharded_serial(&mut control, &mut sites, &mut q,
                                   horizon);
                None
            }
            Engine::Sharded { threads } => {
                let n = if threads == 0 {
                    default_threads(sites.len())
                } else {
                    threads
                };
                let (_, prof) = run_sharded_profiled(
                    &mut control, &mut sites, &mut q, horizon, n);
                Some(prof)
            }
            Engine::Stealing { threads } => {
                let n = if threads == 0 {
                    default_threads(sites.len())
                } else {
                    threads
                };
                let (_, prof) = run_sharded_stealing_profiled(
                    &mut control, &mut sites, &mut q, horizon,
                    StealConfig::new(n));
                Some(prof)
            }
        };
        let makespan = q.now();
        if let Some(msg) = control.fatal.take() {
            anyhow::bail!("{msg}");
        }
        // A quarantine still open at the drain accounts to the
        // makespan (the site never came back).
        for opened in control.quarantine_opened_at.iter_mut() {
            if let Some(o) = opened.take() {
                control.quarantine_secs += makespan.0 - o;
            }
        }

        // Merge the per-shard recorders (control first, then sites in
        // index order — the fixed slice order both merge paths key by).
        // Spill mode streams each shard to its own files during the
        // replay and k-way merges them back here.
        let recorder = if control.recorder.is_spilling() {
            let mut files = Vec::with_capacity(1 + sites.len());
            files.push(control
                .recorder
                .finish_spill()
                .expect("is_spilling checked")
                .context("flushing control metrics spill")?);
            for s in &mut sites {
                files.push(s
                    .take_recorder()
                    .finish_spill()
                    .expect("site recorders spill with the control one")
                    .context("flushing site metrics spill")?);
            }
            Recorder::merge_spills(control.names.clone(), &files)
                .context("merging metrics spill")?
        } else {
            let mut shards = Vec::with_capacity(1 + sites.len());
            shards.push(std::mem::take(&mut control.recorder));
            for s in &mut sites {
                shards.push(s.take_recorder());
            }
            Recorder::merge_shards(control.names.clone(), &shards)
        };

        // ---- report assembly ---------------------------------------
        let mut per_vm = Vec::new();
        let mut total = 0.0;
        for rec in &control.vm_records {
            let site = &sites[rec.site];
            let entry = &site.cloud.ledger.entries[rec.ledger_idx];
            let hours = entry.secs(makespan) / 3600.0;
            let cost = entry.cost(makespan);
            total += cost;
            per_vm.push(PerVm {
                name: rec.name.clone(),
                site: site.cloud.spec.name.clone(),
                role: rec.role,
                hours,
                cost_usd: cost,
                busy_hours: rec.busy_secs / 3600.0,
            });
        }
        let deploy_times = control.deploy_log.clone();
        let busy_secs: HashMap<String, f64> =
            recorder.busy_secs_per_node().into_iter().collect();
        let (mut dropped, mut duplicated, mut retransmitted) =
            (0u64, 0u64, 0u64);
        let mut site_dropped = Vec::with_capacity(sites.len());
        let mut site_duplicated = Vec::with_capacity(sites.len());
        let mut site_retransmitted = Vec::with_capacity(sites.len());
        for s in &sites {
            let (d, du, r) = s.faults.counters();
            dropped += d;
            duplicated += du;
            retransmitted += r;
            site_dropped.push(d);
            site_duplicated.push(du);
            site_retransmitted.push(r);
        }
        // Merge the per-shard trace buffers under the same
        // (time, shard, seq) order the recorder merge uses.
        let trace = if control.cfg.obs.trace {
            let mut tshards = Vec::with_capacity(1 + sites.len());
            tshards.push(control.take_trace());
            for s in &mut sites {
                tshards.push(s.take_trace());
            }
            Some(Trace::merge_shards(tshards))
        } else {
            None
        };
        let metrics = if control.cfg.obs.metrics {
            let site_names: Vec<String> = sites
                .iter()
                .map(|s| s.cloud.spec.name.clone())
                .collect();
            Some(control.take_metrics().into_series(site_names))
        } else {
            None
        };
        Ok(RunReport {
            recorder,
            makespan,
            jobs_completed: control.jobs_completed,
            total_cost_usd: total,
            per_vm,
            deploy_times,
            busy_secs,
            inferences_run: control.inferences_run,
            inference_wall_secs: control.inference_wall_secs,
            events: q.dispatched(),
            wall_secs: wall0.elapsed().as_secs_f64(),
            policy: control.broker.policy_name(),
            preempted_vms: control.preempted_vms,
            preempted_jobs: control.preempted_jobs,
            preempt_recovered: control.preempt_recovered,
            messages_dropped: dropped,
            messages_duplicated: duplicated,
            messages_retransmitted: retransmitted,
            site_messages_dropped: site_dropped,
            site_messages_duplicated: site_duplicated,
            site_messages_retransmitted: site_retransmitted,
            provision_retries: control.provision_retries,
            provision_failovers: control.provision_failovers,
            quarantine_windows: control.quarantine_windows,
            quarantine_secs: control.quarantine_secs,
            lease_requeued_jobs: control.lease_requeued,
            lease_recovered_jobs: control.lease_recovered,
            site_health: control.health.clone(),
            site_health_min: control.health_min.clone(),
            site_deranked_at: control.health_deranked_at.clone(),
            site_first_quarantine_at: control.first_quarantine_at.clone(),
            regional_windows: control.regional_windows,
            peak_buffered_jobs: control.feed.peak_buffered_jobs(),
            max_blocks_per_barrier:
                control.cfg.dispatch_cfg.max_blocks_per_barrier,
            trace,
            metrics,
            profile,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::DisplayState;

    fn small_cfg(scale: f64) -> RunConfig {
        let mut cfg = RunConfig::paper_usecase(scale, 42);
        cfg.inference_every = 0; // no PJRT in unit tests
        cfg
    }

    fn run_cfg(cfg: RunConfig) -> RunReport {
        HybridCluster::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn scaled_usecase_completes_all_jobs() {
        let cfg = small_cfg(0.01); // ~36 jobs
        let total = cfg.workload.total_jobs();
        let report = run_cfg(cfg);
        assert_eq!(report.jobs_completed, total);
        assert!(report.makespan.0 > 0.0);
        // Front-end plus at least the two initial CESNET workers existed.
        let names = report.recorder.node_names();
        assert!(names.iter().any(|n| n == "front-end"), "{names:?}");
        assert!(names.iter().any(|n| n == "vnode-1"), "{names:?}");
        assert!(names.iter().any(|n| n == "vnode-2"), "{names:?}");
    }

    #[test]
    fn engines_produce_byte_identical_runs() {
        let reports: Vec<RunReport> = Engine::ALL
            .iter()
            .map(|&engine| {
                let mut cfg = small_cfg(0.02);
                cfg.engine = engine;
                run_cfg(cfg)
            })
            .collect();
        let reference = reports[0].determinism_digest();
        let until = reports[0].makespan;
        let f10 = reports[0].recorder.fig10_usage(60.0, until).to_csv();
        let f11 = reports[0].recorder.fig11_states(60.0, until).to_csv();
        for r in &reports[1..] {
            assert_eq!(r.determinism_digest(), reference);
            assert_eq!(r.recorder.fig10_usage(60.0, until).to_csv(), f10);
            assert_eq!(r.recorder.fig11_states(60.0, until).to_csv(), f11);
        }
    }

    #[test]
    fn observability_is_digest_neutral_and_engine_identical() {
        // Tracing/metrics on must not perturb the digest of an
        // otherwise identical run...
        let plain = run_cfg(small_cfg(0.02));
        let mut cfg = small_cfg(0.02);
        cfg.obs = crate::obs::ObsConfig::enabled();
        let traced = run_cfg(cfg);
        assert_eq!(traced.determinism_digest(),
                   plain.determinism_digest());
        assert!(plain.trace.is_none() && plain.metrics.is_none());
        let trace = traced.trace.as_ref().expect("trace recorded");
        let metrics = traced.metrics.as_ref().expect("metrics sampled");
        assert!(!trace.is_empty());
        assert!(!metrics.is_empty());
        // ...and the sim-clock streams are byte-identical across the
        // parallel engines (wall-clock profile excepted: it only
        // exists there, and is never compared).
        assert!(traced.profile.is_none(), "serial runs have no profile");
        let json = trace.to_chrome_json();
        let csv = trace.to_csv();
        let mcsv = metrics.to_csv();
        for engine in [Engine::Sharded { threads: 2 },
                       Engine::Stealing { threads: 2 }] {
            let mut cfg = small_cfg(0.02);
            cfg.obs = crate::obs::ObsConfig::enabled();
            cfg.engine = engine;
            let r = run_cfg(cfg);
            assert_eq!(r.determinism_digest(),
                       plain.determinism_digest());
            assert_eq!(r.trace.as_ref().unwrap().to_chrome_json(), json);
            assert_eq!(r.trace.as_ref().unwrap().to_csv(), csv);
            assert_eq!(r.metrics.as_ref().unwrap().to_csv(), mcsv);
            let prof = r.profile.expect("parallel engines profile");
            assert!(prof.windows > 0);
        }
    }

    #[test]
    fn spill_mode_metrics_match_in_memory_run() {
        let mem = run_cfg(small_cfg(0.01));
        let dir = std::env::temp_dir().join("evhc_cluster_spill_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = small_cfg(0.01);
        cfg.metrics_spill_dir = Some(dir.clone());
        let spilled = run_cfg(cfg);
        // Same seed, deterministic world: the streamed-and-merged
        // recorder must be byte-identical to the in-memory one.
        assert_eq!(spilled.makespan.0, mem.makespan.0);
        assert_eq!(spilled.jobs_completed, mem.jobs_completed);
        assert_eq!(spilled.recorder.milestones, mem.recorder.milestones);
        assert_eq!(spilled.recorder.node_names(), mem.recorder.node_names());
        let until = mem.makespan;
        assert_eq!(spilled.recorder.fig10_usage(60.0, until).to_csv(),
                   mem.recorder.fig10_usage(60.0, until).to_csv());
        assert_eq!(spilled.recorder.fig11_states(60.0, until).to_csv(),
                   mem.recorder.fig11_states(60.0, until).to_csv());
        assert_eq!(spilled.busy_secs, mem.busy_secs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_mode_under_stealing_matches_serial_in_memory() {
        let mem = run_cfg(small_cfg(0.02));
        let dir = std::env::temp_dir().join("evhc_cluster_steal_spill");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = small_cfg(0.02);
        cfg.engine = Engine::Stealing { threads: 2 };
        cfg.metrics_spill_dir = Some(dir.clone());
        let spilled = run_cfg(cfg);
        assert_eq!(spilled.makespan.0, mem.makespan.0);
        assert_eq!(spilled.recorder.milestones, mem.recorder.milestones);
        let until = mem.makespan;
        assert_eq!(spilled.recorder.fig10_usage(60.0, until).to_csv(),
                   mem.recorder.fig10_usage(60.0, until).to_csv());
        assert_eq!(spilled.recorder.fig11_states(60.0, until).to_csv(),
                   mem.recorder.fig11_states(60.0, until).to_csv());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bursts_to_aws_when_cesnet_full() {
        // Enough work to demand more than CESNET's quota (FE + 2 WNs).
        let report = run_cfg(small_cfg(0.05));
        // Some worker must have landed at AWS, which requires a vRouter.
        let aws_vms: Vec<&PerVm> = report
            .per_vm
            .iter()
            .filter(|r| r.site == "AWS")
            .collect();
        assert!(
            aws_vms.iter().any(|r| r.name.starts_with("vnode-")),
            "expected AWS workers, got {:?}", report.per_vm
        );
        assert!(
            aws_vms.iter().any(|r| r.name.starts_with("vrouter-")),
            "expected a site vRouter at AWS, got {:?}", report.per_vm
        );
        // And bursting costs money.
        assert!(report.total_cost_usd > 0.0);
    }

    #[test]
    fn workers_power_off_after_workload() {
        let report = run_cfg(small_cfg(0.01));
        // Final state of every worker node is Off.
        let final_states = report.recorder.states_at(report.makespan);
        for (node, state) in final_states {
            if node.starts_with("vnode-") {
                assert_eq!(state, DisplayState::Off, "{node}");
            }
        }
    }

    #[test]
    fn deploy_times_recorded_for_all_joined_nodes() {
        let report = run_cfg(small_cfg(0.02));
        assert!(!report.deploy_times.is_empty());
        for (node, req, joined) in &report.deploy_times {
            assert!(joined.0 > req.0, "{node} joined before requested?");
            // Sanity: between 2 and 40 minutes.
            let mins = (joined.0 - req.0) / 60.0;
            assert!(mins > 2.0 && mins < 40.0, "{node}: {mins} min");
        }
    }

    #[test]
    fn serialized_orchestrator_staggers_aws_joins() {
        let mut cfg = small_cfg(0.05);
        cfg.serialized_orchestrator = true;
        let report = run_cfg(cfg);
        let mut joins: Vec<f64> = report
            .deploy_times
            .iter()
            .filter(|(n, _, _)| n.starts_with("vnode-"))
            .map(|(_, _, j)| j.0)
            .collect();
        joins.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // With serialization, consecutive joins of the burst nodes must
        // be separated by at least a boot+ctx period (~10 min), not
        // simultaneous. Initial 2 CESNET nodes join close together (same
        // InitialDeploy update), so check the tail (AWS bursts).
        if joins.len() >= 4 {
            let gap = joins[3] - joins[2];
            assert!(gap > 300.0, "burst joins too close: {joins:?}");
        }
    }

    #[test]
    fn parallel_ablation_is_faster_to_scale() {
        let mut ser = small_cfg(0.05);
        ser.serialized_orchestrator = true;
        let mut par = small_cfg(0.05);
        par.serialized_orchestrator = false;
        let rs = run_cfg(ser);
        let rp = run_cfg(par);
        assert_eq!(rs.jobs_completed, rp.jobs_completed);
        assert!(
            rp.makespan.0 <= rs.makespan.0 + 2.0,
            "parallel {} !<= serialized {}", rp.makespan.0, rs.makespan.0
        );
    }

    #[test]
    fn vnode5_transient_flap_causes_fail_and_replace() {
        let mut cfg = small_cfg(0.1);
        // Flap vnode-2 well after it has joined (initial workers join
        // ~10 min in) and while work is still flowing.
        cfg.injections = crate::cloudsim::InjectionPlan {
            transient_downs: vec![crate::cloudsim::TransientDown {
                node_name: "vnode-2".into(),
                start: SimTime(1200.0),
                duration_secs: 300.0,
            }],
        };
        let report = run_cfg(cfg);
        // The node must have gone through Failed at some point.
        let failed = report
            .recorder
            .transitions_named()
            .iter()
            .any(|(_, n, s)| n == "vnode-2" && *s == DisplayState::Failed);
        assert!(failed, "vnode-2 never marked failed");
        // All jobs still completed (requeues made up for it).
        assert_eq!(report.jobs_completed, report.recorder.job_runs.len()
                   as u32);
    }

    #[test]
    fn non_hybrid_stays_on_premises() {
        let mut cfg = small_cfg(0.05);
        cfg.template.hybrid = false;
        let report = run_cfg(cfg);
        assert!(report.per_vm.iter().all(|r| r.site != "AWS"),
                "{:?}", report.per_vm);
        // Still finishes everything, just slower.
        assert!(report.jobs_completed > 0);
    }

    #[test]
    fn spot_wave_preempts_and_recovers_jobs() {
        let mut cfg = small_cfg(0.1);
        // Reclaim every running CESNET worker mid-block-1: vnode-1 and
        // vnode-2 joined before t0 and are busy until ~t0+800.
        cfg.scenario = ScenarioPlan::new().spot_wave(0, 600.0, 0);
        let total = cfg.workload.total_jobs();
        let report = run_cfg(cfg);
        assert_eq!(report.jobs_completed, total);
        assert!(report.preempted_vms >= 1,
                "wave reclaimed nothing");
        // Every preempted job was requeued and finished elsewhere.
        assert_eq!(report.preempt_recovered, report.preempted_jobs);
        assert_eq!(report.policy, "sla-rank");
        assert!(report.recorder.milestones.iter().any(
            |(_, m)| m.contains("spot-preemption wave")));
    }

    #[test]
    fn site_outage_bursts_to_surviving_site() {
        let mut cfg = small_cfg(0.1);
        // CESNET goes dark shortly after the run starts; the broker
        // must route every replacement worker to AWS until it is back.
        cfg.scenario = ScenarioPlan::new().site_outage(0, 600.0, 3600.0);
        let total = cfg.workload.total_jobs();
        let report = run_cfg(cfg);
        assert_eq!(report.jobs_completed, total);
        assert!(report.preempted_vms >= 1, "outage killed nothing");
        assert!(report.per_vm.iter().any(
            |r| r.site == "AWS" && r.name.starts_with("vnode-")),
            "no AWS replacements: {:?}", report.per_vm);
        assert!(report.recorder.milestones.iter().any(
            |(_, m)| m.contains("site outage")));
    }

    #[test]
    fn price_spike_inflates_burst_cost() {
        let base = run_cfg(small_cfg(0.05));
        let mut cfg = small_cfg(0.05);
        // 10x AWS prices for the whole burst window.
        cfg.scenario = ScenarioPlan::new()
            .price_spike(1, 0.0, 1_000_000.0, 10.0);
        let spiked = run_cfg(cfg);
        assert_eq!(base.jobs_completed, spiked.jobs_completed);
        // SlaRank ignores price, so the placements match — only the
        // bill changes. (The first burst VM can open before the spike
        // event lands, so the factor is well below the full 10x.)
        assert!(spiked.total_cost_usd > base.total_cost_usd * 1.5,
                "spiked {} !>> base {}", spiked.total_cost_usd,
                base.total_cost_usd);
    }

    #[test]
    fn alternative_policies_complete_the_workload() {
        for kind in [PolicyKind::CostMin, PolicyKind::LatencyMin,
                     PolicyKind::SpotAware] {
            let mut cfg = small_cfg(0.05);
            cfg.policy = kind;
            let total = cfg.workload.total_jobs();
            let report = run_cfg(cfg);
            assert_eq!(report.jobs_completed, total, "{kind:?}");
            assert_eq!(report.policy, kind.label());
        }
    }

    #[test]
    fn paid_utilization_between_zero_and_one() {
        let report = run_cfg(small_cfg(0.05));
        let u = report.paid_utilization();
        assert!((0.0..=1.0).contains(&u), "{u}");
        // At 5% scale boot/idle overhead dominates; the full-scale
        // bench checks the paper's ~66%.
        assert!(u > 0.01, "paid nodes barely used: {u}");
    }

    #[test]
    fn paper_site_ladder_shape() {
        let two = RunConfig::paper_site_specs(2);
        assert_eq!(two.len(), 2);
        assert_eq!(two[0].name, "CESNET-MCC");
        assert_eq!(two[1].name, "AWS");
        let five = RunConfig::paper_site_specs(5);
        assert_eq!(five.len(), 5);
        assert_eq!(five[2].name, "AWS-spot");
        assert_eq!(five[4].name, "ON-4");
        let cfg = RunConfig::paper_usecase_sites(0.01, 1, 4);
        assert_eq!(cfg.sites.len(), 4);
        // SLAs stay the paper pair; extra sites rank by default rules.
        assert_eq!(cfg.slas.len(), 2);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;

    #[test]
    fn nonhybrid_engine_drains() {
        let mut cfg = RunConfig::paper_usecase(0.05, 42);
        cfg.template.hybrid = false;
        cfg.inference_every = 0;
        let HybridCluster { mut control, mut sites } =
            HybridCluster::new(cfg).unwrap();
        let mut q: ShardedQueue<Ev> = ShardedQueue::new(sites.len());
        q.schedule_at(SimTime::ZERO, Ev::Deploy);
        run_sharded_serial(&mut control, &mut sites, &mut q,
                           SimTime::from_hms(47, 0, 0));
        let updates = control.engine.updates();
        let stuck: Vec<_> = updates.iter()
            .filter(|u| !matches!(u.state,
                crate::orchestrator::UpdateState::Done
                | crate::orchestrator::UpdateState::Cancelled))
            .collect();
        assert!(stuck.is_empty(),
            "stuck updates: {:#?}\nnodes: {:?}\nin_progress: {}",
            stuck, control.nodes.keys().collect::<Vec<_>>(),
            control.engine.in_progress());
    }
}
