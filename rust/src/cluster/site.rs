//! The site-local half of the cluster world: one [`SiteWorld`] per
//! cloud site, replayed on that site's event shard.
//!
//! A site handler owns its [`CloudSite`] (VM lifecycle, ledger,
//! pricing, networks), the in-flight boot/contextualization timers for
//! VMs at the site, the job-execution timers of jobs running on its
//! nodes, the completed-run report buffer, and a per-shard
//! [`Recorder`]. It touches nothing else — the shared [`NodeNames`]
//! interner is only ever *read* here (ids are interned at the control
//! plane, so the dense id space never depends on site-thread
//! interleaving) — and it reaches the control plane exclusively
//! through [`SiteCtx::emit_control_in`] with the configured
//! control-latency delay. That pair of rules is what makes windows of
//! site events safe to replay in parallel and byte-identical across
//! the serial/sharded/stealing engines.
//!
//! Every site → control message additionally crosses the WAN chaos
//! layer ([`SiteFaultState`]): the fault decision for each message is
//! drawn from a stream keyed by `(site, seq)`, where `seq` advances in
//! shard-local order — so Serial, Sharded and Stealing replays drop,
//! duplicate and delay exactly the same messages. Reports (boot
//! failures, joins, losses, power-offs, job batches) are *reliable*:
//! when the layer drops one, the site schedules a local ack-timeout
//! retransmission with exponential backoff. Heartbeat responses are
//! *unreliable* by design — their loss is the control plane's
//! silent-site detection signal.

use crate::cloudsim::CloudSite;
use crate::ids::{NodeId, NodeNames};
use crate::metrics::{DisplayState, Recorder};
use crate::obs::TraceShard;
use crate::sim::shard::{SiteCtx, SiteShard};
use crate::sim::SimTime;

use super::dispatch::SiteSched;
use super::faults::{Delivery, SiteFaultState};
use super::{Ev, JobRun};

/// Short label of a reliable report for chaos trace instants.
fn report_kind(ev: &Ev) -> &'static str {
    match ev {
        Ev::NodeReady { .. } => "node-ready",
        Ev::BootFailed { .. } => "boot-failed",
        Ev::NodeLost { .. } => "node-lost",
        Ev::NodeOff { .. } => "node-off",
        Ev::JobBatch { .. } => "job-batch",
        Ev::SiteHeartbeat { .. } => "heartbeat",
        Ev::SiteJobReport { .. } => "job-report",
        _ => "other",
    }
}

/// Retransmission attempts per message before the site gives up (the
/// validated fault plans — sub-total steady loss, finite partition
/// windows — make reaching this bound astronomically unlikely; it only
/// guards against unbounded event storms).
const MAX_RETRANSMITS: u32 = 64;

/// Everything site-local, replayed on the site's own shard.
pub struct SiteWorld {
    pub(crate) site: usize,
    /// The IaaS site itself: VMs, ledger, pricing, networks.
    pub cloud: CloudSite,
    /// This shard's metrics stream (merged with the control shard and
    /// its site peers at run end).
    pub(crate) recorder: Recorder,
    /// Shared interner handle — read-only on the site side.
    names: NodeNames,
    /// Completed runs the controller has not been told about yet.
    done_buf: Vec<JobRun>,
    /// A `FlushTimer` is already scheduled for `done_buf`.
    flush_scheduled: bool,
    /// Site→control notification latency (the engine lookahead).
    control_latency: f64,
    /// Completed-run report grid, seconds (≤ 0 = report immediately).
    report_grid: f64,
    /// The WAN chaos layer for this site's control channel.
    pub(crate) faults: SiteFaultState,
    /// This shard's causal trace buffer (shard `site + 1`; merged with
    /// the control shard's at run end). Passive — see `crate::obs`.
    pub(crate) trace: TraceShard,
    /// Partitioned dispatch only: this site's local scheduler slice
    /// (`None` in centralized mode). It places leased jobs onto local
    /// nodes during the site's parallel window; starts, completions
    /// and spillover reach the control plane exclusively through the
    /// batched [`Ev::SiteJobReport`] barrier emission.
    pub(crate) sched: Option<SiteSched>,
}

impl SiteWorld {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(site: usize, cloud: CloudSite, recorder: Recorder,
                      names: NodeNames, control_latency: f64,
                      report_grid: f64, faults: SiteFaultState,
                      trace: TraceShard, sched: Option<SiteSched>)
        -> SiteWorld {
        SiteWorld {
            site,
            cloud,
            recorder,
            names,
            done_buf: Vec::new(),
            flush_scheduled: false,
            control_latency,
            report_grid,
            faults,
            trace,
            sched,
        }
    }

    /// Take the shard recorder out for merging (report assembly).
    pub(crate) fn take_recorder(&mut self) -> Recorder {
        std::mem::take(&mut self.recorder)
    }

    /// Take the trace shard out for merging (report assembly).
    pub(crate) fn take_trace(&mut self) -> TraceShard {
        std::mem::replace(&mut self.trace,
                          TraceShard::off((self.site + 1) as u32))
    }

    /// The next completed-run flush instant for a completion at `t`:
    /// the next strict multiple of the report grid (so a burst of
    /// completions in one grid slot becomes one controller report), or
    /// `t` itself when batching is disabled.
    fn next_flush_at(&self, t: f64) -> f64 {
        if self.report_grid <= 0.0 {
            return t;
        }
        ((t / self.report_grid).floor() + 1.0) * self.report_grid
    }

    /// Make sure a [`Ev::FlushTimer`] is pending to carry whatever the
    /// site has buffered (completed-run batches, partitioned job
    /// reports) at the next report-grid slot.
    fn ensure_flush(&mut self, t: SimTime, ctx: &mut SiteCtx<'_, Ev>) {
        if !self.flush_scheduled {
            self.flush_scheduled = true;
            ctx.schedule_at(SimTime(self.next_flush_at(t.0)),
                            Ev::FlushTimer { site: self.site });
        }
    }

    /// Partitioned dispatch: one local scheduling sweep. Places what
    /// fits (starting the completion timers), spills the backlog the
    /// site can no longer hold, and makes sure a flush will carry the
    /// buffered start/completion/spill reports to the control plane.
    fn sweep_local(&mut self, t: SimTime, ctx: &mut SiteCtx<'_, Ev>) {
        let site = self.site;
        let Some(sched) = self.sched.as_mut() else { return };
        let starts = sched.sweep(t);
        let _ = sched.spill_excess(t);
        let has_reports = sched.has_reports();
        for (node, job, gen, secs) in starts {
            ctx.schedule_in(secs, Ev::JobTimer { site, job, node, gen });
        }
        if has_reports {
            self.ensure_flush(t, ctx);
        }
    }

    /// Send a *reliable* report to the control plane through the fault
    /// layer. Dropped messages are retransmitted after an ack-timeout
    /// backoff; `attempt` counts prior transmissions of this message.
    fn send_control(&mut self, ctx: &mut SiteCtx<'_, Ev>, t: SimTime,
                    ev: Ev, attempt: u32) {
        match self.faults.decide(t) {
            Delivery::Drop => {
                if self.trace.enabled() {
                    self.trace.instant(t, "chaos", "wan.drop", format!(
                        "site={} report={} attempt={attempt}",
                        self.site, report_kind(&ev)));
                }
                if attempt >= MAX_RETRANSMITS {
                    self.recorder.milestone(t, format!(
                        "site {} gave up retransmitting a report after \
                         {attempt} attempts", self.site));
                    return;
                }
                let delay = self.faults.retransmit_backoff(attempt);
                if self.trace.enabled() {
                    self.trace.instant(
                        t, "chaos", "wan.retransmit", format!(
                            "site={} report={} attempt={} backoff_s={}",
                            self.site, report_kind(&ev), attempt + 1,
                            delay));
                }
                ctx.schedule_in(delay, Ev::Retransmit {
                    site: self.site,
                    ev: Box::new(ev),
                    attempt: attempt + 1,
                });
            }
            Delivery::Deliver { extra_delay, duplicate } => {
                if self.trace.enabled() {
                    if let Some(dup_delay) = duplicate {
                        self.trace.instant(
                            t, "chaos", "wan.duplicate", format!(
                                "site={} report={} dup_delay_s={}",
                                self.site, report_kind(&ev), dup_delay));
                    }
                }
                match duplicate {
                    Some(dup_delay) => {
                        ctx.emit_control_in(
                            self.control_latency + extra_delay,
                            ev.clone());
                        ctx.emit_control_in(
                            self.control_latency + dup_delay, ev);
                    }
                    None => ctx.emit_control_in(
                        self.control_latency + extra_delay, ev),
                }
            }
        }
    }

    /// Send an *unreliable* message (heartbeat responses): a drop is
    /// simply a drop — no retransmission, the loss is the signal.
    fn send_control_unreliable(&mut self, ctx: &mut SiteCtx<'_, Ev>,
                               t: SimTime, ev: Ev) {
        match self.faults.decide(t) {
            Delivery::Drop => {
                if self.trace.enabled() {
                    self.trace.instant(t, "chaos", "wan.drop", format!(
                        "site={} report={} unreliable",
                        self.site, report_kind(&ev)));
                }
            }
            Delivery::Deliver { extra_delay, duplicate } => {
                if self.trace.enabled() {
                    if let Some(dup_delay) = duplicate {
                        self.trace.instant(
                            t, "chaos", "wan.duplicate", format!(
                                "site={} report={} dup_delay_s={}",
                                self.site, report_kind(&ev), dup_delay));
                    }
                }
                match duplicate {
                    Some(dup_delay) => {
                        ctx.emit_control_in(
                            self.control_latency + extra_delay,
                            ev.clone());
                        ctx.emit_control_in(
                            self.control_latency + dup_delay, ev);
                    }
                    None => ctx.emit_control_in(
                        self.control_latency + extra_delay, ev),
                }
            }
        }
    }
}

impl AsRef<CloudSite> for SiteWorld {
    fn as_ref(&self) -> &CloudSite {
        &self.cloud
    }
}

impl SiteShard for SiteWorld {
    type Event = Ev;

    fn handle(&mut self, t: SimTime, ev: Ev, ctx: &mut SiteCtx<'_, Ev>) {
        match ev {
            Ev::BootDone { vm, node, failed, ctx_secs, .. } => {
                // The VM may have been reclaimed (scenario wave /
                // outage) while still booting — then it is already
                // Failed and there is nothing left to complete.
                if self.cloud.complete_boot(vm, failed, t).is_err() {
                    return;
                }
                if failed {
                    self.recorder.node_state_id(t, node,
                                                DisplayState::Failed);
                    self.recorder.milestone(t, format!(
                        "{} failed to boot", self.names.name(node)));
                    let site = self.site;
                    self.send_control(ctx, t, Ev::BootFailed {
                        site,
                        vm,
                        node,
                    }, 0);
                    return;
                }
                // Contextualization starts now (Ansible over the SSH
                // reverse tunnel fabric).
                ctx.schedule_in(ctx_secs, Ev::CtxTimer {
                    site: self.site,
                    vm,
                    node,
                });
            }

            Ev::CtxTimer { vm, node, .. } => {
                // The node is configured; the controller hears about
                // the join one WAN notification later.
                let site = self.site;
                self.send_control(ctx, t, Ev::NodeReady {
                    site,
                    vm,
                    node,
                }, 0);
            }

            Ev::JobTimer { job, node, gen, .. } => {
                // Partitioned: `job`/`gen` are the local slice's id and
                // execution seq. A stale timer (the execution was
                // requeued away by a node loss) is dropped inside
                // `finish`; a real completion buffers its report and
                // frees a slot, so sweep immediately.
                if let Some(sched) = self.sched.as_mut() {
                    if sched.finish(job, node, gen, t) {
                        self.sweep_local(t, ctx);
                        self.ensure_flush(t, ctx);
                    }
                    return;
                }
                self.done_buf.push(JobRun { job, node, gen });
                self.ensure_flush(t, ctx);
            }

            Ev::FlushTimer { .. } => {
                self.flush_scheduled = false;
                if let Some(sched) = self.sched.as_mut() {
                    if sched.has_reports() {
                        let (started, done, spilled) =
                            sched.take_reports();
                        let site = self.site;
                        self.send_control(ctx, t, Ev::SiteJobReport {
                            site,
                            started,
                            done,
                            spilled,
                        }, 0);
                    }
                    return;
                }
                if self.done_buf.is_empty() {
                    return;
                }
                let done = std::mem::take(&mut self.done_buf);
                let site = self.site;
                self.send_control(ctx, t, Ev::JobBatch {
                    site,
                    done,
                }, 0);
            }

            Ev::CrashTimer { vm, node, preempt, .. } => {
                // Stale unless this exact VM incarnation is still
                // alive: crash_vm rejects Terminating/Terminated/Failed
                // states, which is precisely the "already replaced or
                // decommissioning" filter.
                if self.cloud.crash_vm(vm, t).is_err() {
                    return;
                }
                let name = self.names.name(node);
                self.recorder.node_state_id(t, node, DisplayState::Failed);
                self.recorder.milestone(t, if preempt {
                    format!("{name} preempted (spot capacity reclaimed)")
                } else {
                    format!("{name} crashed (provider-side failure)")
                });
                let site = self.site;
                self.send_control(ctx, t, Ev::NodeLost {
                    site,
                    vm,
                    node,
                    preempted: preempt,
                }, 0);
                // Partitioned: the slice loses the node now — running
                // jobs requeue locally (fresh seq on restart) and the
                // shrunken capacity spills its excess backlog.
                if let Some(sched) = self.sched.as_mut() {
                    sched.deregister(node, t);
                    self.sweep_local(t, ctx);
                }
            }

            Ev::TerminationDone { vm, node, update, .. } => {
                let _ = self.cloud.complete_termination(vm, t);
                self.recorder.node_state_id(t, node, DisplayState::Off);
                self.recorder.milestone(t, format!(
                    "{} powered off", self.names.name(node)));
                let site = self.site;
                self.send_control(ctx, t, Ev::NodeOff {
                    site,
                    vm,
                    node,
                    update,
                }, 0);
                // Partitioned: jobs placed on the node between the
                // power-off decision and the termination requeue
                // locally, then re-place or spill.
                if let Some(sched) = self.sched.as_mut() {
                    sched.deregister(node, t);
                    self.sweep_local(t, ctx);
                }
            }

            Ev::HeartbeatPing { .. } => {
                // Answer the control plane's liveness probe. The reply
                // is unreliable on purpose: a lost answer is exactly
                // the missed-heartbeat signal the circuit breaker
                // counts. (The inbound ping itself crossed the same
                // WAN; the control plane models its loss through the
                // reply's fault decision — one draw covers the round
                // trip.)
                let site = self.site;
                self.send_control_unreliable(
                    ctx, t, Ev::SiteHeartbeat { site });
            }

            Ev::Retransmit { ev, attempt, .. } => {
                // Ack timeout expired for a dropped report: try again.
                // The retransmission consumes a fresh `(site, seq)`
                // fault decision, so its fate is decorrelated from the
                // original's.
                self.send_control(ctx, t, *ev, attempt);
            }

            Ev::JobBlock { jobs, .. } => {
                // Partitioned dispatch: a routed block of leased jobs
                // joins the local queue; place what fits right away.
                if let Some(sched) = self.sched.as_mut() {
                    sched.submit_block(&jobs, t);
                }
                self.sweep_local(t, ctx);
            }

            Ev::SiteNodeUp { node, slots, .. } => {
                // Partitioned dispatch: a freshly joined worker is
                // granted to the local slice (a new incarnation — it
                // pays the one-time setup again).
                if let Some(sched) = self.sched.as_mut() {
                    sched.grant(node, slots, t);
                }
                self.sweep_local(t, ctx);
            }

            // Control-shard events never reach a site handler.
            _ => unreachable!("control event routed to site shard"),
        }
    }
}
