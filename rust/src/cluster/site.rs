//! The site-local half of the cluster world: one [`SiteWorld`] per
//! cloud site, replayed on that site's event shard.
//!
//! A site handler owns its [`CloudSite`] (VM lifecycle, ledger,
//! pricing, networks), the in-flight boot/contextualization timers for
//! VMs at the site, the job-execution timers of jobs running on its
//! nodes, the completed-run report buffer, and a per-shard
//! [`Recorder`]. It touches nothing else — the shared [`NodeNames`]
//! interner is only ever *read* here (ids are interned at the control
//! plane, so the dense id space never depends on site-thread
//! interleaving) — and it reaches the control plane exclusively
//! through [`SiteCtx::emit_control_in`] with the configured
//! control-latency delay. That pair of rules is what makes windows of
//! site events safe to replay in parallel and byte-identical across
//! the serial/sharded/stealing engines.

use crate::cloudsim::CloudSite;
use crate::ids::{NodeId, NodeNames};
use crate::metrics::{DisplayState, Recorder};
use crate::sim::shard::{SiteCtx, SiteShard};
use crate::sim::SimTime;

use super::{Ev, JobRun};

/// Everything site-local, replayed on the site's own shard.
pub struct SiteWorld {
    pub(crate) site: usize,
    /// The IaaS site itself: VMs, ledger, pricing, networks.
    pub cloud: CloudSite,
    /// This shard's metrics stream (merged with the control shard and
    /// its site peers at run end).
    pub(crate) recorder: Recorder,
    /// Shared interner handle — read-only on the site side.
    names: NodeNames,
    /// Completed runs the controller has not been told about yet.
    done_buf: Vec<JobRun>,
    /// A `FlushTimer` is already scheduled for `done_buf`.
    flush_scheduled: bool,
    /// Site→control notification latency (the engine lookahead).
    control_latency: f64,
    /// Completed-run report grid, seconds (≤ 0 = report immediately).
    report_grid: f64,
}

impl SiteWorld {
    pub(crate) fn new(site: usize, cloud: CloudSite, recorder: Recorder,
                      names: NodeNames, control_latency: f64,
                      report_grid: f64) -> SiteWorld {
        SiteWorld {
            site,
            cloud,
            recorder,
            names,
            done_buf: Vec::new(),
            flush_scheduled: false,
            control_latency,
            report_grid,
        }
    }

    /// Take the shard recorder out for merging (report assembly).
    pub(crate) fn take_recorder(&mut self) -> Recorder {
        std::mem::take(&mut self.recorder)
    }

    /// The next completed-run flush instant for a completion at `t`:
    /// the next strict multiple of the report grid (so a burst of
    /// completions in one grid slot becomes one controller report), or
    /// `t` itself when batching is disabled.
    fn next_flush_at(&self, t: f64) -> f64 {
        if self.report_grid <= 0.0 {
            return t;
        }
        ((t / self.report_grid).floor() + 1.0) * self.report_grid
    }
}

impl AsRef<CloudSite> for SiteWorld {
    fn as_ref(&self) -> &CloudSite {
        &self.cloud
    }
}

impl SiteShard for SiteWorld {
    type Event = Ev;

    fn handle(&mut self, t: SimTime, ev: Ev, ctx: &mut SiteCtx<'_, Ev>) {
        match ev {
            Ev::BootDone { vm, node, failed, ctx_secs, .. } => {
                // The VM may have been reclaimed (scenario wave /
                // outage) while still booting — then it is already
                // Failed and there is nothing left to complete.
                if self.cloud.complete_boot(vm, failed, t).is_err() {
                    return;
                }
                if failed {
                    self.recorder.node_state_id(t, node,
                                                DisplayState::Failed);
                    self.recorder.milestone(t, format!(
                        "{} failed to boot", self.names.name(node)));
                    ctx.emit_control_in(self.control_latency,
                                        Ev::BootFailed {
                                            site: self.site,
                                            vm,
                                            node,
                                        });
                    return;
                }
                // Contextualization starts now (Ansible over the SSH
                // reverse tunnel fabric).
                ctx.schedule_in(ctx_secs, Ev::CtxTimer {
                    site: self.site,
                    vm,
                    node,
                });
            }

            Ev::CtxTimer { vm, node, .. } => {
                // The node is configured; the controller hears about
                // the join one WAN notification later.
                ctx.emit_control_in(self.control_latency, Ev::NodeReady {
                    site: self.site,
                    vm,
                    node,
                });
            }

            Ev::JobTimer { job, node, gen, .. } => {
                self.done_buf.push(JobRun { job, node, gen });
                if !self.flush_scheduled {
                    self.flush_scheduled = true;
                    ctx.schedule_at(SimTime(self.next_flush_at(t.0)),
                                    Ev::FlushTimer { site: self.site });
                }
            }

            Ev::FlushTimer { .. } => {
                self.flush_scheduled = false;
                if self.done_buf.is_empty() {
                    return;
                }
                let done = std::mem::take(&mut self.done_buf);
                ctx.emit_control_in(self.control_latency, Ev::JobBatch {
                    site: self.site,
                    done,
                });
            }

            Ev::CrashTimer { vm, node, preempt, .. } => {
                // Stale unless this exact VM incarnation is still
                // alive: crash_vm rejects Terminating/Terminated/Failed
                // states, which is precisely the "already replaced or
                // decommissioning" filter.
                if self.cloud.crash_vm(vm, t).is_err() {
                    return;
                }
                let name = self.names.name(node);
                self.recorder.node_state_id(t, node, DisplayState::Failed);
                self.recorder.milestone(t, if preempt {
                    format!("{name} preempted (spot capacity reclaimed)")
                } else {
                    format!("{name} crashed (provider-side failure)")
                });
                ctx.emit_control_in(self.control_latency, Ev::NodeLost {
                    site: self.site,
                    vm,
                    node,
                    preempted: preempt,
                });
            }

            Ev::TerminationDone { vm, node, update, .. } => {
                let _ = self.cloud.complete_termination(vm, t);
                self.recorder.node_state_id(t, node, DisplayState::Off);
                self.recorder.milestone(t, format!(
                    "{} powered off", self.names.name(node)));
                ctx.emit_control_in(self.control_latency, Ev::NodeOff {
                    site: self.site,
                    vm,
                    node,
                    update,
                });
            }

            // Control-shard events never reach a site handler.
            _ => unreachable!("control event routed to site shard"),
        }
    }
}
