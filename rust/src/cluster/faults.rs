//! Deterministic WAN chaos layer for the control ↔ site boundary.
//!
//! PR 5 split the cluster world into site shards that talk to the
//! control plane over a perfectly reliable fixed-latency channel
//! (`control_latency_s`). Real hybrid clusters do not get that luxury:
//! the paper's vnode-5 incident — a healthy node falsely reported down
//! and power-cycled — is a WAN artifact, not a node fault. This module
//! provides the machinery to reproduce that class of failure *and* the
//! self-healing that recovers from it, without giving up the bit-exact
//! replay contract:
//!
//! - [`WanFaultPlan`]: a scripted, t0-relative plan (like
//!   `ScenarioPlan`) of fault windows injecting message **loss**,
//!   **duplication**, **delay jitter** and full **partitions** onto the
//!   site → control reporting channel and the heartbeat path. Plans
//!   also carry correlated [`RegionGroup`]s — one regional-backbone
//!   outage window cutting several sites off at once — which expand
//!   into ordinary per-site partition windows at resolution time, so
//!   the per-`(site, seq)` decision streams (and with them cross-engine
//!   byte-identity) are untouched by correlation.
//! - [`SiteFaultState`]: the per-site runtime. Every message crossing
//!   the boundary consumes one sequence number, and the fault decision
//!   for it is drawn from a dedicated [`Prng`] stream keyed by
//!   `(site, seq)` — independent of engine interleaving, so Serial,
//!   Sharded and Stealing replays see *identical* faults.
//! - [`RetryPolicy`]: bounded-attempt exponential backoff with
//!   deterministic jitter for provisioning retries and site failover.
//! - [`SiteHealthTracker`]: the control-side circuit breaker (closed →
//!   open → half-open) that quarantines a site after K consecutive
//!   missed heartbeats.
//!
//! Droppable messages are modelled as a *reliable* channel with ack
//! timeouts: when the fault layer drops a report, the sending site
//! schedules a local retransmission after [`SiteFaultState::retransmit_backoff`]
//! — exponential in the attempt count, seeded from the spec's
//! `ack_timeout_s`. Heartbeat responses are deliberately *unreliable*:
//! their loss is the detection signal the circuit breaker feeds on.

use crate::ids::SiteNames;
use crate::sim::SimTime;
use crate::util::prng::Prng;

// ---------------------------------------------------------------------
// Scripted plan
// ---------------------------------------------------------------------

/// One scripted fault window over a single site's WAN path. Times are
/// relative to workload start (t0), like `ScenarioEvent`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWindow {
    /// Broker index of the affected site.
    pub site: usize,
    /// Window start, seconds after workload t0.
    pub at: SimTime,
    /// Window length, seconds (must be finite and > 0).
    pub duration_secs: f64,
    /// Per-message loss probability added while the window is active.
    /// Must stay below 1.0 — use `partition` for total loss.
    pub loss: f64,
    /// Per-message duplication probability while active.
    pub dup: f64,
    /// Extra one-way delay drawn uniformly from `[0, jitter_s)`.
    pub jitter_s: f64,
    /// Total partition: every message in the window is dropped.
    pub partition: bool,
}

/// A correlated regional fault: one scripted backbone-outage window
/// that partitions several sites at once (times t0-relative, like
/// [`FaultWindow`]). Region groups are pure plan-level sugar — at
/// resolution time each member site gets an ordinary partition window,
/// so the per-`(site, seq)` fault streams never see the correlation.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionGroup {
    /// Broker indices of every site behind the failing backbone.
    pub sites: Vec<usize>,
    /// Outage start, seconds after workload t0.
    pub at: SimTime,
    /// Outage length, seconds (must be finite and > 0).
    pub duration_secs: f64,
}

/// A scripted WAN fault plan: a seed for the per-message decision
/// streams plus any number of [`FaultWindow`]s and correlated
/// [`RegionGroup`]s. Empty plans are free — the fault layer stays
/// inert and runs keep their pre-chaos digests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WanFaultPlan {
    /// Mixed with the run seed to key the per-`(site, seq)` streams.
    pub seed: u64,
    pub windows: Vec<FaultWindow>,
    /// Correlated regional outages, expanded into per-site partition
    /// windows by [`WanFaultPlan::expanded_windows`].
    pub regions: Vec<RegionGroup>,
}

impl WanFaultPlan {
    pub fn new(seed: u64) -> WanFaultPlan {
        WanFaultPlan { seed, windows: Vec::new(), regions: Vec::new() }
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty() && self.regions.is_empty()
    }

    /// Steady loss window: drop each message with probability `loss`.
    pub fn lossy(mut self, site: usize, at_secs: f64, duration_secs: f64,
                 loss: f64) -> WanFaultPlan {
        self.windows.push(FaultWindow {
            site,
            at: SimTime(at_secs),
            duration_secs,
            loss,
            dup: 0.0,
            jitter_s: 0.0,
            partition: false,
        });
        self
    }

    /// Duplication window: deliver each message, then with probability
    /// `dup` deliver it a second time.
    pub fn duplicating(mut self, site: usize, at_secs: f64,
                       duration_secs: f64, dup: f64) -> WanFaultPlan {
        self.windows.push(FaultWindow {
            site,
            at: SimTime(at_secs),
            duration_secs,
            loss: 0.0,
            dup,
            jitter_s: 0.0,
            partition: false,
        });
        self
    }

    /// Jitter window: add a uniform `[0, jitter_s)` delay per message.
    pub fn jittery(mut self, site: usize, at_secs: f64, duration_secs: f64,
                   jitter_s: f64) -> WanFaultPlan {
        self.windows.push(FaultWindow {
            site,
            at: SimTime(at_secs),
            duration_secs,
            loss: 0.0,
            dup: 0.0,
            jitter_s,
            partition: false,
        });
        self
    }

    /// Total partition window: the site is unreachable for the
    /// duration. Also fails the site's vRouter on the overlay and is
    /// reflected in broker placement for the window.
    pub fn partition(mut self, site: usize, at_secs: f64,
                     duration_secs: f64) -> WanFaultPlan {
        self.windows.push(FaultWindow {
            site,
            at: SimTime(at_secs),
            duration_secs,
            loss: 1.0,
            dup: 0.0,
            jitter_s: 0.0,
            partition: true,
        });
        self
    }

    /// Fully general window.
    pub fn window(mut self, w: FaultWindow) -> WanFaultPlan {
        self.windows.push(w);
        self
    }

    /// Correlated regional outage: one backbone-failure window cutting
    /// every listed site off for the duration.
    pub fn regional_outage(mut self, sites: &[usize], at_secs: f64,
                           duration_secs: f64) -> WanFaultPlan {
        self.regions.push(RegionGroup {
            sites: sites.to_vec(),
            at: SimTime(at_secs),
            duration_secs,
        });
        self
    }

    /// Every scripted window with the correlated region groups expanded
    /// into one partition window per member site — plan windows first,
    /// then groups in plan order with member sites in listed order, so
    /// the expansion is deterministic and per-site resolution (hence the
    /// `(site, seq)` stream keying) never sees the correlation.
    pub fn expanded_windows(&self) -> Vec<FaultWindow> {
        let mut out = self.windows.clone();
        for g in &self.regions {
            for &site in &g.sites {
                out.push(FaultWindow {
                    site,
                    at: g.at,
                    duration_secs: g.duration_secs,
                    loss: 1.0,
                    dup: 0.0,
                    jitter_s: 0.0,
                    partition: true,
                });
            }
        }
        out
    }

    /// Build-time sanity: every window must target an existing site
    /// with finite times and sub-total loss (partitions excepted), and
    /// every region group must list at least one distinct in-range
    /// site. Front-end targeting can only be checked once the front
    /// end is placed — `ControlWorld::begin_workload` does that part.
    /// Errors name the offending site through the provided interner
    /// (ids in site-vector order; unknown ids render as `site#N`).
    pub fn validate_named(&self, n_sites: usize, names: &SiteNames)
        -> anyhow::Result<()> {
        let site_name = |s: usize| names.name(crate::ids::SiteId(s as u32));
        let roster = || -> String {
            (0..n_sites)
                .map(&site_name)
                .collect::<Vec<_>>()
                .join(", ")
        };
        for (i, w) in self.windows.iter().enumerate() {
            if w.site >= n_sites {
                anyhow::bail!(
                    "fault window {i} targets site {} but the world has \
                     only {n_sites} sites ({})", w.site, roster());
            }
            let name = site_name(w.site);
            if !w.at.0.is_finite() || w.at.0 < 0.0 {
                anyhow::bail!(
                    "fault window {i} on site {} ({name}): start {} must \
                     be a finite non-negative offset", w.site, w.at.0);
            }
            if !w.duration_secs.is_finite() || w.duration_secs <= 0.0 {
                anyhow::bail!(
                    "fault window {i} on site {} ({name}): duration {} \
                     must be finite and positive", w.site, w.duration_secs);
            }
            if !(0.0..=1.0).contains(&w.loss)
                || (!w.partition && w.loss >= 1.0)
            {
                anyhow::bail!(
                    "fault window {i} on site {} ({name}): loss {} must \
                     be in [0, 1) — use a partition window for total \
                     loss", w.site, w.loss);
            }
            if !(0.0..1.0).contains(&w.dup) {
                anyhow::bail!(
                    "fault window {i} on site {} ({name}): dup {} must \
                     be in [0, 1)", w.site, w.dup);
            }
            if !w.jitter_s.is_finite() || w.jitter_s < 0.0 {
                anyhow::bail!(
                    "fault window {i} on site {} ({name}): jitter {} \
                     must be finite and non-negative", w.site, w.jitter_s);
            }
        }
        for (i, g) in self.regions.iter().enumerate() {
            if g.sites.is_empty() {
                anyhow::bail!(
                    "regional outage {i} lists no member sites");
            }
            for (j, &s) in g.sites.iter().enumerate() {
                if s >= n_sites {
                    anyhow::bail!(
                        "regional outage {i} targets site {s} but the \
                         world has only {n_sites} sites ({})", roster());
                }
                if g.sites[..j].contains(&s) {
                    anyhow::bail!(
                        "regional outage {i} lists site {s} ({}) twice",
                        site_name(s));
                }
            }
            if !g.at.0.is_finite() || g.at.0 < 0.0 {
                anyhow::bail!(
                    "regional outage {i}: start {} must be a finite \
                     non-negative offset", g.at.0);
            }
            if !g.duration_secs.is_finite() || g.duration_secs <= 0.0 {
                anyhow::bail!(
                    "regional outage {i}: duration {} must be finite \
                     and positive", g.duration_secs);
            }
        }
        Ok(())
    }

    /// [`validate_named`](Self::validate_named) with no interner: site
    /// names render as the `site#N` placeholder.
    pub fn validate(&self, n_sites: usize) -> anyhow::Result<()> {
        self.validate_named(n_sites, &SiteNames::new())
    }
}

// ---------------------------------------------------------------------
// Retry policy
// ---------------------------------------------------------------------

/// Bounded-attempt exponential backoff with deterministic jitter, used
/// by the control plane to re-provision after `BootFailed` and to pick
/// when a node fails over to the next broker-ranked site.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Give up on a node after this many provisioning attempts.
    pub max_attempts: u32,
    /// First backoff, seconds; doubles per attempt.
    pub base_backoff_s: f64,
    /// Backoff ceiling, seconds.
    pub max_backoff_s: f64,
    /// Symmetric jitter as a fraction of the capped backoff.
    pub jitter_frac: f64,
    /// After this many failed attempts the original site is excluded
    /// from placement and the broker ranks the remaining sites.
    pub failover_after: u32,
    /// Consecutive missed heartbeats before a site is quarantined.
    pub quarantine_after: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_backoff_s: 30.0,
            max_backoff_s: 480.0,
            jitter_frac: 0.2,
            failover_after: 2,
            quarantine_after: 3,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): capped
    /// exponential plus `±jitter_frac` deterministic jitter, floored at
    /// one second so retries never collapse onto the failure instant.
    pub fn backoff(&self, attempt: u32, rng: &mut Prng) -> f64 {
        let exp = self.base_backoff_s * (1u64 << attempt.min(16)) as f64;
        let capped = exp.min(self.max_backoff_s);
        let jitter = if self.jitter_frac > 0.0 {
            capped * self.jitter_frac * (2.0 * rng.next_f64() - 1.0)
        } else {
            0.0
        };
        (capped + jitter).max(1.0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.max_attempts == 0 {
            anyhow::bail!("retry policy: max_attempts must be >= 1");
        }
        if !self.base_backoff_s.is_finite() || self.base_backoff_s <= 0.0 {
            anyhow::bail!("retry policy: base_backoff_s must be finite \
                           and positive");
        }
        if !self.max_backoff_s.is_finite()
            || self.max_backoff_s < self.base_backoff_s
        {
            anyhow::bail!("retry policy: max_backoff_s must be finite \
                           and >= base_backoff_s");
        }
        if !(0.0..1.0).contains(&self.jitter_frac) {
            anyhow::bail!("retry policy: jitter_frac must be in [0, 1)");
        }
        if self.quarantine_after == 0 {
            anyhow::bail!("retry policy: quarantine_after must be >= 1");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Circuit breaker
// ---------------------------------------------------------------------

/// Circuit-breaker state for one site, driven by heartbeat outcomes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Site healthy; heartbeats answered.
    Closed,
    /// Site quarantined after K consecutive misses.
    Open,
    /// First post-quarantine report seen; one more confirms recovery.
    HalfOpen,
}

/// Per-site missed-heartbeat tracker. `miss()` returns true exactly
/// when the breaker trips open (quarantine should start); `report()`
/// returns true exactly when it re-closes (quarantine should lift).
#[derive(Debug, Clone)]
pub struct SiteHealthTracker {
    threshold: u32,
    missed: u32,
    state: BreakerState,
}

impl SiteHealthTracker {
    pub fn new(threshold: u32) -> SiteHealthTracker {
        SiteHealthTracker {
            threshold: threshold.max(1),
            missed: 0,
            state: BreakerState::Closed,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// A heartbeat went unanswered for a full poll period.
    pub fn miss(&mut self) -> bool {
        match self.state {
            BreakerState::Closed => {
                self.missed += 1;
                if self.missed >= self.threshold {
                    self.state = BreakerState::Open;
                    return true;
                }
                false
            }
            BreakerState::HalfOpen => {
                // The probe that half-opened us was a fluke; re-open
                // without starting a new quarantine window.
                self.state = BreakerState::Open;
                false
            }
            BreakerState::Open => false,
        }
    }

    /// Any message from the site arrived at the control plane.
    pub fn report(&mut self) -> bool {
        match self.state {
            BreakerState::Closed => {
                self.missed = 0;
                false
            }
            BreakerState::Open => {
                self.state = BreakerState::HalfOpen;
                false
            }
            BreakerState::HalfOpen => {
                self.state = BreakerState::Closed;
                self.missed = 0;
                true
            }
        }
    }
}

// ---------------------------------------------------------------------
// Per-site runtime
// ---------------------------------------------------------------------

/// A fault window resolved to absolute simulation times, installed into
/// a site shard at workload start.
#[derive(Debug, Clone)]
pub struct ResolvedWindow {
    pub from: f64,
    pub to: f64,
    pub loss: f64,
    pub dup: f64,
    pub jitter_s: f64,
    pub partition: bool,
}

/// Verdict for one site → control message.
#[derive(Debug, Clone, PartialEq)]
pub enum Delivery {
    /// The message is lost on the WAN.
    Drop,
    /// Delivered after `extra_delay` extra seconds; when `duplicate`
    /// is set a second copy lands after that delay too.
    Deliver { extra_delay: f64, duplicate: Option<f64> },
}

/// Per-site fault runtime owned by the site shard, so sequence numbers
/// advance in shard-local (deterministic) order regardless of engine.
#[derive(Debug, Clone)]
pub struct SiteFaultState {
    /// Stream key base: run seed mixed with the plan seed and site.
    stream_seed: u64,
    /// Messages sent so far — the per-message stream discriminator.
    seq: u64,
    /// Spec-level steady loss (`FailureModel::message_loss_prob`).
    steady_loss: f64,
    /// Ack timeout seeding the retransmission backoff.
    ack_timeout_s: f64,
    /// Absolute-time windows, installed at workload start.
    windows: Vec<ResolvedWindow>,
    /// False ⇒ the whole layer is inert (no seq consumption, no RNG).
    pub enabled: bool,
    pub dropped: u64,
    pub duplicated: u64,
    pub retransmits: u64,
}

impl SiteFaultState {
    pub fn new(site: usize, seed: u64, steady_loss: f64,
               ack_timeout_s: f64, enabled: bool) -> SiteFaultState {
        SiteFaultState {
            stream_seed: seed
                ^ (site as u64).wrapping_mul(0xA24BAED4963EE407),
            seq: 0,
            steady_loss,
            ack_timeout_s: if ack_timeout_s > 0.0 {
                ack_timeout_s
            } else {
                120.0
            },
            windows: Vec::new(),
            enabled,
            dropped: 0,
            duplicated: 0,
            retransmits: 0,
        }
    }

    /// Install the absolute-time windows for this site (workload start).
    pub fn install(&mut self, windows: Vec<ResolvedWindow>) {
        self.windows = windows;
    }

    /// Decide the fate of the next outbound message. Consumes one
    /// sequence number per call (when enabled), so the decision stream
    /// is a pure function of `(plan seed, site, seq)` — engine
    /// interleaving cannot perturb it.
    pub fn decide(&mut self, t: SimTime) -> Delivery {
        if !self.enabled {
            return Delivery::Deliver { extra_delay: 0.0, duplicate: None };
        }
        let seq = self.seq;
        self.seq += 1;
        let mut loss = self.steady_loss;
        let mut dup = 0.0;
        let mut jitter = 0.0;
        let mut partition = false;
        for w in &self.windows {
            if t.0 >= w.from && t.0 < w.to {
                if w.partition {
                    partition = true;
                }
                loss = 1.0 - (1.0 - loss) * (1.0 - w.loss);
                dup = 1.0 - (1.0 - dup) * (1.0 - w.dup);
                if w.jitter_s > jitter {
                    jitter = w.jitter_s;
                }
            }
        }
        if partition {
            self.dropped += 1;
            return Delivery::Drop;
        }
        if loss <= 0.0 && dup <= 0.0 && jitter <= 0.0 {
            return Delivery::Deliver { extra_delay: 0.0, duplicate: None };
        }
        let mut rng = Prng::for_stream(
            self.stream_seed ^ seq.wrapping_mul(0x9E3779B97F4A7C15));
        if loss > 0.0 && rng.chance(loss) {
            self.dropped += 1;
            return Delivery::Drop;
        }
        let extra_delay =
            if jitter > 0.0 { rng.next_f64() * jitter } else { 0.0 };
        let duplicate = if dup > 0.0 && rng.chance(dup) {
            self.duplicated += 1;
            Some(if jitter > 0.0 { rng.next_f64() * jitter } else { 0.0 })
        } else {
            None
        };
        Delivery::Deliver { extra_delay, duplicate }
    }

    /// Delay before retransmission number `attempt` (0-based) of a
    /// dropped reliable message: ack timeout doubling per attempt,
    /// capped at 8×. Deterministic — no jitter needed, the decision
    /// stream already decorrelates retransmissions.
    pub fn retransmit_backoff(&mut self, attempt: u32) -> f64 {
        self.retransmits += 1;
        self.ack_timeout_s * (1u64 << attempt.min(3)) as f64
    }

    /// Cumulative `(dropped, duplicated, retransmitted)` counters —
    /// the per-site chaos breakdown the report and the on-clock
    /// metrics series read.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.dropped, self.duplicated, self.retransmits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_doubles_caps_and_floors() {
        let p = RetryPolicy { jitter_frac: 0.0, ..RetryPolicy::default() };
        let mut r = Prng::new(1);
        assert_eq!(p.backoff(0, &mut r), 30.0);
        assert_eq!(p.backoff(1, &mut r), 60.0);
        assert_eq!(p.backoff(2, &mut r), 120.0);
        assert_eq!(p.backoff(3, &mut r), 240.0);
        assert_eq!(p.backoff(4, &mut r), 480.0);
        // Cap holds for arbitrarily late attempts.
        assert_eq!(p.backoff(40, &mut r), 480.0);
    }

    #[test]
    fn backoff_jitter_is_bounded_and_deterministic() {
        let p = RetryPolicy::default();
        let a: Vec<f64> = {
            let mut r = Prng::new(7);
            (0..6).map(|i| p.backoff(i, &mut r)).collect()
        };
        let b: Vec<f64> = {
            let mut r = Prng::new(7);
            (0..6).map(|i| p.backoff(i, &mut r)).collect()
        };
        assert_eq!(a, b);
        for (i, v) in a.iter().enumerate() {
            let base = (30.0 * (1u64 << i) as f64).min(480.0);
            assert!(*v >= base * (1.0 - p.jitter_frac) - 1e-9
                    && *v <= base * (1.0 + p.jitter_frac) + 1e-9,
                    "attempt {i}: {v} vs base {base}");
        }
    }

    #[test]
    fn breaker_closed_open_halfopen_closed() {
        let mut b = SiteHealthTracker::new(3);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(!b.miss());
        assert!(!b.miss());
        // Third consecutive miss trips the breaker open.
        assert!(b.miss());
        assert_eq!(b.state(), BreakerState::Open);
        // Further misses do not re-trip.
        assert!(!b.miss());
        // First report half-opens, second closes.
        assert!(!b.report());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.report());
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_reports_reset_the_miss_count() {
        let mut b = SiteHealthTracker::new(2);
        assert!(!b.miss());
        assert!(!b.report()); // closed: reset
        assert!(!b.miss());
        assert!(b.miss()); // needs the full threshold again
    }

    #[test]
    fn halfopen_miss_reopens_without_new_window() {
        let mut b = SiteHealthTracker::new(1);
        assert!(b.miss());
        assert!(!b.report());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // The probe was a fluke — back to open, no second trip signal.
        assert!(!b.miss());
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn fault_decisions_are_a_function_of_site_and_seq() {
        let run = || {
            let mut f = SiteFaultState::new(1, 0xFEED, 0.3, 120.0, true);
            f.install(vec![ResolvedWindow {
                from: 50.0,
                to: 100.0,
                loss: 0.2,
                dup: 0.3,
                jitter_s: 5.0,
                partition: false,
            }]);
            (0..64)
                .map(|i| f.decide(SimTime(i as f64 * 2.0)))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
        // A different site index produces a different stream.
        let mut other = SiteFaultState::new(2, 0xFEED, 0.3, 120.0, true);
        let stream: Vec<Delivery> =
            (0..64).map(|i| other.decide(SimTime(i as f64 * 2.0))).collect();
        assert_ne!(run(), stream);
    }

    #[test]
    fn partition_windows_drop_everything() {
        let mut f = SiteFaultState::new(0, 1, 0.0, 120.0, true);
        f.install(vec![ResolvedWindow {
            from: 10.0,
            to: 20.0,
            loss: 1.0,
            dup: 0.0,
            jitter_s: 0.0,
            partition: true,
        }]);
        assert_eq!(f.decide(SimTime(15.0)), Delivery::Drop);
        assert_eq!(f.decide(SimTime(25.0)),
                   Delivery::Deliver { extra_delay: 0.0, duplicate: None });
        assert_eq!(f.dropped, 1);
    }

    #[test]
    fn disabled_layer_is_inert_and_free() {
        let mut f = SiteFaultState::new(0, 1, 0.9, 120.0, false);
        for _ in 0..32 {
            assert_eq!(f.decide(SimTime(0.0)),
                       Delivery::Deliver { extra_delay: 0.0,
                                           duplicate: None });
        }
        assert_eq!(f.seq, 0);
        assert_eq!(f.dropped, 0);
    }

    #[test]
    fn retransmit_backoff_doubles_to_cap() {
        let mut f = SiteFaultState::new(0, 1, 0.5, 100.0, true);
        assert_eq!(f.retransmit_backoff(0), 100.0);
        assert_eq!(f.retransmit_backoff(1), 200.0);
        assert_eq!(f.retransmit_backoff(2), 400.0);
        assert_eq!(f.retransmit_backoff(3), 800.0);
        assert_eq!(f.retransmit_backoff(9), 800.0);
        assert_eq!(f.retransmits, 5);
    }

    #[test]
    fn plan_validation_rejects_bad_windows() {
        let n = 3;
        assert!(WanFaultPlan::new(1).validate(n).is_ok());
        assert!(WanFaultPlan::new(1)
            .lossy(3, 0.0, 10.0, 0.5)
            .validate(n)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .lossy(1, 0.0, 10.0, 1.0)
            .validate(n)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .partition(1, 0.0, f64::INFINITY)
            .validate(n)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .jittery(1, -5.0, 10.0, 1.0)
            .validate(n)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .partition(2, 30.0, 60.0)
            .lossy(0, 0.0, 10.0, 0.25)
            .validate(n)
            .is_ok());
    }

    #[test]
    fn validation_errors_name_the_site() {
        let names = SiteNames::new();
        names.intern("CESNET-MCC");
        names.intern("AWS");
        let err = WanFaultPlan::new(1)
            .lossy(1, 0.0, 10.0, 1.0)
            .validate_named(2, &names)
            .unwrap_err()
            .to_string();
        assert!(err.contains("AWS"), "{err}");
        assert!(err.contains("loss"), "{err}");
        // Out-of-range targets have no name to resolve; the roster of
        // known sites is listed instead.
        let err = WanFaultPlan::new(1)
            .lossy(7, 0.0, 10.0, 0.5)
            .validate_named(2, &names)
            .unwrap_err()
            .to_string();
        assert!(err.contains("site 7"), "{err}");
        assert!(err.contains("CESNET-MCC, AWS"), "{err}");
        // Without an interner the placeholder names appear.
        let err = WanFaultPlan::new(1)
            .jittery(0, -5.0, 10.0, 1.0)
            .validate(2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("site#0"), "{err}");
    }

    #[test]
    fn regional_outages_validate_and_expand_per_site() {
        let plan = WanFaultPlan::new(3)
            .lossy(0, 0.0, 10.0, 0.2)
            .regional_outage(&[1, 2], 100.0, 600.0);
        assert!(!plan.is_empty());
        assert!(plan.validate(3).is_ok());
        // One ordinary partition window per member site, appended
        // after the plan windows in listed order.
        let exp = plan.expanded_windows();
        assert_eq!(exp.len(), 3);
        assert_eq!(exp[0], plan.windows[0]);
        for (w, site) in exp[1..].iter().zip([1usize, 2]) {
            assert_eq!(w.site, site);
            assert_eq!(w.at, SimTime(100.0));
            assert_eq!(w.duration_secs, 600.0);
            assert!(w.partition);
            assert_eq!(w.loss, 1.0);
        }
        // A regions-only plan still arms the chaos layer.
        let only = WanFaultPlan::new(1).regional_outage(&[0], 0.0, 60.0);
        assert!(!only.is_empty());
        // Rejections: out-of-range member, duplicate member, empty
        // group, bad times.
        assert!(WanFaultPlan::new(1)
            .regional_outage(&[0, 3], 0.0, 60.0)
            .validate(3)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .regional_outage(&[1, 1], 0.0, 60.0)
            .validate(3)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .regional_outage(&[], 0.0, 60.0)
            .validate(3)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .regional_outage(&[1], -1.0, 60.0)
            .validate(3)
            .is_err());
        assert!(WanFaultPlan::new(1)
            .regional_outage(&[1], 0.0, 0.0)
            .validate(3)
            .is_err());
    }
}
