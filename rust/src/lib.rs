//! # EVHC — Elastic Virtual Hybrid Clusters across cloud sites
//!
//! Reproduction of Caballer et al., *"Deployment of Elastic Virtual Hybrid
//! Clusters Across Cloud Sites"*, Journal of Grid Computing, 2021
//! (DOI 10.1007/s10723-021-09543-5).
//!
//! The crate implements the paper's full coordination stack plus every
//! substrate it depends on (see `DESIGN.md`):
//!
//! * [`ids`] — interned dense node identity shared across subsystems.
//! * [`sim`] — discrete-event simulation engine (virtual clock).
//! * [`netsim`] — flow-level inter-site network with cipher cost model.
//! * [`cloudsim`] — IaaS cloud-site simulator (quotas, VMs, networks,
//!   pricing, failure injection).
//! * [`tosca`] — TOSCA YAML-subset templates describing cluster topology.
//! * [`orchestrator`] — the INDIGO PaaS-Orchestrator analogue: SLA-driven
//!   site ranking and the (serialized) deployment workflow engine.
//! * [`im`] — the Infrastructure Manager analogue: network-first
//!   multi-cloud provisioning + Ansible-like contextualization.
//! * [`vrouter`] — the INDIGO Virtual Router analogue: OpenVPN-star
//!   overlay networks, redundant central points, standalone nodes, CA.
//! * [`lrms`] — SLURM-like batch system behind a plugin trait.
//! * [`clues`] — the CLUES elasticity engine.
//! * [`broker`] — the multi-site elasticity broker: pluggable placement
//!   policies over live per-site signals, plus scripted scenarios
//!   (spot-preemption waves, site outages, price spikes).
//! * [`workload`] — the paper's §4 audio-classification workload.
//! * [`runtime`] — PJRT executor for the AOT-compiled L2/L1 model.
//! * [`cluster`] — the public façade tying everything together.
//! * [`metrics`] — time-series recording + figure/table regeneration.
//! * [`obs`] — deterministic trace/telemetry layer: causal spans,
//!   on-clock metrics and the wall-clock engine profiler.
//! * [`api`] — the Orchestrator's REST API (+ orchent-style client).
//! * [`util`] — in-tree substrates for crates unavailable offline
//!   (CLI parsing, YAML subset, CSV, PRNG, stats, property testing).
//!
//! Python/JAX/Pallas exist only on the build path (`make artifacts`); the
//! compiled binary serves inference straight from `artifacts/*.hlo.txt`
//! via the PJRT C API.

pub mod api;
pub mod ids;
pub mod util;
pub mod sim;
pub mod netsim;
pub mod cloudsim;
pub mod tosca;
pub mod lrms;
pub mod clues;
pub mod broker;
pub mod vrouter;
pub mod im;
pub mod orchestrator;
pub mod workload;
pub mod runtime;
pub mod metrics;
pub mod obs;
pub mod cluster;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
