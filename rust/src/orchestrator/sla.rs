//! SLA-and-monitoring-driven site ranking (§3.2).
//!
//! The PaaS Orchestrator picks the "best" site for each deployment
//! request by combining the user's signed SLAs with monitored
//! availability data. We reproduce that ranking: SLA priority dominates,
//! monitored availability breaks ties and disqualifies unhealthy sites.

/// One signed SLA between the user and a site.
#[derive(Debug, Clone)]
pub struct Sla {
    pub site_name: String,
    /// Lower = preferred (the user's home site is usually 0).
    pub priority: u32,
    /// Optional ceiling on instances this SLA grants.
    pub max_instances: Option<u32>,
}

/// Monitoring snapshot for one site.
#[derive(Debug, Clone)]
pub struct SiteHealth {
    pub site_name: String,
    /// Availability in [0,1] from the monitoring system.
    pub availability: f64,
    /// Known free VM headroom (None = unknown).
    pub free_vms: Option<u32>,
}

/// Minimum availability for a site to be eligible at all.
pub const MIN_AVAILABILITY: f64 = 0.5;

/// Rank eligible sites best-first. Returns indices into `health`.
///
/// Ordering: (has SLA, SLA priority asc, availability desc, name asc).
/// Sites without an SLA rank after all SLA sites (the orchestrator can
/// still use them if nothing else has capacity, mirroring opportunistic
/// use of federated sites).
pub fn rank_sites(slas: &[Sla], health: &[SiteHealth]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..health.len())
        .filter(|&i| health[i].availability >= MIN_AVAILABILITY)
        .filter(|&i| {
            // An SLA granting zero instances disqualifies the site.
            match slas.iter().find(|s| s.site_name == health[i].site_name) {
                Some(s) => s.max_instances != Some(0),
                None => true,
            }
        })
        .collect();
    let key = |i: usize| {
        let h = &health[i];
        let sla = slas.iter().find(|s| s.site_name == h.site_name);
        (
            sla.is_none(),                              // SLA sites first
            sla.map(|s| s.priority).unwrap_or(u32::MAX),
            // availability desc with 1e-6 resolution
            (1e6 - h.availability * 1e6) as i64,
            h.site_name.clone(),
        )
    };
    idx.sort_by_key(|&i| key(i));
    idx
}

/// Instances an SLA still allows given `already_used`.
pub fn sla_headroom(slas: &[Sla], site: &str, already_used: u32)
    -> Option<u32> {
    match slas.iter().find(|s| s.site_name == site) {
        Some(Sla { max_instances: Some(max), .. }) => {
            Some(max.saturating_sub(already_used))
        }
        _ => None, // unlimited (site quota still applies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h(name: &str, avail: f64) -> SiteHealth {
        SiteHealth { site_name: name.into(), availability: avail,
                     free_vms: None }
    }

    #[test]
    fn sla_priority_dominates_availability() {
        let slas = vec![
            Sla { site_name: "cesnet".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "aws".into(), priority: 1,
                  max_instances: None },
        ];
        let health = vec![h("aws", 0.999), h("cesnet", 0.9)];
        let ranked = rank_sites(&slas, &health);
        assert_eq!(ranked, vec![1, 0]); // cesnet first despite lower avail
    }

    #[test]
    fn availability_breaks_ties() {
        let slas = vec![
            Sla { site_name: "a".into(), priority: 0, max_instances: None },
            Sla { site_name: "b".into(), priority: 0, max_instances: None },
        ];
        let health = vec![h("a", 0.9), h("b", 0.99)];
        assert_eq!(rank_sites(&slas, &health), vec![1, 0]);
    }

    #[test]
    fn unhealthy_sites_excluded() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: None }];
        let health = vec![h("a", 0.3), h("b", 0.97)];
        assert_eq!(rank_sites(&slas, &health), vec![1]);
    }

    #[test]
    fn no_sla_sites_rank_last() {
        let slas = vec![Sla { site_name: "home".into(), priority: 5,
                              max_instances: None }];
        let health = vec![h("opportunistic", 0.999), h("home", 0.8)];
        assert_eq!(rank_sites(&slas, &health), vec![1, 0]);
    }

    #[test]
    fn zero_instance_sla_disqualifies() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: Some(0) }];
        let health = vec![h("a", 0.99), h("b", 0.9)];
        assert_eq!(rank_sites(&slas, &health), vec![1]);
    }

    #[test]
    fn headroom_accounting() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: Some(5) }];
        assert_eq!(sla_headroom(&slas, "a", 3), Some(2));
        assert_eq!(sla_headroom(&slas, "a", 7), Some(0));
        assert_eq!(sla_headroom(&slas, "other", 0), None);
    }
}
