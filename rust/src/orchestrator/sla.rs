//! SLA-and-monitoring-driven site ranking (§3.2).
//!
//! The PaaS Orchestrator picks the "best" site for each deployment
//! request by combining the user's signed SLAs with monitored
//! availability data. We reproduce that ranking: SLA priority dominates,
//! monitored availability breaks ties and disqualifies unhealthy sites.
//!
//! Site identity in the ranking hot path is the dense interned
//! [`SiteId`]: health snapshots carry ids, SLAs are resolved against the
//! interner once per decision batch ([`ResolvedSlas`]), and the final
//! deterministic name tie-break compares interned names in place —
//! ranking a site list clones no `String`s. Names survive only at the
//! configuration edge ([`Sla::site_name`]) and in reports.

use crate::ids::{SiteId, SiteNames};

/// One signed SLA between the user and a site. This is the
/// configuration-edge type: names are resolved to [`SiteId`]s via
/// [`ResolvedSlas::resolve`] before any ranking happens.
#[derive(Debug, Clone)]
pub struct Sla {
    pub site_name: String,
    /// Lower = preferred (the user's home site is usually 0).
    pub priority: u32,
    /// Optional ceiling on instances this SLA grants.
    pub max_instances: Option<u32>,
}

/// Monitoring snapshot for one site, keyed by interned id.
#[derive(Debug, Clone, Copy)]
pub struct SiteHealth {
    pub site: SiteId,
    /// Availability in [0,1] from the monitoring system.
    pub availability: f64,
    /// Known free VM headroom (None = unknown).
    pub free_vms: Option<u32>,
}

/// SLA terms resolved against a site interner: a dense per-site table
/// of `(priority, max_instances)`. When several SLAs name the same
/// site, the first wins (matching the legacy first-match lookup).
#[derive(Debug, Clone, Default)]
pub struct ResolvedSlas {
    by_site: Vec<Option<(u32, Option<u32>)>>,
}

impl ResolvedSlas {
    pub fn resolve(slas: &[Sla], names: &SiteNames) -> ResolvedSlas {
        let mut by_site: Vec<Option<(u32, Option<u32>)>> =
            vec![None; names.len()];
        for s in slas {
            if let Some(id) = names.get(&s.site_name) {
                let e = &mut by_site[id.index()];
                if e.is_none() {
                    *e = Some((s.priority, s.max_instances));
                }
            }
        }
        ResolvedSlas { by_site }
    }

    /// `(priority, max_instances)` of the SLA covering `site`, if any.
    pub fn get(&self, site: SiteId) -> Option<(u32, Option<u32>)> {
        self.by_site.get(site.index()).copied().flatten()
    }

    /// Instances the SLA for `site` still allows given `already_used`
    /// (None = no SLA ceiling; site quota still applies).
    pub fn headroom(&self, site: SiteId, already_used: u32) -> Option<u32> {
        match self.get(site) {
            Some((_, Some(max))) => Some(max.saturating_sub(already_used)),
            _ => None,
        }
    }
}

/// Minimum availability for a site to be eligible at all.
pub const MIN_AVAILABILITY: f64 = 0.5;

/// Rank eligible sites best-first. Returns indices into `health`.
///
/// Ordering: (has SLA, SLA priority asc, availability desc, name asc).
/// Sites without an SLA rank after all SLA sites (the orchestrator can
/// still use them if nothing else has capacity, mirroring opportunistic
/// use of federated sites).
pub fn rank_sites(slas: &ResolvedSlas, names: &SiteNames,
                  health: &[SiteHealth]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..health.len())
        .filter(|&i| health[i].availability >= MIN_AVAILABILITY)
        .filter(|&i| {
            // An SLA granting zero instances disqualifies the site.
            match slas.get(health[i].site) {
                Some((_, max)) => max != Some(0),
                None => true,
            }
        })
        .collect();
    // Precompute the name tie-break rank over the eligible set: ranking
    // by rank number is identical to ranking by name, without cloning.
    let mut by_name = idx.clone();
    by_name.sort_by(|&a, &b| names.cmp_names(health[a].site,
                                             health[b].site));
    let mut name_rank = vec![0u32; health.len()];
    for (r, &i) in by_name.iter().enumerate() {
        name_rank[i] = r as u32;
    }
    idx.sort_by_key(|&i| {
        let h = &health[i];
        let sla = slas.get(h.site);
        (
            sla.is_none(),                              // SLA sites first
            sla.map(|(p, _)| p).unwrap_or(u32::MAX),
            // availability desc with 1e-6 resolution
            (1e6 - h.availability * 1e6) as i64,
            name_rank[i],
        )
    });
    idx
}

/// Instances an SLA still allows given `already_used` — string-keyed
/// configuration-edge twin of [`ResolvedSlas::headroom`].
pub fn sla_headroom(slas: &[Sla], site: &str, already_used: u32)
    -> Option<u32> {
    match slas.iter().find(|s| s.site_name == site) {
        Some(Sla { max_instances: Some(max), .. }) => {
            Some(max.saturating_sub(already_used))
        }
        _ => None, // unlimited (site quota still applies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Interner + health list from (name, availability) pairs.
    fn world(entries: &[(&str, f64)]) -> (SiteNames, Vec<SiteHealth>) {
        let names = SiteNames::new();
        let health = entries
            .iter()
            .map(|&(n, avail)| SiteHealth {
                site: names.intern(n),
                availability: avail,
                free_vms: None,
            })
            .collect();
        (names, health)
    }

    fn rank(slas: &[Sla], names: &SiteNames, health: &[SiteHealth])
        -> Vec<usize> {
        rank_sites(&ResolvedSlas::resolve(slas, names), names, health)
    }

    #[test]
    fn sla_priority_dominates_availability() {
        let slas = vec![
            Sla { site_name: "cesnet".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "aws".into(), priority: 1,
                  max_instances: None },
        ];
        let (names, health) = world(&[("aws", 0.999), ("cesnet", 0.9)]);
        let ranked = rank(&slas, &names, &health);
        assert_eq!(ranked, vec![1, 0]); // cesnet first despite lower avail
    }

    #[test]
    fn availability_breaks_ties() {
        let slas = vec![
            Sla { site_name: "a".into(), priority: 0, max_instances: None },
            Sla { site_name: "b".into(), priority: 0, max_instances: None },
        ];
        let (names, health) = world(&[("a", 0.9), ("b", 0.99)]);
        assert_eq!(rank(&slas, &names, &health), vec![1, 0]);
    }

    #[test]
    fn name_breaks_full_ties() {
        let slas = vec![
            Sla { site_name: "zeta".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "alpha".into(), priority: 0,
                  max_instances: None },
        ];
        let (names, health) = world(&[("zeta", 0.9), ("alpha", 0.9)]);
        assert_eq!(rank(&slas, &names, &health), vec![1, 0]);
    }

    #[test]
    fn unhealthy_sites_excluded() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: None }];
        let (names, health) = world(&[("a", 0.3), ("b", 0.97)]);
        assert_eq!(rank(&slas, &names, &health), vec![1]);
    }

    #[test]
    fn no_sla_sites_rank_last() {
        let slas = vec![Sla { site_name: "home".into(), priority: 5,
                              max_instances: None }];
        let (names, health) = world(&[("opportunistic", 0.999),
                                      ("home", 0.8)]);
        assert_eq!(rank(&slas, &names, &health), vec![1, 0]);
    }

    #[test]
    fn zero_instance_sla_disqualifies() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: Some(0) }];
        let (names, health) = world(&[("a", 0.99), ("b", 0.9)]);
        assert_eq!(rank(&slas, &names, &health), vec![1]);
    }

    #[test]
    fn headroom_accounting() {
        let slas = vec![Sla { site_name: "a".into(), priority: 0,
                              max_instances: Some(5) }];
        assert_eq!(sla_headroom(&slas, "a", 3), Some(2));
        assert_eq!(sla_headroom(&slas, "a", 7), Some(0));
        assert_eq!(sla_headroom(&slas, "other", 0), None);
    }

    #[test]
    fn resolved_headroom_matches_string_twin() {
        let slas = vec![
            Sla { site_name: "a".into(), priority: 0,
                  max_instances: Some(5) },
            Sla { site_name: "b".into(), priority: 1, max_instances: None },
        ];
        let names = SiteNames::new();
        let a = names.intern("a");
        let b = names.intern("b");
        let c = names.intern("c");
        let resolved = ResolvedSlas::resolve(&slas, &names);
        assert_eq!(resolved.headroom(a, 3), sla_headroom(&slas, "a", 3));
        assert_eq!(resolved.headroom(a, 7), sla_headroom(&slas, "a", 7));
        assert_eq!(resolved.headroom(b, 0), sla_headroom(&slas, "b", 0));
        assert_eq!(resolved.headroom(c, 0), sla_headroom(&slas, "c", 0));
        assert_eq!(resolved.get(c), None);
    }

    #[test]
    fn first_matching_sla_wins() {
        let slas = vec![
            Sla { site_name: "a".into(), priority: 2,
                  max_instances: Some(1) },
            Sla { site_name: "a".into(), priority: 0, max_instances: None },
        ];
        let names = SiteNames::new();
        let a = names.intern("a");
        let resolved = ResolvedSlas::resolve(&slas, &names);
        assert_eq!(resolved.get(a), Some((2, Some(1))));
    }
}
