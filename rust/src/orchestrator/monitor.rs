//! Monitoring service: the availability data the PaaS Orchestrator
//! combines with SLAs when ranking sites (§3.2: "it gathers information
//! about the SLA signed by the providers and monitoring data about the
//! availability of the compute and storage resources").
//!
//! The real stack polls each CMF's health endpoints; here probes are
//! synthetic (a per-site up-probability plus scripted outages), and the
//! service maintains the sliding-window availability the ranking
//! consumes — so a site that starts failing probes organically drops out
//! of new placements.

use std::collections::HashMap;

use crate::ids::{SiteId, SiteNames};
use crate::sim::SimTime;
use crate::util::prng::Prng;

use super::SiteHealth;

/// One probe result.
#[derive(Debug, Clone, Copy)]
pub struct Probe {
    pub at: SimTime,
    pub up: bool,
    /// Probe round-trip, seconds (used as a tie-break quality signal).
    pub rtt_s: f64,
}

/// A scripted outage window for a site (deterministic injections).
#[derive(Debug, Clone)]
pub struct Outage {
    pub site: String,
    pub start: SimTime,
    pub duration_secs: f64,
}

impl Outage {
    fn active_at(&self, t: SimTime) -> bool {
        t.0 >= self.start.0 && t.0 < self.start.0 + self.duration_secs
    }
}

/// Per-site probe configuration.
#[derive(Debug, Clone)]
pub struct ProbeTarget {
    pub site: String,
    /// Baseline probability a probe succeeds outside outages.
    pub base_up_prob: f64,
    /// Median probe RTT, seconds.
    pub rtt_median_s: f64,
}

/// Sliding-window availability monitor. Probe history is keyed by the
/// interned [`SiteId`] (targets are interned once at construction), so
/// a probe round clones no target list and no site-name `String`s.
pub struct Monitor {
    names: SiteNames,
    targets: Vec<ProbeTarget>,
    /// Interned id of each target, parallel to `targets`.
    target_ids: Vec<SiteId>,
    outages: Vec<Outage>,
    window: usize,
    history: HashMap<SiteId, Vec<Probe>>,
    rng: Prng,
}

impl Monitor {
    /// `window`: number of most recent probes that define availability.
    pub fn new(targets: Vec<ProbeTarget>, window: usize, seed: u64)
        -> Monitor {
        Monitor::with_names(targets, window, seed, SiteNames::new())
    }

    /// Share a cluster-wide site interner so ids line up with the
    /// broker and the ranking functions.
    pub fn with_names(targets: Vec<ProbeTarget>, window: usize, seed: u64,
                      names: SiteNames) -> Monitor {
        let target_ids =
            targets.iter().map(|tg| names.intern(&tg.site)).collect();
        Monitor {
            names,
            targets,
            target_ids,
            outages: Vec::new(),
            window: window.max(1),
            history: HashMap::new(),
            rng: Prng::new(seed ^ 0x40A1),
        }
    }

    /// Interner handle (snapshot ids resolve through it).
    pub fn names(&self) -> SiteNames {
        self.names.clone()
    }

    pub fn add_outage(&mut self, outage: Outage) {
        self.outages.push(outage);
    }

    /// Run one probe round at time `t`.
    pub fn probe_all(&mut self, t: SimTime) {
        for ti in 0..self.targets.len() {
            let id = self.target_ids[ti];
            let (in_outage, base_up, rtt_median) = {
                let tg = &self.targets[ti];
                let out = self
                    .outages
                    .iter()
                    .any(|o| o.site == tg.site && o.active_at(t));
                (out, tg.base_up_prob, tg.rtt_median_s)
            };
            let up = !in_outage && self.rng.chance(base_up);
            let rtt = self.rng.lognormal(rtt_median, 0.4);
            self.history
                .entry(id)
                .or_default()
                .push(Probe { at: t, up, rtt_s: rtt });
        }
    }

    /// Availability over the sliding window (1.0 when unprobed — a fresh
    /// site is assumed healthy until evidence says otherwise).
    pub fn availability(&self, site: &str) -> f64 {
        self.names
            .get(site)
            .map(|id| self.availability_id(id))
            .unwrap_or(1.0)
    }

    /// Id-keyed twin of [`Monitor::availability`] (hot path).
    pub fn availability_id(&self, site: SiteId) -> f64 {
        match self.history.get(&site) {
            None => 1.0,
            Some(h) if h.is_empty() => 1.0,
            Some(h) => {
                let tail = &h[h.len().saturating_sub(self.window)..];
                tail.iter().filter(|p| p.up).count() as f64
                    / tail.len() as f64
            }
        }
    }

    /// Median probe RTT over the window (f64::INFINITY when unprobed).
    pub fn median_rtt(&self, site: &str) -> f64 {
        let h = self.names.get(site).and_then(|id| self.history.get(&id));
        match h {
            None => f64::INFINITY,
            Some(h) if h.is_empty() => f64::INFINITY,
            Some(h) => {
                let tail = &h[h.len().saturating_sub(self.window)..];
                let mut rtts: Vec<f64> =
                    tail.iter().map(|p| p.rtt_s).collect();
                rtts.sort_by(|a, b| a.partial_cmp(b).unwrap());
                rtts[rtts.len() / 2]
            }
        }
    }

    /// Health snapshot for the ranking function (id-keyed, no clones).
    pub fn snapshot(&self) -> Vec<SiteHealth> {
        self.target_ids
            .iter()
            .map(|&id| SiteHealth {
                site: id,
                availability: self.availability_id(id),
                free_vms: None,
            })
            .collect()
    }

    pub fn probes_recorded(&self, site: &str) -> usize {
        self.names
            .get(site)
            .and_then(|id| self.history.get(&id))
            .map(|h| h.len())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::{rank_sites, Sla};

    fn targets() -> Vec<ProbeTarget> {
        vec![
            ProbeTarget { site: "cesnet".into(), base_up_prob: 0.99,
                          rtt_median_s: 0.02 },
            ProbeTarget { site: "aws".into(), base_up_prob: 0.999,
                          rtt_median_s: 0.06 },
        ]
    }

    #[test]
    fn fresh_sites_assumed_available() {
        let m = Monitor::new(targets(), 10, 1);
        assert_eq!(m.availability("cesnet"), 1.0);
        assert_eq!(m.availability("unknown"), 1.0);
    }

    #[test]
    fn availability_tracks_probe_outcomes() {
        let mut m = Monitor::new(targets(), 50, 2);
        for i in 0..100 {
            m.probe_all(SimTime(i as f64 * 60.0));
        }
        let a = m.availability("cesnet");
        assert!(a > 0.9, "{a}");
        assert_eq!(m.probes_recorded("cesnet"), 100);
        assert!(m.median_rtt("cesnet") < m.median_rtt("aws"));
    }

    #[test]
    fn outage_drops_availability_then_recovers() {
        let mut m = Monitor::new(targets(), 10, 3);
        m.add_outage(Outage { site: "cesnet".into(), start: SimTime(0.0),
                              duration_secs: 600.0 });
        for i in 0..10 {
            m.probe_all(SimTime(i as f64 * 60.0));
        }
        assert_eq!(m.availability("cesnet"), 0.0);
        assert!(m.availability("aws") > 0.9);
        // After the outage the window slides back to healthy.
        for i in 10..30 {
            m.probe_all(SimTime(i as f64 * 60.0));
        }
        assert!(m.availability("cesnet") > 0.9);
    }

    #[test]
    fn ranking_consumes_monitor_snapshot() {
        let mut m = Monitor::new(targets(), 10, 4);
        m.add_outage(Outage { site: "cesnet".into(), start: SimTime(0.0),
                              duration_secs: 1e9 });
        for i in 0..10 {
            m.probe_all(SimTime(i as f64 * 60.0));
        }
        let slas = vec![
            Sla { site_name: "cesnet".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "aws".into(), priority: 1,
                  max_instances: None },
        ];
        let names = m.names();
        let health = m.snapshot();
        let resolved = crate::orchestrator::ResolvedSlas::resolve(
            &slas, &names);
        let ranked = rank_sites(&resolved, &names, &health);
        // cesnet is dark — despite the better SLA it must be excluded.
        assert_eq!(ranked.len(), 1);
        assert_eq!(names.name(health[ranked[0]].site), "aws");
    }

    #[test]
    fn window_bounds_history_influence() {
        let mut m = Monitor::new(targets(), 5, 5);
        m.add_outage(Outage { site: "aws".into(), start: SimTime(0.0),
                              duration_secs: 300.0 });
        // 5 down probes, then 5 up probes: window=5 forgets the outage.
        for i in 0..10 {
            m.probe_all(SimTime(i as f64 * 60.0));
        }
        assert!(m.availability("aws") > 0.9);
    }
}
