//! PaaS Orchestrator analogue: TOSCA intake, site selection, and the
//! deployment-update workflow engine.
//!
//! Two behaviours from the paper are load-bearing for its results and are
//! modelled explicitly:
//!
//! 1. **Serialized updates** — "the PaaS Orchestrator workflow engine has
//!    a limitation in that it does not allow a deployment to be modified
//!    while an update operation is in progress". This is what turns three
//!    simultaneous CLUES power-on requests into the ~20-minute staircase
//!    of Figures 10/11. The engine runs one update at a time when
//!    `serialized` (default), or fully concurrently when not — the
//!    paper's future-work "parallel provisioning" ablation.
//!
//! 2. **Queued updates are cancellable** — CLUES cancels pending
//!    power-offs when new jobs arrive early; only operations that have
//!    not yet *started* can be cancelled (vnode-3's power-off had already
//!    begun, so only it actually powered off).

pub mod monitor;
pub mod sla;

pub use monitor::{Monitor, Outage, Probe, ProbeTarget};
pub use sla::{rank_sites, sla_headroom, ResolvedSlas, SiteHealth, Sla};

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Context};

use crate::cloudsim::CloudSite;
use crate::sim::SimTime;

/// Update operation kinds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UpdateOp {
    /// Provision one worker node (CLUES power-on).
    AddWorker { name: String },
    /// Decommission one worker node (CLUES power-off).
    RemoveWorker { name: String },
    /// Initial deployment of the front-end + first workers.
    InitialDeploy,
}

/// Workflow-engine update identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct UpdateId(pub u64);

/// Update lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateState {
    Queued,
    InProgress,
    Done,
    Cancelled,
}

/// One deployment update tracked by the engine.
#[derive(Debug, Clone)]
pub struct Update {
    pub id: UpdateId,
    pub op: UpdateOp,
    pub state: UpdateState,
    pub submitted_at: SimTime,
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
}

/// Key identifying what a queued update targets. The engine keeps a
/// FIFO of queued update ids per key so lookups by operation are O(1)
/// instead of a scan over the full (append-only) update history.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum OpKey {
    Add(String),
    Remove(String),
    Init,
}

fn op_key(op: &UpdateOp) -> OpKey {
    match op {
        UpdateOp::AddWorker { name } => OpKey::Add(name.clone()),
        UpdateOp::RemoveWorker { name } => OpKey::Remove(name.clone()),
        UpdateOp::InitialDeploy => OpKey::Init,
    }
}

/// The deployment-update workflow engine.
pub struct WorkflowEngine {
    /// Paper default: one update at a time.
    pub serialized: bool,
    queue: VecDeque<UpdateId>,
    updates: Vec<Update>,
    in_progress: usize,
    /// Queued updates indexed by op key (FIFO per key). Entries leave
    /// the index the moment an update starts or is cancelled, so its
    /// size is bounded by the queue depth, not the history length.
    queued_by_key: HashMap<OpKey, VecDeque<UpdateId>>,
    /// Count of updates currently in `Queued` state.
    queued: usize,
}

impl WorkflowEngine {
    pub fn new(serialized: bool) -> WorkflowEngine {
        WorkflowEngine {
            serialized,
            queue: VecDeque::new(),
            updates: Vec::new(),
            in_progress: 0,
            queued_by_key: HashMap::new(),
            queued: 0,
        }
    }

    /// Submit an update; it queues until the engine is free.
    pub fn submit(&mut self, op: UpdateOp, t: SimTime) -> UpdateId {
        let id = UpdateId(self.updates.len() as u64);
        let key = op_key(&op);
        self.updates.push(Update {
            id,
            op,
            state: UpdateState::Queued,
            submitted_at: t,
            started_at: None,
            finished_at: None,
        });
        self.queue.push_back(id);
        self.queued_by_key.entry(key).or_default().push_back(id);
        self.queued += 1;
        id
    }

    /// Drop `id` from the per-key queued index.
    fn unqueue(&mut self, id: UpdateId, key: OpKey) {
        if let Some(dq) = self.queued_by_key.get_mut(&key) {
            if let Some(pos) = dq.iter().position(|&x| x == id) {
                dq.remove(pos);
                self.queued -= 1;
            }
            if dq.is_empty() {
                self.queued_by_key.remove(&key);
            }
        }
    }

    /// Pop the next update(s) that may start now. With serialization on,
    /// at most one update is in progress at any time.
    pub fn startable(&mut self, t: SimTime) -> Vec<Update> {
        let mut started = Vec::new();
        loop {
            if self.serialized && self.in_progress + started.len() >= 1 {
                break;
            }
            match self.queue.pop_front() {
                None => break,
                Some(id) => {
                    let u = &mut self.updates[id.0 as usize];
                    if u.state != UpdateState::Queued {
                        continue; // cancelled while queued
                    }
                    u.state = UpdateState::InProgress;
                    u.started_at = Some(t);
                    let cloned = u.clone();
                    self.unqueue(id, op_key(&cloned.op));
                    started.push(cloned);
                }
            }
        }
        self.in_progress += started.len();
        started
    }

    /// Mark an in-progress update finished.
    pub fn complete(&mut self, id: UpdateId, t: SimTime)
        -> anyhow::Result<()> {
        let u = self
            .updates
            .get_mut(id.0 as usize)
            .with_context(|| format!("no update {id:?}"))?;
        if u.state != UpdateState::InProgress {
            bail!("update {id:?} is {:?}, not InProgress", u.state);
        }
        u.state = UpdateState::Done;
        u.finished_at = Some(t);
        self.in_progress -= 1;
        Ok(())
    }

    /// Cancel a *queued* update (CLUES revoking a pending power-off).
    /// Fails if it already started — matching the paper's vnode-3, whose
    /// power-off could not be recalled.
    pub fn cancel(&mut self, id: UpdateId, t: SimTime)
        -> anyhow::Result<()> {
        let u = self
            .updates
            .get_mut(id.0 as usize)
            .with_context(|| format!("no update {id:?}"))?;
        match u.state {
            UpdateState::Queued => {
                u.state = UpdateState::Cancelled;
                u.finished_at = Some(t);
                let key = op_key(&u.op);
                self.unqueue(id, key);
                Ok(())
            }
            other => bail!("cannot cancel update in state {other:?}"),
        }
    }

    pub fn update(&self, id: UpdateId) -> Option<&Update> {
        self.updates.get(id.0 as usize)
    }

    pub fn updates(&self) -> &[Update] {
        &self.updates
    }

    /// Find the queued update matching an arbitrary predicate. This is
    /// the generic O(history) path — prefer the keyed O(1) lookup
    /// ([`WorkflowEngine::find_queued_remove`]) on hot paths.
    pub fn find_queued(&self, pred: impl Fn(&UpdateOp) -> bool)
        -> Option<UpdateId> {
        self.updates
            .iter()
            .find(|u| u.state == UpdateState::Queued && pred(&u.op))
            .map(|u| u.id)
    }

    /// O(1): the oldest queued `RemoveWorker` update for `name` (CLUES
    /// revoking a pending power-off).
    pub fn find_queued_remove(&self, name: &str) -> Option<UpdateId> {
        self.queued_by_key
            .get(&OpKey::Remove(name.to_string()))
            .and_then(|dq| dq.front().copied())
    }

    /// Number of updates currently queued — O(1), maintained by the
    /// per-key index.
    pub fn queued_len(&self) -> usize {
        self.queued
    }

    pub fn in_progress(&self) -> usize {
        self.in_progress
    }
}

/// Site selection: pick the best ranked site with headroom for one more
/// `cpus`-sized VM. `slas` order encodes the user's preferences.
///
/// This is the *legacy reference* selector: it re-interns the site list
/// and re-resolves the SLAs on every call. The elasticity hot path goes
/// through [`crate::broker::ElasticityBroker`], which resolves all of
/// this once at construction; `tests/broker_policies.rs` proves the
/// broker's `SlaRank` policy decision-identical to this function.
pub fn select_site(
    sites: &[CloudSite],
    slas: &[Sla],
    used_per_site: &[u32],
    cpus: u32,
) -> Option<usize> {
    let names = crate::ids::SiteNames::new();
    let health: Vec<SiteHealth> = sites
        .iter()
        .map(|s| SiteHealth {
            site: names.intern(&s.spec.name),
            availability: s.spec.availability,
            free_vms: Some(
                (s.spec.quota.max_vms - s.used_vms()) as u32),
        })
        .collect();
    let resolved = ResolvedSlas::resolve(slas, &names);
    for i in rank_sites(&resolved, &names, &health) {
        let site = &sites[i];
        // Site-level quota headroom.
        if site.used_vms() + 1 > site.spec.quota.max_vms {
            continue;
        }
        if site.used_vcpus() + cpus > site.spec.quota.max_vcpus {
            continue;
        }
        // SLA-level headroom.
        if let Some(h) = sla_headroom(slas, &site.spec.name,
                                      used_per_site[i]) {
            if h == 0 {
                continue;
            }
        }
        return Some(i);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudsim::{SiteSpec, VmRequest};
    use crate::netsim::NetId;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    #[test]
    fn serialized_engine_runs_one_at_a_time() {
        let mut e = WorkflowEngine::new(true);
        let a = e.submit(UpdateOp::AddWorker { name: "vnode-3".into() },
                         t(0.0));
        let b = e.submit(UpdateOp::AddWorker { name: "vnode-4".into() },
                         t(0.0));
        let started = e.startable(t(1.0));
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, a);
        assert!(e.startable(t(2.0)).is_empty()); // engine busy
        e.complete(a, t(100.0)).unwrap();
        let started = e.startable(t(100.0));
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, b);
    }

    #[test]
    fn parallel_engine_starts_everything() {
        let mut e = WorkflowEngine::new(false);
        for i in 0..3 {
            e.submit(UpdateOp::AddWorker { name: format!("n{i}") }, t(0.0));
        }
        assert_eq!(e.startable(t(0.0)).len(), 3);
        assert_eq!(e.in_progress(), 3);
    }

    #[test]
    fn cancel_only_queued() {
        let mut e = WorkflowEngine::new(true);
        let a = e.submit(UpdateOp::RemoveWorker { name: "vnode-3".into() },
                         t(0.0));
        let b = e.submit(UpdateOp::RemoveWorker { name: "vnode-4".into() },
                         t(0.0));
        e.startable(t(1.0)); // a starts
        assert!(e.cancel(a, t(2.0)).is_err()); // vnode-3: too late
        e.cancel(b, t(2.0)).unwrap(); // vnode-4: revoked in queue
        e.complete(a, t(50.0)).unwrap();
        assert!(e.startable(t(50.0)).is_empty()); // b was cancelled
        assert_eq!(e.update(b).unwrap().state, UpdateState::Cancelled);
    }

    #[test]
    fn find_queued_matches_op() {
        let mut e = WorkflowEngine::new(true);
        e.submit(UpdateOp::AddWorker { name: "x".into() }, t(0.0));
        let b = e.submit(UpdateOp::RemoveWorker { name: "y".into() }, t(0.0));
        let found = e.find_queued(|op| matches!(op,
            UpdateOp::RemoveWorker { name } if name == "y"));
        // AddWorker is startable first, but both are still Queued.
        assert_eq!(found, Some(b));
    }

    #[test]
    fn queued_index_tracks_lifecycle() {
        let mut e = WorkflowEngine::new(true);
        let a = e.submit(UpdateOp::RemoveWorker { name: "vnode-1".into() },
                         t(0.0));
        let b = e.submit(UpdateOp::RemoveWorker { name: "vnode-1".into() },
                         t(1.0));
        let _c = e.submit(UpdateOp::AddWorker { name: "vnode-2".into() },
                          t(2.0));
        assert_eq!(e.queued_len(), 3);
        // The keyed lookup returns the oldest queued entry per key and
        // agrees with the generic scan.
        assert_eq!(e.find_queued_remove("vnode-1"), Some(a));
        assert_eq!(
            e.find_queued_remove("vnode-1"),
            e.find_queued(|op| matches!(op,
                UpdateOp::RemoveWorker { name } if name == "vnode-1")));
        // Starting `a` drains its index entry; `b` remains findable.
        let started = e.startable(t(3.0));
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, a);
        assert_eq!(e.queued_len(), 2);
        assert_eq!(e.find_queued_remove("vnode-1"), Some(b));
        // Cancelling `b` empties the Remove key entirely.
        e.cancel(b, t(4.0)).unwrap();
        assert_eq!(e.find_queued_remove("vnode-1"), None);
        assert_eq!(e.queued_len(), 1);
        assert_eq!(e.find_queued_remove("vnode-9"), None);
    }

    #[test]
    fn site_selection_prefers_sla_until_quota_then_bursts() {
        let mut sites = vec![
            CloudSite::new(SiteSpec::cesnet_metacentrum(), 0, NetId(0), 1),
            CloudSite::new(SiteSpec::aws_us_east_2(), 1, NetId(1), 2),
        ];
        let slas = vec![
            Sla { site_name: "CESNET-MCC".into(), priority: 0,
                  max_instances: None },
            Sla { site_name: "AWS".into(), priority: 1,
                  max_instances: None },
        ];
        let used = vec![0, 0];
        assert_eq!(select_site(&sites, &slas, &used, 2), Some(0));
        // Fill CESNET to its 3-VM quota.
        for i in 0..3 {
            sites[0]
                .request_vm(&VmRequest {
                    name: format!("n{i}"),
                    instance_type: "standard.medium".into(),
                    network: None,
                    public_ip: false,
                }, t(0.0))
                .unwrap();
        }
        // Bursts to AWS — the paper's step 4.
        assert_eq!(select_site(&sites, &slas, &used, 2), Some(1));
    }

    #[test]
    fn selection_none_when_everything_full() {
        let sites = vec![CloudSite::new(SiteSpec::cesnet_metacentrum(), 0,
                                        NetId(0), 1)];
        let slas = vec![Sla { site_name: "CESNET-MCC".into(), priority: 0,
                              max_instances: Some(0) }];
        assert_eq!(select_site(&sites, &slas, &[0], 2), None);
    }

    #[test]
    fn update_log_records_timing() {
        let mut e = WorkflowEngine::new(true);
        let a = e.submit(UpdateOp::InitialDeploy, t(5.0));
        e.startable(t(6.0));
        e.complete(a, t(90.0)).unwrap();
        let u = e.update(a).unwrap();
        assert_eq!(u.submitted_at.0, 5.0);
        assert_eq!(u.started_at.unwrap().0, 6.0);
        assert_eq!(u.finished_at.unwrap().0, 90.0);
        assert_eq!(u.state, UpdateState::Done);
    }
}
