//! INDIGO Virtual Router analogue: the multi-site private overlay.
//!
//! Reproduces §3.5 of the paper:
//! * a star topology of OpenVPN tunnels with the **central point (CP)**
//!   co-located with the cluster front-end (the only public IP),
//! * one **site vRouter** per additional cloud, routing its local /24
//!   through the CP,
//! * **stand-alone nodes** (§3.5.4) that join the VPN directly because
//!   their site gives no control over the local network,
//! * **redundant stars** (Fig. 6): backup CPs used as hot standby only,
//! * the §3.5.6 **performance–security trade-off** via per-cipher costs,
//! * the future-work **shortest-path extension**: optional direct
//!   router-to-router tunnels that bypass the CP.

pub mod ca;
pub mod routing;

pub use ca::{Certificate, CertificateAuthority};
pub use routing::{build_table, NextHop, RouteTable};

use std::collections::HashMap;

use anyhow::{bail, Context};

use crate::netsim::{Cipher, NetId, Network, OverlayHop};
use crate::sim::SimTime;

/// Role of an overlay element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Designated vRouter accepting VPN connections (has the public IP).
    CentralPoint,
    /// Per-site router tunnelling its local network to a CP.
    SiteRouter,
    /// A single machine connected straight into the VPN (§3.5.4).
    Standalone,
}

/// One overlay element (vRouter instance or standalone client).
#[derive(Debug, Clone)]
pub struct Element {
    pub name: String,
    pub role: Role,
    /// Underlay location (cloud site / internet POP).
    pub location: NetId,
    /// The /24 this element announces (None for standalone clients).
    pub subnet_base: Option<u32>,
    /// Index into `cps` of the CP this element currently uses
    /// (None for CPs themselves, or when disconnected).
    pub via_cp: Option<usize>,
    pub up: bool,
}

/// Time to establish one OpenVPN client connection (TLS handshake +
/// config push), seconds.
pub const VPN_CONNECT_SECS: f64 = 4.0;

/// The overlay network of one hybrid deployment.
pub struct Overlay {
    pub cipher: Cipher,
    pub ca: CertificateAuthority,
    /// Element names of central points; index 0 is the primary.
    cps: Vec<String>,
    elements: HashMap<String, Element>,
    /// Direct router↔router tunnels (shortest-path extension).
    pub shortest_path: bool,
    /// Connection log for reports: (time, element, cp index).
    pub connection_log: Vec<(SimTime, String, usize)>,
}

impl Overlay {
    pub fn new(cipher: Cipher) -> Overlay {
        Overlay {
            cipher,
            ca: CertificateAuthority::new(),
            cps: Vec::new(),
            elements: HashMap::new(),
            shortest_path: false,
            connection_log: Vec::new(),
        }
    }

    /// Install a central point (the first call defines the primary).
    /// The CP hosts the CA, announces its local subnet, and needs the
    /// deployment's only public IP.
    pub fn add_central_point(&mut self, name: &str, location: NetId,
                             subnet_base: u32, t: SimTime)
        -> anyhow::Result<()> {
        if self.elements.contains_key(name) {
            bail!("element {name:?} already exists");
        }
        self.ca.issue(name, t)?;
        self.elements.insert(name.to_string(), Element {
            name: name.to_string(),
            role: Role::CentralPoint,
            location,
            subnet_base: Some(subnet_base),
            via_cp: None,
            up: true,
        });
        self.cps.push(name.to_string());
        Ok(())
    }

    /// Connect a per-site vRouter: issue+register its cert with a static
    /// subnet, then open the tunnel to the primary live CP.
    /// Returns the connection latency (cert exchange + TLS handshake).
    pub fn add_site_router(&mut self, name: &str, location: NetId,
                           subnet_base: u32, t: SimTime)
        -> anyhow::Result<f64> {
        if self.elements.contains_key(name) {
            bail!("element {name:?} already exists");
        }
        let cp = self
            .first_live_cp()
            .context("no live central point to connect to")?;
        self.ca.issue(name, t)?;
        self.ca.register_client(name, subnet_base)?;
        self.elements.insert(name.to_string(), Element {
            name: name.to_string(),
            role: Role::SiteRouter,
            location,
            subnet_base: Some(subnet_base),
            via_cp: Some(cp),
            up: true,
        });
        self.connection_log.push((t, name.to_string(), cp));
        Ok(VPN_CONNECT_SECS)
    }

    /// Connect a stand-alone node (no subnet of its own; the VPN client
    /// runs on the node itself — §3.5.4).
    pub fn add_standalone(&mut self, name: &str, location: NetId, t: SimTime)
        -> anyhow::Result<f64> {
        if self.elements.contains_key(name) {
            bail!("element {name:?} already exists");
        }
        let cp = self
            .first_live_cp()
            .context("no live central point to connect to")?;
        self.ca.issue(name, t)?;
        self.elements.insert(name.to_string(), Element {
            name: name.to_string(),
            role: Role::Standalone,
            location,
            subnet_base: None,
            via_cp: Some(cp),
            up: true,
        });
        self.connection_log.push((t, name.to_string(), cp));
        Ok(VPN_CONNECT_SECS)
    }

    /// Remove an element (its VM was terminated).
    pub fn remove(&mut self, name: &str) -> anyhow::Result<()> {
        let el = self
            .elements
            .remove(name)
            .with_context(|| format!("no element {name:?}"))?;
        if el.role == Role::CentralPoint {
            self.cps.retain(|c| c != name);
            // Clients re-home just as if the CP had failed.
            self.rehome_clients_of(name);
        }
        if self.ca.verify(name) {
            let _ = self.ca.revoke(name);
        }
        Ok(())
    }

    fn first_live_cp(&self) -> Option<usize> {
        self.cps.iter().position(|c| {
            self.elements.get(c).map(|e| e.up).unwrap_or(false)
        })
    }

    /// CP failure: clients fall back to the next live CP (hot backup,
    /// Fig. 6). Returns the names of clients that re-homed (empty if no
    /// backup exists — the deployment is then partitioned).
    pub fn fail_central_point(&mut self, name: &str, t: SimTime)
        -> anyhow::Result<Vec<String>> {
        {
            let el = self
                .elements
                .get_mut(name)
                .with_context(|| format!("no element {name:?}"))?;
            if el.role != Role::CentralPoint {
                bail!("{name:?} is not a central point");
            }
            el.up = false;
        }
        let rehomed = self.rehome_clients_of(name);
        for n in &rehomed {
            if let Some(cp) = self.elements.get(n).and_then(|e| e.via_cp) {
                self.connection_log.push((t, n.clone(), cp));
            }
        }
        Ok(rehomed)
    }

    /// Bring a failed CP back (clients stay where they are; hot backup
    /// remains in use until the next failure, matching "would only use
    /// their connection to the backup CP if connection to the primary
    /// was lost").
    pub fn restore_central_point(&mut self, name: &str)
        -> anyhow::Result<()> {
        let el = self
            .elements
            .get_mut(name)
            .with_context(|| format!("no element {name:?}"))?;
        el.up = true;
        Ok(())
    }

    /// WAN partition: a site router drops off the overlay (its tunnel
    /// to the CP is down) until restored. The router keeps its
    /// certificate and subnet — nothing is revoked, traffic just stops
    /// flowing while the element is down.
    pub fn fail_site_router(&mut self, name: &str) -> anyhow::Result<()> {
        let el = self
            .elements
            .get_mut(name)
            .with_context(|| format!("no element {name:?}"))?;
        if el.role != Role::SiteRouter {
            bail!("{name:?} is not a site router");
        }
        el.up = false;
        Ok(())
    }

    /// The partition healed: the site router's tunnel is back.
    pub fn restore_site_router(&mut self, name: &str)
        -> anyhow::Result<()> {
        let el = self
            .elements
            .get_mut(name)
            .with_context(|| format!("no element {name:?}"))?;
        if el.role != Role::SiteRouter {
            bail!("{name:?} is not a site router");
        }
        el.up = true;
        Ok(())
    }

    fn rehome_clients_of(&mut self, cp_name: &str) -> Vec<String> {
        let failed_idx = match self.cps.iter().position(|c| c == cp_name) {
            Some(i) => i,
            None => return Vec::new(),
        };
        let new_cp = self.first_live_cp();
        let mut rehomed = Vec::new();
        for el in self.elements.values_mut() {
            if el.via_cp == Some(failed_idx) {
                el.via_cp = new_cp;
                if new_cp.is_some() {
                    rehomed.push(el.name.clone());
                }
            }
        }
        rehomed
    }

    pub fn element(&self, name: &str) -> Option<&Element> {
        self.elements.get(name)
    }

    pub fn elements(&self) -> impl Iterator<Item = &Element> {
        self.elements.values()
    }

    pub fn cp_names(&self) -> &[String] {
        &self.cps
    }

    /// Resolve the overlay path between two elements as a list of element
    /// names (including endpoints). None if disconnected.
    pub fn element_path(&self, from: &str, to: &str)
        -> Option<Vec<String>> {
        let a = self.elements.get(from)?;
        let b = self.elements.get(to)?;
        if !a.up || !b.up {
            return None;
        }
        if from == to {
            return Some(vec![from.to_string()]);
        }
        // Same site and both own routed subnets there → pure LAN.
        if a.location == b.location {
            return Some(vec![from.to_string(), to.to_string()]);
        }
        // Shortest-path extension: direct tunnel between site routers.
        if self.shortest_path
            && a.role != Role::CentralPoint
            && b.role != Role::CentralPoint
        {
            return Some(vec![from.to_string(), to.to_string()]);
        }
        // Star routing: a → its CP → b (collapse duplicates when an
        // endpoint *is* the CP).
        let cp_of = |e: &Element| -> Option<String> {
            match e.role {
                Role::CentralPoint => Some(e.name.clone()),
                _ => {
                    let idx = e.via_cp?;
                    let cp = self.cps.get(idx)?;
                    self.elements.get(cp).filter(|c| c.up)?;
                    Some(cp.clone())
                }
            }
        };
        let cp_a = cp_of(a)?;
        let cp_b = cp_of(b)?;
        let mut path = vec![from.to_string()];
        if cp_a != *from {
            path.push(cp_a.clone());
        }
        if cp_b != cp_a {
            // Two different CPs: traffic crosses CP-to-CP (redundant star
            // with split clients).
            path.push(cp_b.clone());
        }
        if *to != *path.last().unwrap() {
            path.push(to.to_string());
        }
        Some(path)
    }

    /// Are two elements mutually reachable over the overlay?
    pub fn is_connected(&self, a: &str, b: &str) -> bool {
        self.element_path(a, b).is_some()
    }

    /// Turn an element path into netsim overlay hops (tunnelled when the
    /// hop crosses sites, clear LAN hop otherwise).
    pub fn hops(&self, net: &Network, path: &[String])
        -> anyhow::Result<Vec<OverlayHop>> {
        let mut hops = Vec::new();
        for w in path.windows(2) {
            let a = self.elements.get(&w[0])
                .with_context(|| format!("no element {:?}", w[0]))?;
            let b = self.elements.get(&w[1])
                .with_context(|| format!("no element {:?}", w[1]))?;
            let link = net
                .link(a.location, b.location)
                .context("locations unreachable in underlay")?;
            let tunnel = if a.location == b.location {
                None
            } else {
                Some(self.cipher)
            };
            hops.push(OverlayHop { link, tunnel });
        }
        Ok(hops)
    }

    /// End-to-end one-way latency between elements, seconds.
    pub fn latency(&self, net: &Network, from: &str, to: &str)
        -> Option<f64> {
        let path = self.element_path(from, to)?;
        let hops = self.hops(net, &path).ok()?;
        Some(hops.iter().map(|h| {
            h.link.latency_s
                + h.tunnel.map(|c| c.hop_latency_s()).unwrap_or(0.0)
        }).sum())
    }

    /// Steady-state throughput between elements, bytes/s, accounting for
    /// CP crypto fan-in: the CP shares its cipher capacity across the
    /// `concurrent_flows` currently traversing it.
    pub fn throughput(&self, net: &Network, from: &str, to: &str,
                      concurrent_flows: u32) -> Option<f64> {
        let path = self.element_path(from, to)?;
        let hops = self.hops(net, &path).ok()?;
        let raw = crate::netsim::path_throughput(&hops);
        let crosses_cp = path.iter().any(|n| {
            self.elements.get(n).map(|e| e.role == Role::CentralPoint)
                .unwrap_or(false)
        }) && path.len() > 2;
        if crosses_cp && concurrent_flows > 1 {
            Some(raw / concurrent_flows as f64)
        } else {
            Some(raw)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::LinkSpec;

    fn net3() -> (Network, NetId, NetId, NetId) {
        let mut n = Network::new();
        let a = n.add_location("cesnet");
        let b = n.add_location("aws");
        let c = n.add_location("cloud3");
        n.set_link(a, b, LinkSpec::transatlantic());
        n.set_link(a, c, LinkSpec::wan());
        n.set_link(b, c, LinkSpec::transatlantic());
        (n, a, b, c)
    }

    fn star(a: NetId, b: NetId) -> Overlay {
        let mut o = Overlay::new(Cipher::Aes256Gcm);
        o.add_central_point("fe", a, 0x0A000000, SimTime(0.0)).unwrap();
        o.add_site_router("vr-aws", b, 0x0A010000, SimTime(1.0)).unwrap();
        o
    }

    #[test]
    fn star_paths() {
        let (_, a, b, _) = net3();
        let o = star(a, b);
        // Router to CP is a single tunnel hop.
        assert_eq!(o.element_path("vr-aws", "fe").unwrap(),
                   vec!["vr-aws".to_string(), "fe".to_string()]);
        // CP to router likewise.
        assert_eq!(o.element_path("fe", "vr-aws").unwrap().len(), 2);
        assert!(o.is_connected("fe", "vr-aws"));
    }

    #[test]
    fn cross_site_routers_go_via_cp() {
        let (_, a, b, c) = net3();
        let mut o = star(a, b);
        o.add_site_router("vr-3", c, 0x0A020000, SimTime(2.0)).unwrap();
        let p = o.element_path("vr-aws", "vr-3").unwrap();
        assert_eq!(p, vec!["vr-aws".to_string(), "fe".to_string(),
                           "vr-3".to_string()]);
    }

    #[test]
    fn shortest_path_extension_bypasses_cp() {
        let (_, a, b, c) = net3();
        let mut o = star(a, b);
        o.add_site_router("vr-3", c, 0x0A020000, SimTime(2.0)).unwrap();
        o.shortest_path = true;
        let p = o.element_path("vr-aws", "vr-3").unwrap();
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn latency_reflects_cipher_and_hops(){
        let (net, a, b, c) = net3();
        let mut o = star(a, b);
        o.add_site_router("vr-3", c, 0x0A020000, SimTime(2.0)).unwrap();
        let via_cp = o.latency(&net, "vr-aws", "vr-3").unwrap();
        o.shortest_path = true;
        let direct = o.latency(&net, "vr-aws", "vr-3").unwrap();
        assert!(direct < via_cp, "{direct} !< {via_cp}");
    }

    #[test]
    fn redundant_star_failover_and_restore() {
        let (_, a, b, c) = net3();
        let mut o = Overlay::new(Cipher::Aes128Gcm);
        o.add_central_point("cp1", a, 0x0A000000, SimTime(0.0)).unwrap();
        o.add_central_point("cp2", b, 0x0A010000, SimTime(0.0)).unwrap();
        o.add_site_router("vr-3", c, 0x0A020000, SimTime(1.0)).unwrap();
        assert_eq!(o.element("vr-3").unwrap().via_cp, Some(0));

        let rehomed = o.fail_central_point("cp1", SimTime(10.0)).unwrap();
        assert_eq!(rehomed, vec!["vr-3".to_string()]);
        assert_eq!(o.element("vr-3").unwrap().via_cp, Some(1));
        assert!(o.is_connected("vr-3", "cp2"));

        // Restore: clients stay on the backup (hot-backup semantics).
        o.restore_central_point("cp1").unwrap();
        assert_eq!(o.element("vr-3").unwrap().via_cp, Some(1));
    }

    #[test]
    fn site_router_partition_and_heal() {
        let (_, a, b, _) = net3();
        let mut o = star(a, b);
        o.fail_site_router("vr-aws").unwrap();
        assert!(!o.is_connected("vr-aws", "fe"));
        assert!(!o.element("vr-aws").unwrap().up);
        o.restore_site_router("vr-aws").unwrap();
        assert!(o.is_connected("vr-aws", "fe"));
        // Certificate survived the partition — no re-enrolment needed.
        assert!(o.ca.verify("vr-aws"));
        // Role checks: the CP is not a site router.
        assert!(o.fail_site_router("fe").is_err());
        assert!(o.restore_site_router("missing").is_err());
    }

    #[test]
    fn single_star_partition_on_cp_failure() {
        let (_, a, b, _) = net3();
        let mut o = star(a, b);
        let rehomed = o.fail_central_point("fe", SimTime(5.0)).unwrap();
        assert!(rehomed.is_empty());
        assert!(!o.is_connected("vr-aws", "fe"));
    }

    #[test]
    fn standalone_node_connects_directly() {
        let (net, a, b, c) = net3();
        let mut o = star(a, b);
        let secs = o.add_standalone("laptop", c, SimTime(3.0)).unwrap();
        assert!(secs > 0.0);
        let p = o.element_path("laptop", "vr-aws").unwrap();
        assert_eq!(p, vec!["laptop".to_string(), "fe".to_string(),
                           "vr-aws".to_string()]);
        assert!(o.latency(&net, "laptop", "fe").unwrap() > 0.0);
        assert_eq!(o.element("laptop").unwrap().subnet_base, None);
    }

    #[test]
    fn duplicate_names_and_missing_cp_rejected() {
        let (_, a, b, _) = net3();
        let mut empty = Overlay::new(Cipher::Plain);
        assert!(empty.add_site_router("vr", b, 1, SimTime(0.0)).is_err());
        let mut o = star(a, b);
        assert!(o.add_site_router("vr-aws", b, 2, SimTime(0.0)).is_err());
        assert!(o.add_central_point("fe", a, 3, SimTime(0.0)).is_err());
    }

    #[test]
    fn cp_fan_in_divides_throughput() {
        let (net, a, b, c) = net3();
        let mut o = star(a, b);
        o.add_site_router("vr-3", c, 0x0A020000, SimTime(2.0)).unwrap();
        let solo = o.throughput(&net, "vr-aws", "vr-3", 1).unwrap();
        let shared = o.throughput(&net, "vr-aws", "vr-3", 4).unwrap();
        assert!((solo / shared - 4.0).abs() < 1e-9);
    }

    #[test]
    fn remove_revokes_and_reroutes() {
        let (_, a, b, _) = net3();
        let mut o = star(a, b);
        o.remove("vr-aws").unwrap();
        assert!(o.element("vr-aws").is_none());
        assert!(!o.ca.verify("vr-aws"));
        // Name can be reused after removal.
        o.add_site_router("vr-aws", b, 0x0A030000, SimTime(9.0)).unwrap();
    }

    #[test]
    fn same_site_traffic_stays_on_lan() {
        let (net, a, b, _) = net3();
        let mut o = star(a, b);
        o.add_standalone("node-local", a, SimTime(1.0)).unwrap();
        let path = o.element_path("node-local", "fe").unwrap();
        let hops = o.hops(&net, &path).unwrap();
        assert_eq!(hops.len(), 1);
        assert!(hops[0].tunnel.is_none(), "LAN hop must not be tunnelled");
    }
}
