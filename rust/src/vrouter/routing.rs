//! Explicit routing tables for overlay elements (§3.5: each vRouter
//! "routes traffic between nodes in the local private network and remote
//! sites", forwarding everything else to the central point — exactly a
//! physical MAN router's FIB, which §5 calls out as the design's
//! deliberately familiar mental model).

use std::collections::BTreeMap;

use crate::cloudsim::ip_to_string;

use super::{Overlay, Role};

/// One routing-table entry.
#[derive(Debug, Clone, PartialEq)]
pub enum NextHop {
    /// Deliver on the local L2 segment.
    Local,
    /// Send through the tunnel to the named element.
    Via(String),
    /// Default route (everything not matched) via the named element.
    Default(String),
}

/// A /24-granular routing table for one element.
#[derive(Debug, Clone, Default)]
pub struct RouteTable {
    /// subnet base → next hop.
    pub routes: BTreeMap<u32, NextHop>,
    pub default: Option<NextHop>,
}

impl RouteTable {
    /// Look up the next hop for a destination IP.
    pub fn lookup(&self, dst_ip: u32) -> Option<&NextHop> {
        let subnet = dst_ip & 0xFFFF_FF00;
        self.routes.get(&subnet).or(self.default.as_ref())
    }

    /// Render as `ip route`-style text (for reports/debugging).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (subnet, hop) in &self.routes {
            out.push_str(&format!("{}/24 {}\n", ip_to_string(*subnet),
                                  render_hop(hop)));
        }
        if let Some(d) = &self.default {
            out.push_str(&format!("default {}\n", render_hop(d)));
        }
        out
    }
}

fn render_hop(hop: &NextHop) -> String {
    match hop {
        NextHop::Local => "dev eth0 (local)".to_string(),
        NextHop::Via(v) => format!("via tun0 -> {v}"),
        NextHop::Default(v) => format!("via tun0 -> {v} (default)"),
    }
}

/// Build the routing table a given element would install, from the
/// overlay's current topology.
///
/// * central point: a route to every client's registered subnet via that
///   client's tunnel; its own subnet is local.
/// * site router: its own subnet local; everything else defaults to its
///   CP (or, with the shortest-path extension, direct routes to sibling
///   routers' subnets).
/// * standalone node: default to its CP.
pub fn build_table(overlay: &Overlay, element: &str)
    -> anyhow::Result<RouteTable> {
    let el = overlay
        .element(element)
        .ok_or_else(|| anyhow::anyhow!("no element {element:?}"))?;
    let mut table = RouteTable::default();

    if let Some(own) = el.subnet_base {
        table.routes.insert(own, NextHop::Local);
    }

    match el.role {
        Role::CentralPoint => {
            // Routes to every connected client subnet.
            for other in overlay.elements() {
                if other.name == el.name || !other.up {
                    continue;
                }
                if let (Some(base), Some(_)) =
                    (other.subnet_base, other.via_cp)
                {
                    table.routes.insert(
                        base, NextHop::Via(other.name.clone()));
                }
            }
        }
        Role::SiteRouter => {
            if overlay.shortest_path {
                // Direct tunnels to sibling routers (§5 extension).
                for other in overlay.elements() {
                    if other.name == el.name
                        || other.role != Role::SiteRouter
                        || !other.up
                    {
                        continue;
                    }
                    if let Some(base) = other.subnet_base {
                        table.routes.insert(
                            base, NextHop::Via(other.name.clone()));
                    }
                }
            }
            if let Some(cp_idx) = el.via_cp {
                let cp = overlay.cp_names()[cp_idx].clone();
                table.default = Some(NextHop::Default(cp));
            }
        }
        Role::Standalone => {
            if let Some(cp_idx) = el.via_cp {
                let cp = overlay.cp_names()[cp_idx].clone();
                table.default = Some(NextHop::Default(cp));
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Cipher, NetId};
    use crate::sim::SimTime;

    fn overlay() -> Overlay {
        let mut ov = Overlay::new(Cipher::Aes256Gcm);
        ov.add_central_point("fe", NetId(0), 0x0A00_0000, SimTime(0.0))
            .unwrap();
        ov.add_site_router("vr-aws", NetId(1), 0x0A01_0000, SimTime(1.0))
            .unwrap();
        ov.add_site_router("vr-bari", NetId(2), 0x0A02_0000, SimTime(2.0))
            .unwrap();
        ov.add_standalone("laptop", NetId(3), SimTime(3.0)).unwrap();
        ov
    }

    #[test]
    fn cp_routes_every_client_subnet() {
        let ov = overlay();
        let t = build_table(&ov, "fe").unwrap();
        assert_eq!(t.routes[&0x0A00_0000], NextHop::Local);
        assert_eq!(t.routes[&0x0A01_0000],
                   NextHop::Via("vr-aws".into()));
        assert_eq!(t.routes[&0x0A02_0000],
                   NextHop::Via("vr-bari".into()));
        // Lookup by host address matches the /24.
        assert_eq!(t.lookup(0x0A01_0007),
                   Some(&NextHop::Via("vr-aws".into())));
    }

    #[test]
    fn site_router_defaults_to_cp() {
        let ov = overlay();
        let t = build_table(&ov, "vr-aws").unwrap();
        assert_eq!(t.routes[&0x0A01_0000], NextHop::Local);
        assert_eq!(t.default, Some(NextHop::Default("fe".into())));
        // Remote subnet falls through to the default.
        assert_eq!(t.lookup(0x0A02_0005),
                   Some(&NextHop::Default("fe".into())));
        let text = t.render();
        assert!(text.contains("10.1.0.0/24"));
        assert!(text.contains("default"));
    }

    #[test]
    fn shortest_path_installs_direct_routes() {
        let mut ov = overlay();
        ov.shortest_path = true;
        let t = build_table(&ov, "vr-aws").unwrap();
        assert_eq!(t.routes[&0x0A02_0000],
                   NextHop::Via("vr-bari".into()));
        // Default still points at the CP for everything else.
        assert_eq!(t.default, Some(NextHop::Default("fe".into())));
    }

    #[test]
    fn standalone_has_default_only() {
        let ov = overlay();
        let t = build_table(&ov, "laptop").unwrap();
        assert!(t.routes.is_empty());
        assert_eq!(t.default, Some(NextHop::Default("fe".into())));
    }

    #[test]
    fn tables_and_paths_agree() {
        // Consistency: for every pair (a, b) with subnets, the first hop
        // in element_path(a, b) equals a's table lookup of b's subnet.
        let ov = overlay();
        let named: Vec<&str> = vec!["fe", "vr-aws", "vr-bari"];
        for a in &named {
            let table = build_table(&ov, a).unwrap();
            for b in &named {
                if a == b {
                    continue;
                }
                let dst = ov.element(b).unwrap().subnet_base.unwrap() + 5;
                let path = ov.element_path(a, b).unwrap();
                let expected_next = path[1].clone();
                let hop = table.lookup(dst).unwrap();
                let via = match hop {
                    NextHop::Local => a.to_string(),
                    NextHop::Via(v) | NextHop::Default(v) => v.clone(),
                };
                assert_eq!(via, expected_next,
                           "{a}->{b}: table {hop:?} vs path {path:?}");
            }
        }
    }
}
