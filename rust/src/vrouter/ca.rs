//! Simulated certificate authority for the overlay (§3.5.5).
//!
//! The real stack reuses OpenVPN's bundled Easy-RSA at the central point:
//! certificates are generated at the CP, the IM retrieves them through
//! its callback, and client subjects are pre-registered so each vRouter
//! can be assigned a *static* subnet. This module reproduces those
//! semantics (issuance, registration, revocation, static subnet maps) —
//! no actual cryptography, which the simulation does not need.

use std::collections::HashMap;

use anyhow::{bail, Context};

use crate::sim::SimTime;

/// An issued client/server certificate.
#[derive(Debug, Clone)]
pub struct Certificate {
    pub serial: u64,
    /// X.509 subject CN, e.g. "vrouter-aws" or "standalone-laptop".
    pub subject: String,
    pub issued_at: SimTime,
    pub revoked: bool,
}

/// Easy-RSA-like CA living on the central point.
#[derive(Debug, Default)]
pub struct CertificateAuthority {
    next_serial: u64,
    issued: Vec<Certificate>,
    /// subject → statically assigned /24 (network base address).
    registrations: HashMap<String, u32>,
}

impl CertificateAuthority {
    pub fn new() -> CertificateAuthority {
        CertificateAuthority::default()
    }

    /// Issue a certificate for `subject`. Duplicate subjects are rejected
    /// (one identity per networking element).
    pub fn issue(&mut self, subject: &str, t: SimTime)
        -> anyhow::Result<Certificate> {
        if self.issued.iter().any(|c| c.subject == subject && !c.revoked) {
            bail!("subject {subject:?} already holds a live certificate");
        }
        let cert = Certificate {
            serial: self.next_serial,
            subject: subject.to_string(),
            issued_at: t,
            revoked: false,
        };
        self.next_serial += 1;
        self.issued.push(cert.clone());
        Ok(cert)
    }

    /// Pre-register a client subject with its static subnet, so the CP
    /// "makes it possible for the orchestration layer to pre-determine
    /// which client vRouter will be assigned which subnet".
    pub fn register_client(&mut self, subject: &str, subnet_base: u32)
        -> anyhow::Result<()> {
        if !self.has_live_cert(subject) {
            bail!("cannot register {subject:?}: no live certificate");
        }
        if self
            .registrations
            .values()
            .any(|&s| s == subnet_base)
        {
            bail!("subnet already registered to another subject");
        }
        self.registrations.insert(subject.to_string(), subnet_base);
        Ok(())
    }

    /// The static subnet registered for a subject (used by the CP when
    /// the client connects).
    pub fn subnet_for(&self, subject: &str) -> Option<u32> {
        self.registrations.get(subject).copied()
    }

    /// Authenticate an incoming VPN connection.
    pub fn verify(&self, subject: &str) -> bool {
        self.has_live_cert(subject)
    }

    pub fn revoke(&mut self, subject: &str) -> anyhow::Result<()> {
        let cert = self
            .issued
            .iter_mut()
            .find(|c| c.subject == subject && !c.revoked)
            .with_context(|| format!("no live certificate for {subject:?}"))?;
        cert.revoked = true;
        self.registrations.remove(subject);
        Ok(())
    }

    fn has_live_cert(&self, subject: &str) -> bool {
        self.issued.iter().any(|c| c.subject == subject && !c.revoked)
    }

    pub fn issued_count(&self) -> usize {
        self.issued.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_register_verify() {
        let mut ca = CertificateAuthority::new();
        let c = ca.issue("vrouter-aws", SimTime(1.0)).unwrap();
        assert_eq!(c.serial, 0);
        assert!(ca.verify("vrouter-aws"));
        assert!(!ca.verify("impostor"));
        ca.register_client("vrouter-aws", 0x0A010000).unwrap();
        assert_eq!(ca.subnet_for("vrouter-aws"), Some(0x0A010000));
    }

    #[test]
    fn duplicate_subject_rejected_until_revoked() {
        let mut ca = CertificateAuthority::new();
        ca.issue("x", SimTime(0.0)).unwrap();
        assert!(ca.issue("x", SimTime(1.0)).is_err());
        ca.revoke("x").unwrap();
        assert!(!ca.verify("x"));
        ca.issue("x", SimTime(2.0)).unwrap(); // re-issue after revocation
        assert!(ca.verify("x"));
    }

    #[test]
    fn registration_requires_cert_and_unique_subnet() {
        let mut ca = CertificateAuthority::new();
        assert!(ca.register_client("ghost", 1).is_err());
        ca.issue("a", SimTime(0.0)).unwrap();
        ca.issue("b", SimTime(0.0)).unwrap();
        ca.register_client("a", 7).unwrap();
        assert!(ca.register_client("b", 7).is_err());
        ca.register_client("b", 8).unwrap();
    }

    #[test]
    fn revocation_clears_registration() {
        let mut ca = CertificateAuthority::new();
        ca.issue("a", SimTime(0.0)).unwrap();
        ca.register_client("a", 7).unwrap();
        ca.revoke("a").unwrap();
        assert_eq!(ca.subnet_for("a"), None);
        assert!(ca.revoke("a").is_err());
    }
}
