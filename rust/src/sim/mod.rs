//! Discrete-event simulation core.
//!
//! The paper's use case spans 5 h 40 m of wall-clock time on two real
//! clouds; the simulator replays the same coordination logic in
//! milliseconds under a virtual clock, or — via [`RealTimeRunner`] — in
//! scaled real time for demos.
//!
//! The engine comes in two tiers:
//!
//! * [`EventQueue`] — the classic single binary-heap queue ordered by
//!   `(time, sequence-number)`. Still the right tool for small worlds
//!   and micro-benchmarks.
//! * [`shard`] — the **sharded engine**: events carry a [`shard::ShardKey`]
//!   (one shard per cloud site, plus a *control* shard for orchestrator /
//!   CLUES / VPN traffic), each shard owns its own queue, and a
//!   deterministic merge — min time across shards with a fixed
//!   shard-order tiebreak — either replays serially (the *single-queue*
//!   reference mode) or dispatches site-local windows in parallel while
//!   control-shard events act as synchronization barriers. Both modes
//!   produce identical event streams; `tests/shard_equivalence.rs`
//!   proves it on randomized scenarios.
//!
//! Shared guarantees, both tiers:
//! * events are ordered by a **total** order (`f64::total_cmp`), and
//!   non-finite schedule times are rejected outright instead of silently
//!   collapsing the heap order,
//! * same-time events dispatch in schedule order (per shard),
//! * scheduled events can be cancelled, which the CLUES reproduction
//!   needs (the paper describes pending power-offs being cancelled when
//!   new jobs arrive early). Cancellation is **generation-slot** based:
//!   each scheduled event holds a reusable slot whose generation advances
//!   when the event fires or is cancelled, so the pop hot path performs
//!   no hashing and stale cancels of already-fired events are rejected
//!   without storing anything.

pub mod shard;

use std::fmt;

pub use shard::{run_merged, run_merged_until, MergedWorld, ShardEvent,
                ShardEventId, ShardKey, ShardedQueue};

/// Virtual time in seconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);

    pub fn secs(self) -> f64 {
        self.0
    }

    pub fn from_hms(h: u64, m: u64, s: u64) -> SimTime {
        SimTime((h * 3600 + m * 60 + s) as f64)
    }

    pub fn add(self, d: f64) -> SimTime {
        SimTime(self.0 + d)
    }

    /// `hh:mm:ss` rendering used by figure outputs.
    pub fn hms(self) -> String {
        let total = self.0.max(0.0).round() as u64;
        format!("{:02}:{:02}:{:02}", total / 3600, (total / 60) % 60,
                total % 60)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hms())
    }
}

/// Handle to a scheduled event; used for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId {
    slot: u32,
    gen: u32,
}

/// The event queue + virtual clock (single-queue tier): one
/// [`shard::ShardHeap`] plus the clock.
///
/// Cancellation uses generation slots: scheduling claims a slot (reusing
/// freed ones) and stamps the entry with the slot's current generation;
/// firing or cancelling advances the generation, so a stale handle can
/// never match again. Memory is bounded by the maximum number of
/// *concurrently* scheduled events, and neither `pop` nor `cancel`
/// hashes anything.
pub struct EventQueue<E> {
    heap: shard::ShardHeap<E>,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: shard::ShardHeap::new(),
            now: SimTime::ZERO,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched so far (perf counters).
    pub fn dispatched(&self) -> u64 {
        self.heap.dispatched()
    }

    /// Events scheduled but not yet fired or cancelled.
    pub fn live_count(&self) -> usize {
        self.heap.live_count()
    }

    /// Schedule `ev` after `delay` seconds (clamped at now for negatives).
    /// Non-finite delays are a caller bug and are rejected loudly.
    pub fn schedule_in(&mut self, delay: f64, ev: E) -> EventId {
        let at = shard::delay_to_at(self.now, delay);
        self.schedule_at(at, ev)
    }

    /// Schedule `ev` at absolute time `at` (clamped at now if in the
    /// past). Non-finite times are a caller bug and are rejected loudly.
    pub fn schedule_at(&mut self, at: SimTime, ev: E) -> EventId {
        let at = shard::clamp_schedule_time(self.now, at);
        let (slot, gen) = self.heap.schedule(at, ev);
        EventId { slot, gen }
    }

    /// Cancel a scheduled event. Returns false if it already fired or was
    /// already cancelled — in both cases without storing anything.
    pub fn cancel(&mut self, id: EventId) -> bool {
        self.heap.cancel(id.slot, id.gen)
    }

    /// Pop the next live event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        match self.heap.pop() {
            Some((t, _seq, ev)) => {
                self.now = t;
                Some((t, ev))
            }
            None => None,
        }
    }

    /// Time of the next live event without popping it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.heap.peek().map(|(t, _seq)| t)
    }

    pub fn is_empty(&mut self) -> bool {
        self.peek_time().is_none()
    }
}

/// A simulation world reacts to events and may schedule more.
pub trait World {
    type Event;

    /// Handle one event at virtual time `t`.
    fn handle(
        &mut self,
        t: SimTime,
        ev: Self::Event,
        q: &mut EventQueue<Self::Event>,
    );
}

/// Drive `world` until the queue drains or `horizon` is exceeded.
/// Returns the final virtual time.
pub fn run_until<W: World>(
    world: &mut W,
    q: &mut EventQueue<W::Event>,
    horizon: SimTime,
) -> SimTime {
    while let Some(at) = q.peek_time() {
        if at.0 > horizon.0 {
            break;
        }
        let (t, ev) = q.pop().expect("peeked event vanished");
        world.handle(t, ev, q);
    }
    q.now()
}

/// Drive `world` until the queue drains completely.
pub fn run_to_completion<W: World>(
    world: &mut W,
    q: &mut EventQueue<W::Event>,
) -> SimTime {
    run_until(world, q, SimTime(f64::INFINITY))
}

/// Real-time adapter: dispatches the same event queue against the wall
/// clock, compressed by `speedup` (e.g. 60.0 → one virtual minute per
/// real second). Used by the demo mode of the CLI.
pub struct RealTimeRunner {
    pub speedup: f64,
}

impl RealTimeRunner {
    pub fn run<W: World>(
        &self,
        world: &mut W,
        q: &mut EventQueue<W::Event>,
        horizon: SimTime,
    ) -> SimTime {
        let start = std::time::Instant::now();
        while let Some(at) = q.peek_time() {
            if at.0 > horizon.0 {
                break;
            }
            let target = at.0 / self.speedup;
            let elapsed = start.elapsed().as_secs_f64();
            if target > elapsed {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    target - elapsed,
                ));
            }
            let (t, ev) = q.pop().expect("peeked event vanished");
            world.handle(t, ev, q);
        }
        q.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(f64, u32)>,
    }

    impl World for Recorder {
        type Event = u32;
        fn handle(&mut self, t: SimTime, ev: u32, q: &mut EventQueue<u32>) {
            self.seen.push((t.0, ev));
            if ev == 1 {
                // Cascading event.
                q.schedule_in(5.0, 100);
            }
        }
    }

    #[test]
    fn dispatch_order_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10.0), 2);
        q.schedule_at(SimTime(5.0), 1);
        q.schedule_at(SimTime(10.0), 3); // same time as `2`, later seq
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(5.0, 1), (10.0, 2), (10.0, 3), (10.0, 100)]);
    }

    #[test]
    fn cascaded_events_advance_clock() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 1);
        let mut w = Recorder { seen: vec![] };
        let end = run_to_completion(&mut w, &mut q);
        assert_eq!(end.0, 6.0);
        assert_eq!(q.dispatched(), 2);
    }

    #[test]
    fn cancellation_suppresses_dispatch() {
        let mut q = EventQueue::new();
        let a = q.schedule_in(1.0, 7);
        q.schedule_in(2.0, 8);
        assert!(q.cancel(a));
        assert!(!q.cancel(a)); // double-cancel is a no-op
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(2.0, 8)]);
    }

    #[test]
    fn stale_cancel_of_fired_event_is_rejected_without_leaking() {
        let mut q = EventQueue::new();
        let a = q.schedule_in(1.0, 7);
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(1.0, 7)]);
        // The event already dispatched: cancelling it must fail, and the
        // slot store must be fully recycled (nothing live).
        assert!(!q.cancel(a));
        assert_eq!(q.live_count(), 0);
        assert!(q.is_empty());
        // Never-scheduled ids are rejected too.
        assert!(!q.cancel(EventId { slot: 999, gen: 0 }));
    }

    #[test]
    fn cancelled_then_popped_entry_clears_slot() {
        let mut q: EventQueue<u32> = EventQueue::new();
        let a = q.schedule_in(1.0, 1);
        q.schedule_in(2.0, 2);
        assert!(q.cancel(a));
        assert!(!q.cancel(a));
        let (_, ev) = q.pop().unwrap();
        assert_eq!(ev, 2);
        assert_eq!(q.live_count(), 0);
    }

    #[test]
    fn slots_are_reused_with_fresh_generations() {
        let mut q: EventQueue<u32> = EventQueue::new();
        let a = q.schedule_in(1.0, 1);
        assert!(q.cancel(a));
        // The freed slot is reclaimed; the stale handle must not be able
        // to cancel the new occupant.
        let b = q.schedule_in(2.0, 2);
        assert!(!q.cancel(a));
        assert_eq!(q.live_count(), 1);
        let (_, ev) = q.pop().unwrap();
        assert_eq!(ev, 2);
        assert!(!q.cancel(b)); // already fired
        // Bounded store: two schedules, one slot.
        assert_eq!(q.heap.slot_capacity(), 1);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn nan_schedule_time_is_rejected() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(f64::NAN), 1);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn infinite_delay_is_rejected() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_in(f64::INFINITY, 1);
    }

    #[test]
    fn horizon_stops_early() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 5);
        q.schedule_in(100.0, 6);
        let mut w = Recorder { seen: vec![] };
        run_until(&mut w, &mut q, SimTime(10.0));
        assert_eq!(w.seen.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10.0), 1);
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        // Now at 15 (cascade); scheduling "at 3" fires immediately.
        q.schedule_at(SimTime(3.0), 9);
        let (t, ev) = q.pop().unwrap();
        assert_eq!(ev, 9);
        assert!(t.0 >= 10.0);
    }

    #[test]
    fn hms_formatting() {
        assert_eq!(SimTime::from_hms(5, 40, 0).hms(), "05:40:00");
        assert_eq!(SimTime(61.4).hms(), "00:01:01");
        assert_eq!(SimTime::from_hms(5, 40, 0).secs(), 20400.0);
    }

    #[test]
    fn realtime_runner_respects_speedup() {
        let mut q = EventQueue::new();
        q.schedule_in(0.2, 1); // cascades one more at +5s virtual
        let mut w = Recorder { seen: vec![] };
        let t0 = std::time::Instant::now();
        RealTimeRunner { speedup: 100.0 }.run(&mut w, &mut q,
                                              SimTime(1000.0));
        let real = t0.elapsed().as_secs_f64();
        assert_eq!(w.seen.len(), 2);
        // 5.2 virtual seconds at 100x ≈ 52 ms real.
        assert!(real >= 0.04 && real < 1.0, "real={real}");
    }
}
