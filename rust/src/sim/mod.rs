//! Discrete-event simulation engine.
//!
//! The paper's use case spans 5 h 40 m of wall-clock time on two real
//! clouds; the simulator replays the same coordination logic in
//! milliseconds under a virtual clock, or — via [`RealTimeRunner`] — in
//! scaled real time for demos.
//!
//! The engine is deliberately minimal and deterministic:
//! * events are ordered by `(time, sequence-number)` so same-time events
//!   dispatch in schedule order,
//! * scheduled events can be cancelled, which the CLUES reproduction
//!   needs (the paper describes pending power-offs being cancelled when
//!   new jobs arrive early); stale cancels of already-fired events are
//!   rejected without storing anything.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};
use std::fmt;

/// Virtual time in seconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);

    pub fn secs(self) -> f64 {
        self.0
    }

    pub fn from_hms(h: u64, m: u64, s: u64) -> SimTime {
        SimTime((h * 3600 + m * 60 + s) as f64)
    }

    pub fn add(self, d: f64) -> SimTime {
        SimTime(self.0 + d)
    }

    /// `hh:mm:ss` rendering used by figure outputs.
    pub fn hms(self) -> String {
        let total = self.0.max(0.0).round() as u64;
        format!("{:02}:{:02}:{:02}", total / 3600, (total / 60) % 60,
                total % 60)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hms())
    }
}

/// Handle to a scheduled event; used for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

struct Entry<E> {
    at: SimTime,
    seq: u64,
    id: EventId,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock.
///
/// Cancellation is tracked through a *live* set (ids scheduled but not
/// yet dispatched or cancelled) rather than a tombstone set: cancelling
/// an id whose event already fired is a `false` no-op that stores
/// nothing, so long replays with many stale cancels cannot leak memory,
/// and the set's size is always bounded by the heap's.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    live: HashSet<EventId>,
    seq: u64,
    now: SimTime,
    dispatched: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            live: HashSet::new(),
            seq: 0,
            now: SimTime::ZERO,
            dispatched: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched so far (perf counters).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Schedule `ev` after `delay` seconds (clamped at now for negatives).
    pub fn schedule_in(&mut self, delay: f64, ev: E) -> EventId {
        let at = self.now.add(delay.max(0.0));
        self.schedule_at(at, ev)
    }

    /// Schedule `ev` at absolute time `at` (clamped at now if in the past).
    pub fn schedule_at(&mut self, at: SimTime, ev: E) -> EventId {
        let at = if at.0 < self.now.0 { self.now } else { at };
        let id = EventId(self.seq);
        self.heap.push(Entry { at, seq: self.seq, id, ev });
        self.live.insert(id);
        self.seq += 1;
        id
    }

    /// Cancel a scheduled event. Returns false if it already fired or was
    /// already cancelled — in both cases without storing anything.
    pub fn cancel(&mut self, id: EventId) -> bool {
        self.live.remove(&id)
    }

    /// Pop the next live event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        while let Some(entry) = self.heap.pop() {
            if !self.live.remove(&entry.id) {
                continue; // cancelled while queued
            }
            self.now = entry.at;
            self.dispatched += 1;
            return Some((entry.at, entry.ev));
        }
        None
    }

    /// Time of the next live event without popping it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        while let Some(entry) = self.heap.peek() {
            if !self.live.contains(&entry.id) {
                self.heap.pop();
                continue;
            }
            return Some(entry.at);
        }
        None
    }

    pub fn is_empty(&mut self) -> bool {
        self.peek_time().is_none()
    }
}

/// A simulation world reacts to events and may schedule more.
pub trait World {
    type Event;

    /// Handle one event at virtual time `t`.
    fn handle(
        &mut self,
        t: SimTime,
        ev: Self::Event,
        q: &mut EventQueue<Self::Event>,
    );
}

/// Drive `world` until the queue drains or `horizon` is exceeded.
/// Returns the final virtual time.
pub fn run_until<W: World>(
    world: &mut W,
    q: &mut EventQueue<W::Event>,
    horizon: SimTime,
) -> SimTime {
    while let Some(at) = q.peek_time() {
        if at.0 > horizon.0 {
            break;
        }
        let (t, ev) = q.pop().expect("peeked event vanished");
        world.handle(t, ev, q);
    }
    q.now()
}

/// Drive `world` until the queue drains completely.
pub fn run_to_completion<W: World>(
    world: &mut W,
    q: &mut EventQueue<W::Event>,
) -> SimTime {
    run_until(world, q, SimTime(f64::INFINITY))
}

/// Real-time adapter: dispatches the same event queue against the wall
/// clock, compressed by `speedup` (e.g. 60.0 → one virtual minute per
/// real second). Used by the demo mode of the CLI.
pub struct RealTimeRunner {
    pub speedup: f64,
}

impl RealTimeRunner {
    pub fn run<W: World>(
        &self,
        world: &mut W,
        q: &mut EventQueue<W::Event>,
        horizon: SimTime,
    ) -> SimTime {
        let start = std::time::Instant::now();
        while let Some(at) = q.peek_time() {
            if at.0 > horizon.0 {
                break;
            }
            let target = at.0 / self.speedup;
            let elapsed = start.elapsed().as_secs_f64();
            if target > elapsed {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    target - elapsed,
                ));
            }
            let (t, ev) = q.pop().expect("peeked event vanished");
            world.handle(t, ev, q);
        }
        q.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(f64, u32)>,
    }

    impl World for Recorder {
        type Event = u32;
        fn handle(&mut self, t: SimTime, ev: u32, q: &mut EventQueue<u32>) {
            self.seen.push((t.0, ev));
            if ev == 1 {
                // Cascading event.
                q.schedule_in(5.0, 100);
            }
        }
    }

    #[test]
    fn dispatch_order_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10.0), 2);
        q.schedule_at(SimTime(5.0), 1);
        q.schedule_at(SimTime(10.0), 3); // same time as `2`, later seq
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(5.0, 1), (10.0, 2), (10.0, 3), (10.0, 100)]);
    }

    #[test]
    fn cascaded_events_advance_clock() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 1);
        let mut w = Recorder { seen: vec![] };
        let end = run_to_completion(&mut w, &mut q);
        assert_eq!(end.0, 6.0);
        assert_eq!(q.dispatched(), 2);
    }

    #[test]
    fn cancellation_suppresses_dispatch() {
        let mut q = EventQueue::new();
        let a = q.schedule_in(1.0, 7);
        q.schedule_in(2.0, 8);
        assert!(q.cancel(a));
        assert!(!q.cancel(a)); // double-cancel is a no-op
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(2.0, 8)]);
    }

    #[test]
    fn stale_cancel_of_fired_event_is_rejected_without_leaking() {
        let mut q = EventQueue::new();
        let a = q.schedule_in(1.0, 7);
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        assert_eq!(w.seen, vec![(1.0, 7)]);
        // The event already dispatched: cancelling it must fail and must
        // not tombstone anything (the live set stays bounded by the
        // heap, which is empty here).
        assert!(!q.cancel(a));
        assert!(q.live.is_empty());
        assert!(q.is_empty());
        // Never-scheduled ids are rejected too.
        assert!(!q.cancel(EventId(999)));
    }

    #[test]
    fn cancelled_then_popped_entry_clears_live_set() {
        let mut q: EventQueue<u32> = EventQueue::new();
        let a = q.schedule_in(1.0, 1);
        q.schedule_in(2.0, 2);
        assert!(q.cancel(a));
        assert!(!q.cancel(a));
        let (_, ev) = q.pop().unwrap();
        assert_eq!(ev, 2);
        assert!(q.live.is_empty());
    }

    #[test]
    fn horizon_stops_early() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 5);
        q.schedule_in(100.0, 6);
        let mut w = Recorder { seen: vec![] };
        run_until(&mut w, &mut q, SimTime(10.0));
        assert_eq!(w.seen.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10.0), 1);
        let mut w = Recorder { seen: vec![] };
        run_to_completion(&mut w, &mut q);
        // Now at 15 (cascade); scheduling "at 3" fires immediately.
        let id = q.schedule_at(SimTime(3.0), 9);
        assert!(id.0 > 0);
        let (t, ev) = q.pop().unwrap();
        assert_eq!(ev, 9);
        assert!(t.0 >= 10.0);
    }

    #[test]
    fn hms_formatting() {
        assert_eq!(SimTime::from_hms(5, 40, 0).hms(), "05:40:00");
        assert_eq!(SimTime(61.4).hms(), "00:01:01");
        assert_eq!(SimTime::from_hms(5, 40, 0).secs(), 20400.0);
    }

    #[test]
    fn realtime_runner_respects_speedup() {
        let mut q = EventQueue::new();
        q.schedule_in(0.2, 1); // cascades one more at +5s virtual
        let mut w = Recorder { seen: vec![] };
        let t0 = std::time::Instant::now();
        RealTimeRunner { speedup: 100.0 }.run(&mut w, &mut q,
                                              SimTime(1000.0));
        let real = t0.elapsed().as_secs_f64();
        assert_eq!(w.seen.len(), 2);
        // 5.2 virtual seconds at 100x ≈ 52 ms real.
        assert!(real >= 0.04 && real < 1.0, "real={real}");
    }
}
