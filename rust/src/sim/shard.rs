//! Sharded discrete-event engine: one queue per cloud site plus a
//! control shard, merged deterministically, with optional parallel
//! replay of site-local event windows.
//!
//! ## Model
//!
//! Every event declares a [`ShardKey`] through the [`ShardEvent`] trait:
//! [`ShardKey::Site`]`(s)` for traffic local to cloud site `s` (boots,
//! job completions, crashes), [`ShardKey::Control`] for everything that
//! crosses sites — orchestrator updates, CLUES decisions, VPN/overlay
//! traffic. A [`ShardedQueue`] owns one [`ShardHeap`] per shard; each
//! heap orders its entries by `(time, per-shard sequence)` and cancels
//! through generation slots (no hashing on the pop path).
//!
//! ## Deterministic merge
//!
//! The global dispatch order is `(time, shard index, per-shard seq)`
//! with the control shard at index 0 — min-time across shards, fixed
//! shard-order tiebreak. This order is what both replay modes produce:
//!
//! * [`run_sharded_serial`] — the *single-queue engine*: pops one
//!   globally-minimal event at a time. Reference semantics.
//! * [`run_sharded`] — the *parallel engine*: control events run
//!   serially as synchronization barriers; between barriers, each site
//!   shard's window of events is drained on its own thread (scoped
//!   threads, `E: Send`). Site shards share no state, so any thread
//!   interleaving yields the same per-shard outcome, and cross-shard
//!   (control) emissions are buffered and flushed in origin dispatch
//!   order, reproducing the serial enqueue order exactly.
//!
//! The window bound is conservative-PDES style: a site window starting
//! at `T` extends to `min(next queued control event, T + lookahead)`,
//! where [`ControlPlane::lookahead`] is the world's minimum site→control
//! latency (in the paper's setting, inter-site WAN latency makes this a
//! natural, honest bound). Site handlers must emit control events at
//! least `lookahead` in the future ([`SiteCtx::emit_control_in`]
//! asserts it); with a zero lookahead the engine degrades gracefully to
//! single-queue stepping and stays exactly equivalent.
//!
//! ## Work stealing
//!
//! [`run_sharded`] assigns site shards to threads in fixed contiguous
//! chunks, so one *hot* shard (a skewed back-end mix concentrates most
//! of the workload on one site) serializes behind the cold shards that
//! share its chunk while other workers idle. [`run_sharded_stealing`]
//! fixes that: each busy shard's window `[T, barrier)` becomes one
//! sequential *chain*, all chains go onto a shared injector (a
//! mutex-protected deque), and every worker thread steals the next
//! ready chain — from any shard — the moment it finishes its previous
//! one. A hot shard therefore never waits behind cold shards, and cold
//! shards spread across the remaining workers.
//!
//! **Determinism.** Because (a) shards share no state, (b) each chain
//! is held by at most one worker at a time and drained strictly in
//! time order, and (c) cross-shard control emissions are buffered and
//! flushed in origin `(time, shard)` dispatch order at the barrier,
//! the per-shard event sequences — and thus the merged stream — are
//! byte-identical to [`run_sharded_serial`] no matter which worker
//! steals which chain. `tests/shard_equivalence.rs` proves it on
//! skew-heavy randomized worlds with stealing on and off.
//!
//! **Worker↔chain affinity.** The worker that holds a chain drains its
//! remaining segments itself before stealing another chain: the
//! chain's heap and site state are already hot in its cache, and a
//! sequential chain gains nothing from bouncing to a different core
//! between segments (see [`steal_worker`] for the full argument).
//! Determinism is untouched — the affinity only changes *which thread*
//! executes a segment, never the segment order.
//!
//! **Core pinning.** `StealConfig::pin_cores` (off by default, Linux
//! only) pins worker `w` to CPU `w mod cores` via `sched_setaffinity`
//! on thread startup, trading the kernel's freedom to migrate workers
//! for stable cache residency on dedicated bench boxes. Pinning is
//! wall-clock-only by the same argument as chain affinity: it decides
//! where a worker runs, never what it drains, so every observable
//! stream is byte-identical with the flag on or off (unit-proven by
//! `pinning_is_determinism_neutral`). Pin failures are ignored —
//! affinity is an optimization, not a correctness input.
//!
//! Worlds whose handlers genuinely need global state on every event
//! implement [`MergedWorld`] instead and replay through
//! [`run_merged_until`] — same queue, same deterministic order, serial
//! dispatch. The full [`crate::cluster::HybridCluster`] reproduction
//! used to be such a world; it is now split into per-site
//! [`SiteShard`]s plus a [`ControlPlane`] and replays on all three
//! engines (`rust/src/cluster/mod.rs` documents the ownership
//! boundary). `tests/shard_equivalence.rs` proves serial ≡ parallel on
//! randomized scenarios down to byte-identical figure output.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Condvar, Mutex};

use super::SimTime;

/// Which shard an event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardKey {
    /// Cross-site traffic: orchestrator, CLUES, VPN/overlay. Serialized;
    /// acts as a barrier in parallel replay.
    Control,
    /// Site-local traffic for cloud site `s`.
    Site(u32),
}

/// Events declare their shard; the queue routes on it.
pub trait ShardEvent {
    fn shard_key(&self) -> ShardKey;
}

/// Validate and clamp an absolute schedule time against `now`. Every
/// `schedule_at` entry point (single-queue, sharded, site ctx) goes
/// through here so the engines' rejection/clamping policies cannot
/// drift apart.
pub(crate) fn clamp_schedule_time(now: SimTime, at: SimTime) -> SimTime {
    assert!(at.0.is_finite(), "schedule_at: non-finite time {}", at.0);
    if at.0 < now.0 { now } else { at }
}

/// Validate a relative delay and turn it into an absolute time
/// (negatives clamp to `now`). Shared by every `schedule_in`.
pub(crate) fn delay_to_at(now: SimTime, delay: f64) -> SimTime {
    assert!(delay.is_finite(), "schedule_in: non-finite delay {delay}");
    now.add(delay.max(0.0))
}

/// Handle to a scheduled sharded event; used for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShardEventId {
    shard: u32,
    slot: u32,
    gen: u32,
}

struct Entry<E> {
    at: SimTime,
    seq: u64,
    slot: u32,
    gen: u32,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: invert for earliest-first; total order via total_cmp.
        other
            .at
            .0
            .total_cmp(&self.at.0)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One shard's queue: binary heap ordered `(time, seq)` with
/// generation-slot cancellation. Scheduling claims a reusable slot and
/// stamps the entry with the slot's generation; firing or cancelling
/// advances the generation, so stale handles can never match and the
/// slot store stays bounded by the number of concurrently live events.
///
/// This is the one heap implementation in the crate:
/// [`super::EventQueue`] wraps a single `ShardHeap`, so the
/// model-checked cancellation property in `tests/shard_equivalence.rs`
/// covers the parallel engine's shards too.
pub struct ShardHeap<E> {
    heap: BinaryHeap<Entry<E>>,
    gens: Vec<u32>,
    free: Vec<u32>,
    seq: u64,
    dispatched: u64,
}

impl<E> ShardHeap<E> {
    pub(crate) fn new() -> ShardHeap<E> {
        ShardHeap {
            heap: BinaryHeap::new(),
            gens: Vec::new(),
            free: Vec::new(),
            seq: 0,
            dispatched: 0,
        }
    }

    pub(crate) fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Events scheduled but not yet fired or cancelled.
    pub(crate) fn live_count(&self) -> usize {
        self.gens.len() - self.free.len()
    }

    /// Slot-store capacity (bounded by peak concurrent live events).
    pub(crate) fn slot_capacity(&self) -> usize {
        self.gens.len()
    }

    pub(crate) fn schedule(&mut self, at: SimTime, ev: E) -> (u32, u32) {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.gens.push(0);
                (self.gens.len() - 1) as u32
            }
        };
        let gen = self.gens[slot as usize];
        self.heap.push(Entry { at, seq: self.seq, slot, gen, ev });
        self.seq += 1;
        (slot, gen)
    }

    pub(crate) fn cancel(&mut self, slot: u32, gen: u32) -> bool {
        match self.gens.get_mut(slot as usize) {
            Some(g) if *g == gen => {
                *g = g.wrapping_add(1);
                self.free.push(slot);
                true
            }
            _ => false,
        }
    }

    /// `(time, seq)` of the next live entry; prunes cancelled entries.
    pub(crate) fn peek(&mut self) -> Option<(SimTime, u64)> {
        while let Some(entry) = self.heap.peek() {
            if self.gens[entry.slot as usize] != entry.gen {
                self.heap.pop();
                continue;
            }
            return Some((entry.at, entry.seq));
        }
        None
    }

    pub(crate) fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        while let Some(entry) = self.heap.pop() {
            let i = entry.slot as usize;
            if self.gens[i] != entry.gen {
                continue;
            }
            self.gens[i] = self.gens[i].wrapping_add(1);
            self.free.push(entry.slot);
            self.dispatched += 1;
            return Some((entry.at, entry.seq, entry.ev));
        }
        None
    }
}

/// The sharded event queue + virtual clock.
///
/// Shard 0 is the control shard; site `s` lives at shard `1 + s`.
/// Global dispatch order is `(time, shard index, per-shard seq)`.
pub struct ShardedQueue<E> {
    shards: Vec<ShardHeap<E>>,
    now: SimTime,
}

impl<E: ShardEvent> ShardedQueue<E> {
    /// A queue with `sites` site shards plus the control shard.
    pub fn new(sites: usize) -> ShardedQueue<E> {
        ShardedQueue {
            shards: (0..sites + 1).map(|_| ShardHeap::new()).collect(),
            now: SimTime::ZERO,
        }
    }

    /// Number of site shards.
    pub fn sites(&self) -> usize {
        self.shards.len() - 1
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched across all shards (perf counters).
    pub fn dispatched(&self) -> u64 {
        self.shards.iter().map(|s| s.dispatched).sum()
    }

    fn shard_index(&self, key: ShardKey) -> usize {
        match key {
            ShardKey::Control => 0,
            ShardKey::Site(s) => {
                let i = 1 + s as usize;
                assert!(
                    i < self.shards.len(),
                    "event routed to unknown site shard {s} \
                     (queue has {} site shards)",
                    self.shards.len() - 1
                );
                i
            }
        }
    }

    /// Schedule `ev` at absolute time `at` (clamped at now if in the
    /// past), routed to the shard it declares. Non-finite times are a
    /// caller bug and are rejected loudly.
    pub fn schedule_at(&mut self, at: SimTime, ev: E) -> ShardEventId {
        let at = clamp_schedule_time(self.now, at);
        let shard = self.shard_index(ev.shard_key());
        let (slot, gen) = self.shards[shard].schedule(at, ev);
        ShardEventId { shard: shard as u32, slot, gen }
    }

    /// Schedule `ev` after `delay` seconds (clamped at now for
    /// negatives). Non-finite delays are rejected loudly.
    pub fn schedule_in(&mut self, delay: f64, ev: E) -> ShardEventId {
        let at = delay_to_at(self.now, delay);
        self.schedule_at(at, ev)
    }

    /// Cancel a scheduled event. Returns false if it already fired or
    /// was already cancelled — without storing anything either way.
    pub fn cancel(&mut self, id: ShardEventId) -> bool {
        match self.shards.get_mut(id.shard as usize) {
            Some(sh) => sh.cancel(id.slot, id.gen),
            None => false,
        }
    }

    /// `(time, shard)` of the globally next event under the
    /// deterministic merge order `(time, shard, seq)`.
    pub fn peek(&mut self) -> Option<(SimTime, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for (i, sh) in self.shards.iter_mut().enumerate() {
            if let Some((t, _seq)) = sh.peek() {
                // Strict < keeps the lowest shard index on ties: shards
                // are visited in ascending order.
                if best.map_or(true, |(bt, _)| t.0 < bt) {
                    best = Some((t.0, i));
                }
            }
        }
        best.map(|(t, i)| (SimTime(t), i))
    }

    /// Pop the globally next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let (_, shard) = self.peek()?;
        self.pop_from(shard)
    }

    /// Pop from the shard a preceding [`ShardedQueue::peek`] identified,
    /// skipping the O(shards) re-scan — the runners' hot path.
    fn pop_from(&mut self, shard: usize) -> Option<(SimTime, E)> {
        let (t, _seq, ev) = self.shards[shard].pop()?;
        self.now = t;
        Some((t, ev))
    }

    pub fn is_empty(&mut self) -> bool {
        self.peek().is_none()
    }
}

// ---------------------------------------------------------------------
// Merged (serial, global-state) worlds
// ---------------------------------------------------------------------

/// A world whose handlers need global state on every event. Dispatch is
/// serial in the deterministic merge order; the sharded queue still
/// routes and cancels per shard.
pub trait MergedWorld {
    type Event: ShardEvent;

    fn handle(
        &mut self,
        t: SimTime,
        ev: Self::Event,
        q: &mut ShardedQueue<Self::Event>,
    );
}

/// Drive a [`MergedWorld`] until the queue drains or `horizon` is
/// exceeded. Returns the final virtual time.
pub fn run_merged_until<W: MergedWorld>(
    world: &mut W,
    q: &mut ShardedQueue<W::Event>,
    horizon: SimTime,
) -> SimTime {
    while let Some((at, shard)) = q.peek() {
        if at.0 > horizon.0 {
            break;
        }
        let (t, ev) = q.pop_from(shard).expect("peeked event vanished");
        world.handle(t, ev, q);
    }
    q.now()
}

/// Drive a [`MergedWorld`] until the queue drains completely.
pub fn run_merged<W: MergedWorld>(
    world: &mut W,
    q: &mut ShardedQueue<W::Event>,
) -> SimTime {
    run_merged_until(world, q, SimTime(f64::INFINITY))
}

// ---------------------------------------------------------------------
// Sharded (parallel-capable) worlds
// ---------------------------------------------------------------------

/// Per-site shard state. Handlers only touch their own site, schedule
/// into their own shard, and may emit control events through the ctx —
/// which is exactly what makes windows of site events safe to replay in
/// parallel.
pub trait SiteShard: Send {
    type Event: ShardEvent + Send;

    fn handle(
        &mut self,
        t: SimTime,
        ev: Self::Event,
        ctx: &mut SiteCtx<'_, Self::Event>,
    );
}

/// The control plane: serial handler with full access to every site at
/// barrier points.
pub trait ControlPlane {
    type Site: SiteShard;

    /// Handle one control-shard event. May schedule into any shard and
    /// mutate any site state.
    fn handle(
        &mut self,
        sites: &mut [Self::Site],
        t: SimTime,
        ev: <Self::Site as SiteShard>::Event,
        q: &mut ShardedQueue<<Self::Site as SiteShard>::Event>,
    );

    /// Minimum virtual-time distance between a site event and any
    /// control event it emits (conservative lookahead). Site windows
    /// extend at most this far past their start; the default means
    /// "sites never talk to the control plane".
    fn lookahead(&self) -> f64 {
        f64::INFINITY
    }
}

/// A control emission buffered during a site window, flushed at the
/// barrier in origin dispatch order.
struct ControlEmission<E> {
    origin_t: f64,
    origin_shard: u32,
    at: SimTime,
    ev: E,
}

/// What a site handler may do: schedule/cancel in its own shard, emit
/// control events at least `lookahead` in the future.
pub struct SiteCtx<'a, E> {
    shard: u32,
    now: SimTime,
    lookahead: f64,
    heap: &'a mut ShardHeap<E>,
    control_out: &'a mut Vec<ControlEmission<E>>,
}

impl<'a, E: ShardEvent> SiteCtx<'a, E> {
    /// Time of the event being handled.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// The site this shard belongs to.
    pub fn site(&self) -> u32 {
        self.shard - 1
    }

    /// Schedule into this site's own shard at absolute time `at`
    /// (clamped at the current event time if in the past).
    pub fn schedule_at(&mut self, at: SimTime, ev: E) -> ShardEventId {
        match ev.shard_key() {
            ShardKey::Site(s) if s + 1 == self.shard => {}
            other => panic!(
                "site shard {} may only schedule its own events, got {:?}",
                self.shard - 1, other
            ),
        }
        let at = clamp_schedule_time(self.now, at);
        let (slot, gen) = self.heap.schedule(at, ev);
        ShardEventId { shard: self.shard, slot, gen }
    }

    /// Schedule into this site's own shard after `delay` seconds.
    pub fn schedule_in(&mut self, delay: f64, ev: E) -> ShardEventId {
        let at = delay_to_at(self.now, delay);
        self.schedule_at(at, ev)
    }

    /// Cancel an event previously scheduled in this shard.
    pub fn cancel(&mut self, id: ShardEventId) -> bool {
        assert_eq!(id.shard, self.shard,
                   "cross-shard cancel from a site handler");
        self.heap.cancel(id.slot, id.gen)
    }

    /// Emit a control-shard event `delay` seconds from now. `delay`
    /// must respect the world's lookahead — that is the contract that
    /// keeps parallel windows equivalent to the serial replay.
    pub fn emit_control_in(&mut self, delay: f64, ev: E) {
        assert!(
            delay.is_finite() && delay >= self.lookahead,
            "control emission delay {delay} below the lookahead {}",
            self.lookahead
        );
        assert!(
            matches!(ev.shard_key(), ShardKey::Control),
            "emit_control_in given a site-shard event"
        );
        self.control_out.push(ControlEmission {
            origin_t: self.now.0,
            origin_shard: self.shard,
            at: self.now.add(delay),
            ev,
        });
    }
}

/// Drain one site shard's window `[*, barrier)` (bounded by `horizon`,
/// inclusive). Returns the time of the last dispatched event, or
/// `NEG_INFINITY` if none qualified.
fn drain_window<S: SiteShard>(
    site: &mut S,
    heap: &mut ShardHeap<S::Event>,
    shard: u32,
    barrier: f64,
    horizon: f64,
    lookahead: f64,
    out: &mut Vec<ControlEmission<S::Event>>,
) -> f64 {
    let mut last = f64::NEG_INFINITY;
    loop {
        match heap.peek() {
            Some((t, _)) if t.0 < barrier && t.0 <= horizon => {}
            _ => break,
        }
        let (t, _seq, ev) = heap.pop().expect("peeked entry vanished");
        last = t.0; // per-shard dispatch times are monotone
        let mut ctx = SiteCtx {
            shard,
            now: t,
            lookahead,
            heap: &mut *heap,
            control_out: &mut *out,
        };
        site.handle(t, ev, &mut ctx);
    }
    last
}

/// Dispatch exactly one site event (the global front) — the degenerate
/// single-queue step used by the serial engine and by zero-lookahead
/// windows.
fn step_site<S: SiteShard>(
    sites: &mut [S],
    q: &mut ShardedQueue<S::Event>,
    shard: usize,
    lookahead: f64,
) {
    let mut out: Vec<ControlEmission<S::Event>> = Vec::new();
    let t = {
        let heap = &mut q.shards[shard];
        let (t, _seq, ev) = heap.pop().expect("peeked event vanished");
        let mut ctx = SiteCtx {
            shard: shard as u32,
            now: t,
            lookahead,
            heap: &mut *heap,
            control_out: &mut out,
        };
        sites[shard - 1].handle(t, ev, &mut ctx);
        t
    };
    if t.0 > q.now.0 {
        q.now = t;
    }
    flush_control(q, out);
}

/// Flush buffered control emissions in origin dispatch order — the
/// order the serial single-queue replay would have enqueued them in
/// (per-shard buffers are already in per-shard dispatch order; the
/// stable sort interleaves shards by `(origin time, origin shard)`).
fn flush_control<E: ShardEvent>(
    q: &mut ShardedQueue<E>,
    mut emissions: Vec<ControlEmission<E>>,
) {
    emissions.sort_by(|a, b| {
        a.origin_t
            .total_cmp(&b.origin_t)
            .then(a.origin_shard.cmp(&b.origin_shard))
    });
    for em in emissions {
        debug_assert!(matches!(em.ev.shard_key(), ShardKey::Control));
        debug_assert!(em.at.0 >= q.now.0,
                      "control emission scheduled into the past");
        q.schedule_at(em.at, em.ev);
    }
}

// ---------------------------------------------------------------------
// Wall-clock engine profiler
// ---------------------------------------------------------------------

/// Wall-clock timing breakdown of one parallel replay: how much real
/// time went to the serial control barrier versus the parallel site
/// windows, how well the windows filled their worker budget, and (for
/// the stealing engine) how long workers sat on the injector.
///
/// Everything here is measured with [`std::time::Instant`] and varies
/// run to run — it is *observability about the engine*, not simulation
/// state, and must never be folded into a determinism digest (the
/// crate-wide contract lives in `rust/src/obs/mod.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineProfile {
    /// Parallel site windows executed (spawn/join or chain rounds).
    pub windows: u64,
    /// Zero-lookahead fallbacks to exact single-queue stepping.
    pub serial_steps: u64,
    /// Control-shard events handled — each one is a global barrier.
    pub barrier_events: u64,
    /// Wall time inside control-shard handlers (the serial barrier).
    pub barrier_wall_s: f64,
    /// Wall time inside parallel site windows, spawn→join inclusive.
    pub window_wall_s: f64,
    /// Sum over windows of the busiest worker's drain time — the
    /// critical path through the parallel sections.
    pub busiest_shard_wall_s: f64,
    /// Total worker drain time summed across all workers and windows.
    pub worker_wall_s: f64,
    /// Chains drained by the stealing engine (0 for the chunked engine).
    pub chains_executed: u64,
    /// Wall time stealing workers spent blocked on the shared injector
    /// (lock + condvar), including the tail wait for the last chain.
    pub injector_wait_s: f64,
    /// Worker-thread budget actually used (max across windows).
    pub workers: usize,
}

impl EngineProfile {
    /// Fraction of measured engine wall time spent in the serial
    /// control barrier — the control-coupling stall. 0 when nothing
    /// was measured.
    pub fn barrier_fraction(&self) -> f64 {
        let total = self.barrier_wall_s + self.window_wall_s;
        if total > 0.0 { self.barrier_wall_s / total } else { 0.0 }
    }

    /// Worker-busy time divided by the worker budget's window
    /// occupancy — 1.0 means every worker drained events for the whole
    /// of every window, lower means idle workers. 0 when unmeasured.
    pub fn parallel_efficiency(&self) -> f64 {
        let budget = self.window_wall_s * self.workers.max(1) as f64;
        if budget > 0.0 { self.worker_wall_s / budget } else { 0.0 }
    }
}

/// The single-queue engine: serial replay of a sharded world, one
/// globally-minimal event at a time. Reference semantics for
/// [`run_sharded`] — the equivalence suite holds the two byte-identical.
pub fn run_sharded_serial<C, S, E>(
    control: &mut C,
    sites: &mut [S],
    q: &mut ShardedQueue<E>,
    horizon: SimTime,
) -> SimTime
where
    C: ControlPlane<Site = S>,
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    assert_eq!(sites.len() + 1, q.shards.len(),
               "one site state per site shard");
    loop {
        let Some((at, shard)) = q.peek() else { break };
        if at.0 > horizon.0 {
            break;
        }
        if shard == 0 {
            let (t, ev) = q.pop_from(0).expect("peeked event vanished");
            control.handle(sites, t, ev, q);
        } else {
            let lookahead = control.lookahead().max(0.0);
            step_site(sites, q, shard, lookahead);
        }
    }
    q.now()
}

/// The parallel engine: control events run serially as barriers;
/// between barriers each site shard's window is drained on its own
/// thread. Produces exactly the event stream of [`run_sharded_serial`].
pub fn run_sharded<C, S, E>(
    control: &mut C,
    sites: &mut [S],
    q: &mut ShardedQueue<E>,
    horizon: SimTime,
    threads: usize,
) -> SimTime
where
    C: ControlPlane<Site = S>,
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    run_sharded_profiled(control, sites, q, horizon, threads).0
}

/// [`run_sharded`] with a wall-clock [`EngineProfile`]: same event
/// stream, same return time, plus the barrier/window timing breakdown.
/// The profile never feeds back into the simulation.
pub fn run_sharded_profiled<C, S, E>(
    control: &mut C,
    sites: &mut [S],
    q: &mut ShardedQueue<E>,
    horizon: SimTime,
    threads: usize,
) -> (SimTime, EngineProfile)
where
    C: ControlPlane<Site = S>,
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    assert_eq!(sites.len() + 1, q.shards.len(),
               "one site state per site shard");
    let mut prof = EngineProfile::default();
    loop {
        let Some((at, shard)) = q.peek() else { break };
        if at.0 > horizon.0 {
            break;
        }
        if shard == 0 {
            let (t, ev) = q.pop_from(0).expect("peeked event vanished");
            let b0 = std::time::Instant::now();
            control.handle(sites, t, ev, q);
            prof.barrier_wall_s += b0.elapsed().as_secs_f64();
            prof.barrier_events += 1;
            continue;
        }
        let lookahead = control.lookahead().max(0.0);
        let t_start = at.0;
        let mut barrier = if lookahead.is_finite() {
            t_start + lookahead
        } else {
            f64::INFINITY
        };
        if let Some((tc, _)) = q.shards[0].peek() {
            barrier = barrier.min(tc.0);
        }
        if barrier <= t_start {
            // Zero lookahead: the window is empty — fall back to exact
            // single-queue stepping of the front event.
            step_site(sites, q, shard, lookahead);
            prof.serial_steps += 1;
            continue;
        }
        // Parallel site window [t_start, barrier).
        let workers = threads.max(1).min(sites.len());
        if workers > prof.workers {
            prof.workers = workers;
        }
        let chunk = sites.len().div_ceil(workers);
        let horizon_t = horizon.0;
        let mut emissions: Vec<ControlEmission<E>> = Vec::new();
        let mut max_t = f64::NEG_INFINITY;
        let mut busiest = 0.0f64;
        let w0 = std::time::Instant::now();
        {
            let (_control_shard, site_heaps) = q.shards.split_at_mut(1);
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (ci, (site_chunk, heap_chunk)) in sites
                    .chunks_mut(chunk)
                    .zip(site_heaps.chunks_mut(chunk))
                    .enumerate()
                {
                    let base = ci * chunk;
                    handles.push(scope.spawn(move || {
                        let d0 = std::time::Instant::now();
                        let mut out: Vec<ControlEmission<E>> = Vec::new();
                        let mut last = f64::NEG_INFINITY;
                        for (k, (site, heap)) in site_chunk
                            .iter_mut()
                            .zip(heap_chunk.iter_mut())
                            .enumerate()
                        {
                            let l = drain_window(
                                site,
                                heap,
                                (1 + base + k) as u32,
                                barrier,
                                horizon_t,
                                lookahead,
                                &mut out,
                            );
                            if l > last {
                                last = l;
                            }
                        }
                        (last, out, d0.elapsed().as_secs_f64())
                    }));
                }
                for h in handles {
                    let (last, out, drain_s) =
                        h.join().expect("site shard worker panicked");
                    if last > max_t {
                        max_t = last;
                    }
                    if drain_s > busiest {
                        busiest = drain_s;
                    }
                    prof.worker_wall_s += drain_s;
                    emissions.extend(out);
                }
            });
        }
        prof.window_wall_s += w0.elapsed().as_secs_f64();
        prof.busiest_shard_wall_s += busiest;
        prof.windows += 1;
        if max_t > q.now.0 {
            q.now = SimTime(max_t);
        }
        flush_control(q, emissions);
    }
    (q.now(), prof)
}

// ---------------------------------------------------------------------
// Work-stealing parallel engine
// ---------------------------------------------------------------------

/// Configuration for [`run_sharded_stealing`].
#[derive(Debug, Clone, Copy)]
pub struct StealConfig {
    /// Worker threads (clamped per window to the number of busy shards).
    pub threads: usize,
    /// Pin worker `i` to CPU `i % cores` (Linux, best-effort; off by
    /// default). A wall-clock affinity hint only: pinning changes
    /// which core runs a worker, never which chains it drains or in
    /// what order, so event streams and digests are byte-identical
    /// with it on or off (the `pinning_is_determinism_neutral` test).
    pub pin_cores: bool,
}

impl StealConfig {
    /// `threads` worker threads, no core pinning.
    pub fn new(threads: usize) -> StealConfig {
        StealConfig { threads, pin_cores: false }
    }

    /// `threads` worker threads pinned to CPUs round-robin.
    pub fn pinned(threads: usize) -> StealConfig {
        StealConfig { threads, pin_cores: true }
    }
}

/// Best-effort pin of the calling thread to CPU `worker % cores`
/// (Linux only; a no-op elsewhere and on any syscall failure). Purely
/// a wall-clock affinity hint — it never touches the event stream.
#[cfg(target_os = "linux")]
fn pin_current_thread(worker: usize) {
    // Raw prototype instead of a libc dependency: the symbol is in
    // every glibc/musl, and the kernel accepts any mask size that
    // covers the CPUs actually set.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize,
                             mask: *const u64) -> i32;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(64);
    let mask: u64 = 1u64 << (worker % cores);
    // pid 0 = the calling thread; failure is ignored (it is a hint).
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of::<u64>(),
                                  &mask);
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_worker: usize) {}

/// One shard's window as a sequential chain of segments. At most one
/// worker holds a chain at a time; the holder drains the segments in
/// order (worker↔chain affinity) and the injector hands whole ready
/// chains to idle workers — which is what lets chains spread across
/// workers without ever reordering any shard's events.
struct Chain<'a, S: SiteShard> {
    shard: u32,
    site: &'a mut S,
    heap: &'a mut ShardHeap<S::Event>,
    /// Ascending segment end-cuts; the last is the window barrier.
    bounds: Vec<f64>,
    /// Index of the next segment to drain.
    next: usize,
}

/// The shared injector: ready chains plus the count of chains not yet
/// retired (queued *or* held by a worker — the distinction is what the
/// idle-worker wait condition needs).
struct StealState<'a, S: SiteShard> {
    ready: VecDeque<Chain<'a, S>>,
    active: usize,
}

/// Steal the next ready chain, blocking while chains are still held by
/// other workers. Returns `None` once every chain has retired.
fn steal_next<'a, S: SiteShard>(
    state: &Mutex<StealState<'a, S>>,
    cv: &Condvar,
) -> Option<Chain<'a, S>> {
    let mut g = state.lock().expect("steal state poisoned");
    loop {
        if let Some(c) = g.ready.pop_front() {
            return Some(c);
        }
        if g.active == 0 {
            return None;
        }
        g = cv.wait(g).expect("steal state poisoned");
    }
}

/// One worker: steal a ready chain, then drain its segments to
/// completion before stealing elsewhere. Returns the max dispatched
/// time and the buffered control emissions.
///
/// **Worker↔chain affinity.** A worker that just finished a chain
/// segment prefers that chain's next ready segment over anything on
/// the injector: the chain's heap and site state are hot in this
/// worker's cache, and — since a chain is sequential and at most one
/// worker may hold it — handing it back through the injector could
/// only move it to a cold core while this worker picks up a different
/// cold chain. (The pre-affinity scheme did exactly that: re-inject
/// after every segment, `push_back` behind the cold chains, so a hot
/// shard's tail bounced between workers.) This is the cheap step
/// toward pinned shard workers; determinism is unaffected by
/// construction, because segment cuts come from queue state and chains
/// execute strictly in segment order whoever holds them —
/// `tests/shard_equivalence.rs` asserts byte-identical output with
/// stealing on and off either way.
fn steal_worker<'a, S, E>(
    state: &Mutex<StealState<'a, S>>,
    cv: &Condvar,
    horizon: f64,
    lookahead: f64,
) -> (f64, Vec<ControlEmission<E>>, StealWorkerStats)
where
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    let mut out: Vec<ControlEmission<E>> = Vec::new();
    let mut last = f64::NEG_INFINITY;
    let mut stats = StealWorkerStats::default();
    loop {
        let w0 = std::time::Instant::now();
        let Some(mut chain) = steal_next(state, cv) else {
            stats.wait_s += w0.elapsed().as_secs_f64();
            break;
        };
        stats.wait_s += w0.elapsed().as_secs_f64();
        stats.chains += 1;
        let b0 = std::time::Instant::now();
        while chain.next < chain.bounds.len() {
            let end = chain.bounds[chain.next];
            let l = drain_window(chain.site, chain.heap, chain.shard, end,
                                 horizon, lookahead, &mut out);
            if l > last {
                last = l;
            }
            chain.next += 1;
        }
        stats.busy_s += b0.elapsed().as_secs_f64();
        let mut g = state.lock().expect("steal state poisoned");
        g.active -= 1;
        if g.active == 0 {
            drop(g);
            cv.notify_all();
        }
    }
    (last, out, stats)
}

/// Per-worker wall-clock tallies from one stealing window: time spent
/// draining chains, time blocked on the injector, chains stolen.
/// Profiler-only — never read by the simulation.
#[derive(Debug, Clone, Copy, Default)]
struct StealWorkerStats {
    busy_s: f64,
    wait_s: f64,
    chains: u64,
}

/// The work-stealing parallel engine: identical window/barrier
/// semantics to [`run_sharded`], but site windows are drained as
/// segment chains stolen from a shared injector instead of fixed
/// per-thread chunks, so a hot shard's tail never serializes behind
/// cold shards. Produces exactly the event stream of
/// [`run_sharded_serial`] (see the module docs for the argument).
pub fn run_sharded_stealing<C, S, E>(
    control: &mut C,
    sites: &mut [S],
    q: &mut ShardedQueue<E>,
    horizon: SimTime,
    cfg: StealConfig,
) -> SimTime
where
    C: ControlPlane<Site = S>,
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    run_sharded_stealing_profiled(control, sites, q, horizon, cfg).0
}

/// [`run_sharded_stealing`] with a wall-clock [`EngineProfile`]: same
/// event stream, same return time, plus chain counts and injector-wait
/// timing on top of the barrier/window breakdown. The profile never
/// feeds back into the simulation.
pub fn run_sharded_stealing_profiled<C, S, E>(
    control: &mut C,
    sites: &mut [S],
    q: &mut ShardedQueue<E>,
    horizon: SimTime,
    cfg: StealConfig,
) -> (SimTime, EngineProfile)
where
    C: ControlPlane<Site = S>,
    S: SiteShard<Event = E>,
    E: ShardEvent + Send,
{
    assert_eq!(sites.len() + 1, q.shards.len(),
               "one site state per site shard");
    let mut prof = EngineProfile::default();
    loop {
        let Some((at, shard)) = q.peek() else { break };
        if at.0 > horizon.0 {
            break;
        }
        if shard == 0 {
            let (t, ev) = q.pop_from(0).expect("peeked event vanished");
            let b0 = std::time::Instant::now();
            control.handle(sites, t, ev, q);
            prof.barrier_wall_s += b0.elapsed().as_secs_f64();
            prof.barrier_events += 1;
            continue;
        }
        let lookahead = control.lookahead().max(0.0);
        let t_start = at.0;
        let mut barrier = if lookahead.is_finite() {
            t_start + lookahead
        } else {
            f64::INFINITY
        };
        if let Some((tc, _)) = q.shards[0].peek() {
            barrier = barrier.min(tc.0);
        }
        if barrier <= t_start {
            // Zero lookahead: fall back to exact single-queue stepping.
            step_site(sites, q, shard, lookahead);
            prof.serial_steps += 1;
            continue;
        }
        let horizon_t = horizon.0;
        let mut emissions: Vec<ControlEmission<E>> = Vec::new();
        let mut max_t = f64::NEG_INFINITY;
        let mut busiest = 0.0f64;
        let w0 = std::time::Instant::now();
        {
            let (_control_shard, site_heaps) = q.shards.split_at_mut(1);
            // One chain per shard with work in this window, each
            // covering the whole window up to the barrier (under
            // worker↔chain affinity the holder drains it back-to-back).
            let mut chains: VecDeque<Chain<'_, S>> = VecDeque::new();
            for (i, (site, heap)) in sites
                .iter_mut()
                .zip(site_heaps.iter_mut())
                .enumerate()
            {
                match heap.peek() {
                    Some((t, _)) if t.0 < barrier && t.0 <= horizon_t => {}
                    _ => continue,
                }
                chains.push_back(Chain {
                    shard: (1 + i) as u32,
                    site,
                    heap,
                    bounds: vec![barrier],
                    next: 0,
                });
            }
            let workers = cfg.threads.max(1).min(chains.len());
            if workers > prof.workers {
                prof.workers = workers;
            }
            if workers <= 1 {
                // One worker: drain each chain's whole window in place.
                let n_chains = chains.len() as u64;
                let d0 = std::time::Instant::now();
                for c in chains {
                    let l = drain_window(c.site, c.heap, c.shard, barrier,
                                         horizon_t, lookahead,
                                         &mut emissions);
                    if l > max_t {
                        max_t = l;
                    }
                }
                let drain_s = d0.elapsed().as_secs_f64();
                prof.chains_executed += n_chains;
                prof.worker_wall_s += drain_s;
                busiest = drain_s;
            } else {
                let active = chains.len();
                let state = Mutex::new(StealState { ready: chains, active });
                let cv = Condvar::new();
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for w in 0..workers {
                        let (state, cv) = (&state, &cv);
                        let pin = cfg.pin_cores;
                        handles.push(scope.spawn(move || {
                            if pin {
                                pin_current_thread(w);
                            }
                            steal_worker(state, cv, horizon_t, lookahead)
                        }));
                    }
                    for h in handles {
                        let (last, out, stats) =
                            h.join().expect("steal worker panicked");
                        if last > max_t {
                            max_t = last;
                        }
                        if stats.busy_s > busiest {
                            busiest = stats.busy_s;
                        }
                        prof.worker_wall_s += stats.busy_s;
                        prof.injector_wait_s += stats.wait_s;
                        prof.chains_executed += stats.chains;
                        emissions.extend(out);
                    }
                });
            }
        }
        prof.window_wall_s += w0.elapsed().as_secs_f64();
        prof.busiest_shard_wall_s += busiest;
        prof.windows += 1;
        if max_t > q.now.0 {
            q.now = SimTime(max_t);
        }
        flush_control(q, emissions);
    }
    (q.now(), prof)
}

/// A sensible worker count: one thread per site shard, capped by the
/// machine's available parallelism.
pub fn default_threads(sites: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(sites.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq)]
    enum TEv {
        Ctl(u32),
        Site { site: u32, tag: u32 },
    }

    impl ShardEvent for TEv {
        fn shard_key(&self) -> ShardKey {
            match self {
                TEv::Ctl(_) => ShardKey::Control,
                TEv::Site { site, .. } => ShardKey::Site(*site),
            }
        }
    }

    #[test]
    fn merge_order_is_time_shard_seq() {
        let mut q: ShardedQueue<TEv> = ShardedQueue::new(2);
        q.schedule_at(SimTime(5.0), TEv::Site { site: 1, tag: 0 });
        q.schedule_at(SimTime(5.0), TEv::Site { site: 0, tag: 1 });
        q.schedule_at(SimTime(5.0), TEv::Ctl(2));
        q.schedule_at(SimTime(1.0), TEv::Site { site: 1, tag: 3 });
        q.schedule_at(SimTime(5.0), TEv::Site { site: 0, tag: 4 });
        let mut order = Vec::new();
        while let Some((t, ev)) = q.pop() {
            order.push((t.0, ev));
        }
        // t=1 first; at t=5 control (shard 0) precedes site 0 precedes
        // site 1, and within site 0 schedule order holds.
        assert_eq!(order, vec![
            (1.0, TEv::Site { site: 1, tag: 3 }),
            (5.0, TEv::Ctl(2)),
            (5.0, TEv::Site { site: 0, tag: 1 }),
            (5.0, TEv::Site { site: 0, tag: 4 }),
            (5.0, TEv::Site { site: 1, tag: 0 }),
        ]);
        assert_eq!(q.dispatched(), 5);
    }

    #[test]
    fn cancellation_per_shard() {
        let mut q: ShardedQueue<TEv> = ShardedQueue::new(1);
        let a = q.schedule_at(SimTime(1.0), TEv::Site { site: 0, tag: 0 });
        let b = q.schedule_at(SimTime(2.0), TEv::Ctl(1));
        assert!(q.cancel(a));
        assert!(!q.cancel(a));
        let (t, ev) = q.pop().unwrap();
        assert_eq!((t.0, ev), (2.0, TEv::Ctl(1)));
        assert!(!q.cancel(b)); // already fired
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "unknown site shard")]
    fn unknown_site_shard_is_rejected() {
        let mut q: ShardedQueue<TEv> = ShardedQueue::new(1);
        q.schedule_at(SimTime(0.0), TEv::Site { site: 7, tag: 0 });
    }

    // -- a toy sharded world used by the serial/parallel equivalence
    //    checks below (heavier randomized coverage lives in
    //    tests/shard_equivalence.rs) ---------------------------------

    #[derive(Clone)]
    struct TSite {
        site: u32,
        remaining: u32,
        log: Vec<(f64, u32)>,
    }

    impl SiteShard for TSite {
        type Event = TEv;

        fn handle(&mut self, t: SimTime, ev: TEv,
                  ctx: &mut SiteCtx<'_, TEv>) {
            let TEv::Site { tag, .. } = ev else { return };
            self.log.push((t.0, tag));
            if self.remaining > 0 {
                self.remaining -= 1;
                ctx.schedule_in(1.5, TEv::Site {
                    site: self.site,
                    tag: tag + 1,
                });
                if self.remaining % 3 == 0 {
                    ctx.emit_control_in(10.0, TEv::Ctl(self.site));
                }
            }
        }
    }

    #[derive(Clone)]
    struct TControl {
        kicked: bool,
        lookahead: f64,
        log: Vec<(f64, u32)>,
    }

    impl ControlPlane for TControl {
        type Site = TSite;

        fn handle(&mut self, sites: &mut [TSite], t: SimTime, ev: TEv,
                  q: &mut ShardedQueue<TEv>) {
            let TEv::Ctl(x) = ev else { return };
            self.log.push((t.0, x));
            if !self.kicked {
                self.kicked = true;
                for s in sites.iter() {
                    q.schedule_at(t, TEv::Site { site: s.site, tag: 0 });
                }
            }
        }

        fn lookahead(&self) -> f64 {
            self.lookahead
        }
    }

    fn toy_world(lookahead: f64) -> (TControl, Vec<TSite>) {
        let control = TControl { kicked: false, lookahead, log: vec![] };
        let sites = (0..3)
            .map(|s| TSite {
                site: s,
                remaining: 7 + s * 3,
                log: vec![],
            })
            .collect();
        (control, sites)
    }

    fn run_both(lookahead: f64)
        -> ((TControl, Vec<TSite>, u64), (TControl, Vec<TSite>, u64)) {
        // The toy world emits control at +10.0, so any lookahead ≤ 10
        // respects the contract.
        let (mut c1, mut s1) = toy_world(lookahead);
        let mut q1: ShardedQueue<TEv> = ShardedQueue::new(s1.len());
        q1.schedule_at(SimTime(0.0), TEv::Ctl(99));
        run_sharded_serial(&mut c1, &mut s1, &mut q1,
                           SimTime(f64::INFINITY));
        let (mut c2, mut s2) = toy_world(lookahead);
        let mut q2: ShardedQueue<TEv> = ShardedQueue::new(s2.len());
        q2.schedule_at(SimTime(0.0), TEv::Ctl(99));
        run_sharded(&mut c2, &mut s2, &mut q2, SimTime(f64::INFINITY), 3);
        ((c1, s1, q1.dispatched()), (c2, s2, q2.dispatched()))
    }

    #[test]
    fn parallel_replay_matches_serial() {
        let ((c1, s1, d1), (c2, s2, d2)) = run_both(10.0);
        assert_eq!(c1.log, c2.log);
        assert_eq!(d1, d2);
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.log, b.log, "site {} diverged", a.site);
        }
        // The cascade actually ran.
        assert!(s1.iter().all(|s| s.log.len() > 1));
        assert!(!c1.log.is_empty());
    }

    #[test]
    fn zero_lookahead_degrades_to_single_queue() {
        let ((c1, s1, d1), (c2, s2, d2)) = run_both(0.0);
        assert_eq!(c1.log, c2.log);
        assert_eq!(d1, d2);
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.log, b.log);
        }
    }

    fn run_stealing_toy(lookahead: f64, cfg: StealConfig)
        -> (TControl, Vec<TSite>, u64) {
        let (mut c, mut s) = toy_world(lookahead);
        let mut q: ShardedQueue<TEv> = ShardedQueue::new(s.len());
        q.schedule_at(SimTime(0.0), TEv::Ctl(99));
        run_sharded_stealing(&mut c, &mut s, &mut q,
                             SimTime(f64::INFINITY), cfg);
        (c, s, q.dispatched())
    }

    #[test]
    fn stealing_replay_matches_serial() {
        for threads in [1usize, 2, 3] {
            for lookahead in [0.0, 10.0] {
                let ((c1, s1, d1), _) = run_both(lookahead);
                let cfg = StealConfig::new(threads);
                let (c2, s2, d2) = run_stealing_toy(lookahead, cfg);
                assert_eq!(c1.log, c2.log,
                           "control log (threads={threads}, \
                            la={lookahead})");
                assert_eq!(d1, d2);
                for (a, b) in s1.iter().zip(&s2) {
                    assert_eq!(a.log, b.log,
                               "site {} (threads={threads}, \
                                la={lookahead})",
                               a.site);
                }
            }
        }
    }

    #[test]
    fn stealing_respects_horizon() {
        let (mut c1, mut s1) = toy_world(10.0);
        let mut q1: ShardedQueue<TEv> = ShardedQueue::new(s1.len());
        q1.schedule_at(SimTime(0.0), TEv::Ctl(99));
        let end1 = run_sharded_serial(&mut c1, &mut s1, &mut q1,
                                      SimTime(4.0));
        let (mut c2, mut s2) = toy_world(10.0);
        let mut q2: ShardedQueue<TEv> = ShardedQueue::new(s2.len());
        q2.schedule_at(SimTime(0.0), TEv::Ctl(99));
        let end2 = run_sharded_stealing(
            &mut c2, &mut s2, &mut q2, SimTime(4.0),
            StealConfig::new(2));
        assert_eq!(end1.0, end2.0);
        assert_eq!(c1.log, c2.log);
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.log, b.log);
        }
        assert!(!q2.is_empty(), "horizon left events queued");
    }

    #[test]
    fn pinning_is_determinism_neutral() {
        // Core pinning is a wall-clock affinity hint: the event
        // stream with pinning on must be byte-for-byte the stream
        // with it off (and the serial reference).
        for lookahead in [0.0, 10.0] {
            let (c0, s0, d0) = run_stealing_toy(
                lookahead, StealConfig::new(3));
            let (c1, s1, d1) = run_stealing_toy(
                lookahead, StealConfig::pinned(3));
            assert_eq!(d0, d1);
            assert_eq!(c0.log, c1.log);
            for (a, b) in s0.iter().zip(&s1) {
                assert_eq!(a.log, b.log);
            }
        }
    }

    #[test]
    fn horizon_bounds_both_engines_identically() {
        let (mut c1, mut s1) = toy_world(10.0);
        let mut q1: ShardedQueue<TEv> = ShardedQueue::new(s1.len());
        q1.schedule_at(SimTime(0.0), TEv::Ctl(99));
        let end1 = run_sharded_serial(&mut c1, &mut s1, &mut q1,
                                      SimTime(4.0));
        let (mut c2, mut s2) = toy_world(10.0);
        let mut q2: ShardedQueue<TEv> = ShardedQueue::new(s2.len());
        q2.schedule_at(SimTime(0.0), TEv::Ctl(99));
        let end2 = run_sharded(&mut c2, &mut s2, &mut q2, SimTime(4.0), 2);
        assert_eq!(end1.0, end2.0);
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.log, b.log);
            assert!(a.log.iter().all(|&(t, _)| t <= 4.0));
        }
        assert!(!q1.is_empty(), "horizon left events queued");
    }

    struct MergeCounter {
        seen: Vec<(f64, u32)>,
    }

    impl MergedWorld for MergeCounter {
        type Event = TEv;

        fn handle(&mut self, t: SimTime, ev: TEv,
                  q: &mut ShardedQueue<TEv>) {
            match ev {
                TEv::Ctl(x) => {
                    self.seen.push((t.0, x));
                    if x > 0 {
                        q.schedule_in(1.0, TEv::Site { site: 0, tag: x - 1 });
                    }
                }
                TEv::Site { tag, .. } => {
                    self.seen.push((t.0, tag));
                    if tag > 0 {
                        q.schedule_in(1.0, TEv::Ctl(tag - 1));
                    }
                }
            }
        }
    }

    #[test]
    fn merged_world_cascades_across_shards() {
        let mut w = MergeCounter { seen: vec![] };
        let mut q: ShardedQueue<TEv> = ShardedQueue::new(1);
        q.schedule_at(SimTime(0.0), TEv::Ctl(3));
        let end = run_merged(&mut w, &mut q);
        assert_eq!(w.seen, vec![(0.0, 3), (1.0, 2), (2.0, 1), (3.0, 0)]);
        assert_eq!(end.0, 3.0);
    }
}
