//! Time-series recording and figure/table regeneration.
//!
//! The cluster world emits node display-state transitions and job events
//! into a [`Recorder`]; exporters then rebuild the paper's Figure 10
//! (per-node usage evolution), Figure 11 (node state counts evolution)
//! and the §4.2 cost/utilization table from the recorded series.
//!
//! Transitions and job runs are recorded by interned [`NodeId`] — one
//! `u32` per event instead of a cloned `String` — and first-appearance
//! order is maintained in an order-preserving index set, so
//! [`Recorder::node_names`] is O(nodes) instead of the old O(n²)
//! rescan of the whole transition log. Names are resolved only when a
//! figure/table is rendered.
//!
//! For spill-mode runs the figures can also be rendered straight from
//! the per-shard spill streams ([`Recorder::fig10_from_spills`] /
//! [`Recorder::fig11_from_spills`] in [`spill`]) without materializing
//! the merged recorder — property-proven byte-identical to merging
//! first and rendering from memory.

pub mod spill;

use std::collections::{BTreeMap, HashMap};

use crate::ids::{NodeId, NodeNames};
use crate::sim::SimTime;
use crate::util::csv::Table;

pub use spill::{ShardSink, SpillFiles};

/// Node display states — exactly the legend of the paper's Figure 11
/// (blue=used, green=powering on, orange=idle, purple=powering off),
/// plus Off/Failed for completeness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DisplayState {
    Used,
    PoweringOn,
    Idle,
    PoweringOff,
    Off,
    Failed,
}

impl DisplayState {
    pub fn label(self) -> &'static str {
        match self {
            DisplayState::Used => "used",
            DisplayState::PoweringOn => "powering_on",
            DisplayState::Idle => "idle",
            DisplayState::PoweringOff => "powering_off",
            DisplayState::Off => "off",
            DisplayState::Failed => "failed",
        }
    }

    /// Inverse of [`DisplayState::label`] (spill-file deserialization).
    pub fn from_label(s: &str) -> Option<DisplayState> {
        Some(match s {
            "used" => DisplayState::Used,
            "powering_on" => DisplayState::PoweringOn,
            "idle" => DisplayState::Idle,
            "powering_off" => DisplayState::PoweringOff,
            "off" => DisplayState::Off,
            "failed" => DisplayState::Failed,
            _ => return None,
        })
    }
}

/// Recorder of everything the figures need.
///
/// Two recording modes share this surface: the default accumulates in
/// the public vectors below; a recorder built by
/// [`Recorder::with_spill`] instead streams every record to its
/// [`ShardSink`]'s spill files and keeps nothing in memory — rebuild
/// the in-memory view afterwards with [`Recorder::merge_spills`].
#[derive(Debug, Default)]
pub struct Recorder {
    names: NodeNames,
    /// (t, node, new state) transitions, in time order.
    pub transitions: Vec<(SimTime, NodeId, DisplayState)>,
    /// (t, event label) milestones for the narrative log.
    pub milestones: Vec<(SimTime, String)>,
    /// Completed job records: (node, start, end).
    pub job_runs: Vec<(NodeId, SimTime, SimTime)>,
    /// First-appearance order of node ids (order-preserving index set:
    /// `seen` answers membership, `order` preserves insertion order).
    order: Vec<NodeId>,
    seen: Vec<bool>,
    /// When set, records stream here instead of the vectors above.
    sink: Option<ShardSink>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Share the cluster-wide interner so ids resolve to real names.
    pub fn with_names(names: NodeNames) -> Recorder {
        Recorder { names, ..Recorder::default() }
    }

    /// A streaming recorder: every record goes to `sink`'s spill files,
    /// nothing accumulates in memory. The figure/query methods on a
    /// spilling recorder see an empty log — merge the spills back with
    /// [`Recorder::merge_spills`] when the replay ends.
    pub fn with_spill(names: NodeNames, sink: ShardSink) -> Recorder {
        Recorder { names, sink: Some(sink), ..Recorder::default() }
    }

    /// Is this recorder streaming to spill files?
    pub fn is_spilling(&self) -> bool {
        self.sink.is_some()
    }

    /// Take the spill sink out and flush it, leaving an (empty)
    /// in-memory recorder behind. `None` if not spilling.
    pub fn finish_spill(&mut self)
        -> Option<anyhow::Result<SpillFiles>> {
        self.sink.take().map(ShardSink::finish)
    }

    /// Approximate heap footprint of the accumulated record vectors —
    /// the number the per-shard streaming flush exists to keep flat.
    pub fn approx_bytes(&self) -> usize {
        use std::mem::size_of;
        self.transitions.capacity()
            * size_of::<(SimTime, NodeId, DisplayState)>()
            + self.job_runs.capacity()
                * size_of::<(NodeId, SimTime, SimTime)>()
            + self.milestones.capacity() * size_of::<(SimTime, String)>()
            + self.milestones.iter().map(|(_, s)| s.capacity()).sum::<usize>()
            + self.order.capacity() * size_of::<NodeId>()
            + self.seen.capacity()
    }

    /// Interner handle (ids recorded here resolve through it).
    pub fn names(&self) -> NodeNames {
        self.names.clone()
    }

    pub fn node_state(&mut self, t: SimTime, node: &str, s: DisplayState) {
        let id = self.names.intern(node);
        self.node_state_id(t, id, s);
    }

    /// Hot-path variant: no hashing, no cloning (in-memory mode).
    pub fn node_state_id(&mut self, t: SimTime, id: NodeId,
                         s: DisplayState) {
        if let Some(sink) = self.sink.as_mut() {
            sink.node_state(t, &self.names.name(id), s);
            return;
        }
        let i = id.index();
        if self.seen.len() <= i {
            self.seen.resize(i + 1, false);
        }
        if !self.seen[i] {
            self.seen[i] = true;
            self.order.push(id);
        }
        self.transitions.push((t, id, s));
    }

    pub fn milestone(&mut self, t: SimTime, label: impl Into<String>) {
        let label = label.into();
        if let Some(sink) = self.sink.as_mut() {
            sink.milestone(t, &label);
            return;
        }
        self.milestones.push((t, label));
    }

    pub fn job_run(&mut self, node: &str, start: SimTime, end: SimTime) {
        let id = self.names.intern(node);
        self.job_run_id(id, start, end);
    }

    /// Hot-path variant: no hashing, no cloning (in-memory mode).
    pub fn job_run_id(&mut self, id: NodeId, start: SimTime, end: SimTime) {
        if let Some(sink) = self.sink.as_mut() {
            sink.job_run(&self.names.name(id), start, end);
            return;
        }
        self.job_runs.push((id, start, end));
    }

    /// All node names seen, in first-appearance order.
    pub fn node_names(&self) -> Vec<String> {
        self.order.iter().map(|&id| self.names.name(id)).collect()
    }

    /// Merge per-shard recorders into one stream, ordered by the
    /// sharded engine's deterministic merge key
    /// `(time, shard index, intra-shard record order)`. Parallel site
    /// shards own their recorders (and possibly private interners), so
    /// every record is re-interned by name into `names`; the result is
    /// byte-identical however the shards were scheduled on threads.
    pub fn merge_shards(names: NodeNames, shards: &[Recorder]) -> Recorder {
        let mut merged = Recorder::with_names(names);

        let mut transitions: Vec<(f64, usize, usize, String, DisplayState)> =
            Vec::new();
        let mut runs: Vec<(f64, usize, usize, String, SimTime, SimTime)> =
            Vec::new();
        let mut notes: Vec<(f64, usize, usize, &str)> = Vec::new();
        for (si, r) in shards.iter().enumerate() {
            for (k, &(t, id, s)) in r.transitions.iter().enumerate() {
                transitions.push((t.0, si, k, r.names.name(id), s));
            }
            for (k, &(id, s, e)) in r.job_runs.iter().enumerate() {
                runs.push((e.0, si, k, r.names.name(id), s, e));
            }
            for (k, (t, label)) in r.milestones.iter().enumerate() {
                notes.push((t.0, si, k, label.as_str()));
            }
        }
        let key = |a: &(f64, usize, usize), b: &(f64, usize, usize)| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
        };
        transitions.sort_by(|a, b| key(&(a.0, a.1, a.2), &(b.0, b.1, b.2)));
        runs.sort_by(|a, b| key(&(a.0, a.1, a.2), &(b.0, b.1, b.2)));
        notes.sort_by(|a, b| key(&(a.0, a.1, a.2), &(b.0, b.1, b.2)));

        for (t, _, _, name, s) in transitions {
            merged.node_state(SimTime(t), &name, s);
        }
        for (_, _, _, name, s, e) in runs {
            merged.job_run(&name, s, e);
        }
        for (t, _, _, label) in notes {
            merged.milestone(SimTime(t), label);
        }
        merged
    }

    /// Transition log with names resolved (test/report convenience).
    pub fn transitions_named(&self)
        -> Vec<(SimTime, String, DisplayState)> {
        self.transitions
            .iter()
            .map(|&(t, id, s)| (t, self.names.name(id), s))
            .collect()
    }

    /// State of each node at time `t` (replay of the transition log).
    pub fn states_at(&self, t: SimTime) -> BTreeMap<String, DisplayState> {
        let mut by_id: HashMap<NodeId, DisplayState> = HashMap::new();
        for &(at, node, s) in &self.transitions {
            if at.0 <= t.0 {
                by_id.insert(node, s);
            }
        }
        by_id
            .into_iter()
            .map(|(id, s)| (self.names.name(id), s))
            .collect()
    }

    /// Figure 10: one row per `bucket_secs`, one column per node, cell =
    /// 1 when the node is executing a job in that bucket.
    /// Pointer-sweep over per-node sorted intervals —
    /// O(runs log runs + buckets x nodes) instead of rescanning every
    /// job run per cell (EXPERIMENTS §Perf L3).
    pub fn fig10_usage(&self, bucket_secs: f64, until: SimTime) -> Table {
        let ids = &self.order;
        let mut header = vec!["time".to_string()];
        header.extend(ids.iter().map(|&id| self.names.name(id)));
        let mut table = Table::new(header);

        // Group + sort intervals per node.
        let mut per_node: HashMap<NodeId, Vec<(f64, f64)>> = HashMap::new();
        for &(node, s, e) in &self.job_runs {
            per_node.entry(node).or_default().push((s.0, e.0));
        }
        for runs in per_node.values_mut() {
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }
        let mut cursor: HashMap<NodeId, usize> =
            ids.iter().map(|&id| (id, 0)).collect();

        let mut t = 0.0;
        while t <= until.0 {
            let mut row = vec![SimTime(t).hms()];
            for id in ids {
                let busy = match per_node.get(id) {
                    None => false,
                    Some(runs) => {
                        let idx = cursor.get_mut(id).expect("cursor seeded");
                        // Skip intervals that ended before this bucket.
                        while *idx < runs.len() && runs[*idx].1 <= t {
                            *idx += 1;
                        }
                        *idx < runs.len()
                            && runs[*idx].0 < t + bucket_secs
                            && runs[*idx].1 > t
                    }
                };
                row.push(if busy { "1".into() } else { "0".into() });
            }
            table.push(row);
            t += bucket_secs;
        }
        table
    }

    /// Figure 11: one row per bucket with counts of nodes per display
    /// state (used / powering_on / idle / powering_off / failed).
    ///
    /// Single forward replay of the (time-ordered) transition log —
    /// O(transitions + buckets) instead of a full scan per bucket, which
    /// cost as much as the entire simulation (EXPERIMENTS §Perf L3).
    pub fn fig11_states(&self, bucket_secs: f64, until: SimTime) -> Table {
        let mut table = Table::new(vec![
            "time", "used", "powering_on", "idle", "powering_off", "failed",
        ]);
        // DES dispatch order makes the log time-sorted already; the
        // stable sort is a cheap guarantee for hand-built recorders.
        let mut ordered: Vec<&(SimTime, NodeId, DisplayState)> =
            self.transitions.iter().collect();
        ordered.sort_by(|a, b| a.0 .0.partial_cmp(&b.0 .0).unwrap());
        let mut current: HashMap<NodeId, DisplayState> = HashMap::new();
        let mut idx = 0usize;
        let mut t = 0.0;
        while t <= until.0 {
            while idx < ordered.len() && ordered[idx].0 .0 <= t {
                let &(_, node, s) = ordered[idx];
                current.insert(node, s);
                idx += 1;
            }
            let count = |want: DisplayState| {
                current.values().filter(|&&s| s == want).count().to_string()
            };
            table.push(vec![
                SimTime(t).hms(),
                count(DisplayState::Used),
                count(DisplayState::PoweringOn),
                count(DisplayState::Idle),
                count(DisplayState::PoweringOff),
                count(DisplayState::Failed),
            ]);
            t += bucket_secs;
        }
        table
    }

    /// Total busy seconds per node (Figure 10 integrals / §4.2 numbers).
    pub fn busy_secs_per_node(&self) -> BTreeMap<String, f64> {
        let mut by_id: HashMap<NodeId, f64> = HashMap::new();
        for &(node, s, e) in &self.job_runs {
            *by_id.entry(node).or_insert(0.0) += e.0 - s.0;
        }
        by_id
            .into_iter()
            .map(|(id, secs)| (self.names.name(id), secs))
            .collect()
    }

    /// Seconds each node spent in each display state up to `until`.
    pub fn state_durations(&self, until: SimTime)
        -> BTreeMap<String, BTreeMap<&'static str, f64>> {
        let mut per_node: HashMap<NodeId,
            Vec<(SimTime, DisplayState)>> = HashMap::new();
        for &(t, n, s) in &self.transitions {
            per_node.entry(n).or_default().push((t, s));
        }
        let mut out = BTreeMap::new();
        for (node, mut evs) in per_node {
            evs.sort_by(|a, b| a.0 .0.partial_cmp(&b.0 .0).unwrap());
            let mut durs: BTreeMap<&'static str, f64> = BTreeMap::new();
            for (i, (t0, s)) in evs.iter().enumerate() {
                let t1 = evs.get(i + 1).map(|(t, _)| t.0).unwrap_or(until.0);
                if t1 > t0.0 {
                    *durs.entry(s.label()).or_insert(0.0) += t1 - t0.0;
                }
            }
            out.insert(self.names.name(node), durs);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn demo() -> Recorder {
        let mut r = Recorder::new();
        r.node_state(t(0.0), "vnode-1", DisplayState::Idle);
        r.node_state(t(10.0), "vnode-1", DisplayState::Used);
        r.node_state(t(50.0), "vnode-1", DisplayState::Idle);
        r.node_state(t(0.0), "vnode-3", DisplayState::PoweringOn);
        r.node_state(t(30.0), "vnode-3", DisplayState::Used);
        r.job_run("vnode-1", t(10.0), t(50.0));
        r.job_run("vnode-3", t(30.0), t(80.0));
        r
    }

    #[test]
    fn states_at_replays_log() {
        let r = demo();
        let s = r.states_at(t(5.0));
        assert_eq!(s["vnode-1"], DisplayState::Idle);
        assert_eq!(s["vnode-3"], DisplayState::PoweringOn);
        let s = r.states_at(t(40.0));
        assert_eq!(s["vnode-1"], DisplayState::Used);
        assert_eq!(s["vnode-3"], DisplayState::Used);
    }

    #[test]
    fn fig10_marks_busy_buckets() {
        let r = demo();
        let tab = r.fig10_usage(20.0, t(80.0));
        let csv = tab.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "time,vnode-1,vnode-3");
        // Bucket [20,40): vnode-1 busy (job 10-50), vnode-3 busy (30-80).
        assert_eq!(lines[2], "00:00:20,1,1");
        // Bucket [60,80): only vnode-3.
        assert_eq!(lines[4], "00:01:00,0,1");
    }

    #[test]
    fn fig11_counts_states() {
        let r = demo();
        let tab = r.fig11_states(30.0, t(60.0));
        let lines: Vec<String> =
            tab.to_csv().lines().map(String::from).collect();
        // At t=0: one idle, one powering on.
        assert_eq!(lines[1], "00:00:00,0,1,1,0,0");
        // At t=30: both used.
        assert_eq!(lines[2], "00:00:30,2,0,0,0,0");
        // At t=60: vnode-1 idle again, vnode-3 used.
        assert_eq!(lines[3], "00:01:00,1,0,1,0,0");
    }

    #[test]
    fn busy_totals() {
        let r = demo();
        let m = r.busy_secs_per_node();
        assert_eq!(m["vnode-1"], 40.0);
        assert_eq!(m["vnode-3"], 50.0);
    }

    #[test]
    fn state_durations_integrate_to_horizon() {
        let r = demo();
        let d = r.state_durations(t(100.0));
        let v1: f64 = d["vnode-1"].values().sum();
        assert!((v1 - 100.0).abs() < 1e-9);
        assert_eq!(d["vnode-1"]["used"], 40.0);
        assert_eq!(d["vnode-3"]["powering_on"], 30.0);
    }

    #[test]
    fn milestones_recorded() {
        let mut r = Recorder::new();
        r.milestone(t(60.0), "AWS vnode-3 joined SLURM");
        assert_eq!(r.milestones.len(), 1);
    }

    #[test]
    fn node_names_first_appearance_order() {
        let mut r = Recorder::new();
        r.node_state(t(0.0), "b", DisplayState::Idle);
        r.node_state(t(1.0), "a", DisplayState::Idle);
        r.node_state(t(2.0), "b", DisplayState::Used); // repeat: no dup
        r.node_state(t(3.0), "c", DisplayState::Idle);
        assert_eq!(r.node_names(), vec!["b", "a", "c"]);
        let named = r.transitions_named();
        assert_eq!(named.len(), 4);
        assert_eq!(named[2].1, "b");
    }

    #[test]
    fn merge_shards_orders_by_time_then_shard() {
        // Two shard recorders with private interners, overlapping times.
        let mut a = Recorder::new();
        a.node_state(t(0.0), "s0-n1", DisplayState::Idle);
        a.node_state(t(10.0), "s0-n1", DisplayState::Used);
        a.job_run("s0-n1", t(10.0), t(20.0));
        a.milestone(t(10.0), "s0 started");
        let mut b = Recorder::new();
        b.node_state(t(5.0), "s1-n1", DisplayState::Idle);
        b.node_state(t(10.0), "s1-n1", DisplayState::Used);
        b.job_run("s1-n1", t(10.0), t(20.0));
        b.milestone(t(10.0), "s1 started");

        let merged = Recorder::merge_shards(NodeNames::new(), &[a, b]);
        // First-appearance order follows the merged (time, shard) order.
        assert_eq!(merged.node_names(), vec!["s0-n1", "s1-n1"]);
        let named = merged.transitions_named();
        assert_eq!(named.len(), 4);
        assert_eq!(named[1].1, "s1-n1"); // t=5 from shard 1
        // At t=10 shard 0 precedes shard 1.
        assert_eq!(named[2].1, "s0-n1");
        assert_eq!(named[3].1, "s1-n1");
        assert_eq!(merged.milestones,
                   vec![(t(10.0), "s0 started".to_string()),
                        (t(10.0), "s1 started".to_string())]);
        assert_eq!(merged.busy_secs_per_node()["s0-n1"], 10.0);
        assert_eq!(merged.busy_secs_per_node()["s1-n1"], 10.0);
    }

    #[test]
    fn id_and_name_recording_agree() {
        let names = NodeNames::new();
        let id = names.intern("wn");
        let mut r = Recorder::with_names(names);
        r.node_state_id(t(0.0), id, DisplayState::Used);
        r.job_run_id(id, t(0.0), t(5.0));
        assert_eq!(r.node_names(), vec!["wn"]);
        assert_eq!(r.busy_secs_per_node()["wn"], 5.0);
    }
}
