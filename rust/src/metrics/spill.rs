//! Streaming per-shard metrics: spill recorder streams to disk during
//! replay, deterministic k-way merge back afterwards.
//!
//! A [`ShardSink`] owns three append-only CSV spill files (state
//! transitions, job runs, milestones) for one shard. Rows are written
//! in record order — which, under the sharded engine, is the shard's
//! dispatch order, so every stream is time-sorted within its file (the
//! merge precondition). Virtual times are serialized as
//! `f64::to_bits` so they roundtrip exactly, and fields go through
//! [`crate::util::csv`] quoting, so names with commas survive.
//!
//! [`Recorder::merge_spills`] replays `k` spill sets through a
//! streaming k-way merge keyed by `(time, shard, in-file order)` — the
//! same key [`Recorder::merge_shards`] sorts by, pass order included
//! (all transitions, then all job runs, then all milestones, so node
//! first-appearance order matches) — holding only one pending row per
//! shard in memory. `tests/shard_equivalence.rs` proves the two merge
//! paths byte-identical down to fig10/fig11 output.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};
use std::fmt;
use std::fs::{self, File};
use std::io::{BufRead, BufReader, BufWriter, Lines, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::ids::NodeNames;
use crate::sim::SimTime;
use crate::util::csv::{format_row, parse_row, Table};

use super::{DisplayState, Recorder};

/// The finished spill set of one shard: three stream files plus the
/// total bytes written. Produced by [`ShardSink::finish`], consumed by
/// [`Recorder::merge_spills`].
#[derive(Debug, Clone)]
pub struct SpillFiles {
    pub shard: u32,
    pub states: PathBuf,
    pub jobs: PathBuf,
    pub notes: PathBuf,
    /// Total bytes written across the three streams.
    pub bytes: u64,
}

impl SpillFiles {
    /// The spill set [`ShardSink::create`] writes for `shard` under
    /// `dir` — the one place the on-disk naming convention lives.
    /// `bytes` is 0: callers locating existing files (rather than
    /// receiving the set from [`ShardSink::finish`]) have no byte
    /// count.
    pub fn locate(dir: impl AsRef<Path>, shard: u32) -> SpillFiles {
        let dir = dir.as_ref();
        let path = |stream: &str| {
            dir.join(format!("shard-{shard:04}.{stream}.csv"))
        };
        SpillFiles {
            shard,
            states: path("states"),
            jobs: path("jobs"),
            notes: path("notes"),
            bytes: 0,
        }
    }
}

/// Streaming writer for one shard's metrics. Mirrors the recording
/// surface of [`Recorder`] but appends every record to a spill file
/// instead of a vector, so a shard's memory footprint stays flat no
/// matter how long the replay runs. IO errors are deferred: the first
/// one is kept and surfaced by [`ShardSink::finish`], keeping the
/// record methods signature-compatible with the hot path.
pub struct ShardSink {
    states: BufWriter<File>,
    jobs: BufWriter<File>,
    notes: BufWriter<File>,
    out: SpillFiles,
    err: Option<std::io::Error>,
}

impl fmt::Debug for ShardSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ShardSink(shard {}, {} bytes)", self.out.shard,
               self.out.bytes)
    }
}

/// Exact-roundtrip serialization of a virtual time.
fn time_bits(t: SimTime) -> String {
    t.0.to_bits().to_string()
}

/// Inverse of [`time_bits`].
fn parse_time_bits(s: &str) -> anyhow::Result<SimTime> {
    let bits: u64 = s
        .parse()
        .map_err(|e| anyhow!("bad time bits {s:?} in spill row: {e}"))?;
    Ok(SimTime(f64::from_bits(bits)))
}

impl ShardSink {
    /// Open the three stream files for `shard` under `dir` (created if
    /// missing). Existing files are truncated.
    pub fn create(dir: impl AsRef<Path>, shard: u32)
        -> anyhow::Result<ShardSink> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)
            .with_context(|| format!("creating spill dir {dir:?}"))?;
        let open = |p: &PathBuf| -> anyhow::Result<BufWriter<File>> {
            let f = File::create(p)
                .with_context(|| format!("creating spill file {p:?}"))?;
            Ok(BufWriter::new(f))
        };
        let out = SpillFiles::locate(dir, shard);
        let mut sink = ShardSink {
            states: open(&out.states)?,
            jobs: open(&out.jobs)?,
            notes: open(&out.notes)?,
            out,
            err: None,
        };
        sink.header();
        Ok(sink)
    }

    fn header(&mut self) {
        let (b, e) = (&mut self.out.bytes, &mut self.err);
        emit(&mut self.states, b, e, &["t_bits", "node", "state"]);
        emit(&mut self.jobs, b, e, &["end_bits", "node", "start_bits"]);
        emit(&mut self.notes, b, e, &["t_bits", "label"]);
    }

    pub fn shard(&self) -> u32 {
        self.out.shard
    }

    /// Bytes written so far (headers included, buffered or flushed).
    pub fn bytes_written(&self) -> u64 {
        self.out.bytes
    }

    /// Record a node display-state transition.
    pub fn node_state(&mut self, t: SimTime, node: &str, s: DisplayState) {
        emit(&mut self.states, &mut self.out.bytes, &mut self.err,
             &[&time_bits(t), node, s.label()]);
    }

    /// Record a completed job run (the stream is keyed by end time, the
    /// same key [`Recorder::merge_shards`] orders runs by).
    pub fn job_run(&mut self, node: &str, start: SimTime, end: SimTime) {
        emit(&mut self.jobs, &mut self.out.bytes, &mut self.err,
             &[&time_bits(end), node, &time_bits(start)]);
    }

    /// Record a narrative milestone.
    pub fn milestone(&mut self, t: SimTime, label: &str) {
        emit(&mut self.notes, &mut self.out.bytes, &mut self.err,
             &[&time_bits(t), label]);
    }

    /// Flush everything and hand back the spill set; surfaces the first
    /// deferred IO error if any write failed.
    pub fn finish(self) -> anyhow::Result<SpillFiles> {
        let ShardSink { mut states, mut jobs, mut notes, out, err } = self;
        if let Some(e) = err {
            return Err(anyhow!("metrics spill write (shard {}): {e}",
                               out.shard));
        }
        states.flush().context("flushing states spill")?;
        jobs.flush().context("flushing jobs spill")?;
        notes.flush().context("flushing notes spill")?;
        Ok(out)
    }
}

/// Append one CSV record; on failure keep the first error and drop the
/// rest (surfaced at [`ShardSink::finish`]). Spilled fields must be
/// newline-free — the readers are line-based, and `format_row`'s
/// quoting cannot hide a raw line break from them — so embedded
/// newlines are rejected through the same deferred-error path rather
/// than silently corrupting the stream.
fn emit(w: &mut BufWriter<File>, bytes: &mut u64,
        err: &mut Option<std::io::Error>, row: &[&str]) {
    if err.is_some() {
        return;
    }
    if row.iter().any(|f| f.contains(['\n', '\r'])) {
        *err = Some(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "spill fields must be newline-free (readers are line-based)",
        ));
        return;
    }
    let line = format_row(row);
    *bytes += line.len() as u64 + 1;
    if let Err(e) = writeln!(w, "{line}") {
        *err = Some(e);
    }
}

/// `f64` time wrapped with the same total order the in-memory merge
/// sorts by (`total_cmp`).
#[derive(PartialEq)]
struct TotalTime(f64);

impl Eq for TotalTime {}

impl PartialOrd for TotalTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TotalTime {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// One shard's stream cursor: line iterator plus the parsed row whose
/// key currently sits in the merge heap.
struct Cursor {
    lines: Lines<BufReader<File>>,
    pending: Option<Vec<String>>,
    path: PathBuf,
}

impl Cursor {
    fn open(path: &Path) -> anyhow::Result<Cursor> {
        let f = File::open(path)
            .with_context(|| format!("opening spill file {path:?}"))?;
        let mut lines = BufReader::new(f).lines();
        // Skip the header row.
        if let Some(h) = lines.next() {
            h.with_context(|| format!("reading spill header {path:?}"))?;
        }
        Ok(Cursor { lines, pending: None, path: path.to_path_buf() })
    }

    /// Read the next row; returns its merge-key time, or `None` at EOF.
    fn advance(&mut self) -> anyhow::Result<Option<f64>> {
        match self.lines.next() {
            None => {
                self.pending = None;
                Ok(None)
            }
            Some(line) => {
                let line = line.with_context(
                    || format!("reading spill file {:?}", self.path))?;
                let fields = parse_row(&line);
                let t = parse_time_bits(fields.first().map(String::as_str)
                        .ok_or_else(|| anyhow!("empty spill row"))?)
                    .with_context(|| format!("in {:?}", self.path))?;
                self.pending = Some(fields);
                Ok(Some(t.0))
            }
        }
    }
}

/// Streaming k-way merge of one stream across shards, ordered by
/// `(time, shard slice index, in-file order)`. Each cursor holds one
/// pending row, so memory is O(shards) regardless of stream length.
/// Precondition: each file is time-sorted (true for DES dispatch-order
/// recording; [`Recorder::merge_shards`] re-sorts and therefore also
/// accepts unsorted input — the property suite runs on engine output,
/// where both agree).
fn merge_stream(
    paths: &[&Path],
    mut apply: impl FnMut(&[String]) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let mut cursors = Vec::with_capacity(paths.len());
    let mut heap: BinaryHeap<Reverse<(TotalTime, usize)>> =
        BinaryHeap::new();
    for (i, &p) in paths.iter().enumerate() {
        let mut cur = Cursor::open(p)?;
        if let Some(t) = cur.advance()? {
            heap.push(Reverse((TotalTime(t), i)));
        }
        cursors.push(cur);
    }
    while let Some(Reverse((_, i))) = heap.pop() {
        let fields = cursors[i]
            .pending
            .take()
            .expect("heap key without a pending row");
        apply(&fields)?;
        if let Some(t) = cursors[i].advance()? {
            heap.push(Reverse((TotalTime(t), i)));
        }
    }
    Ok(())
}

fn field<'a>(row: &'a [String], i: usize, what: &str)
    -> anyhow::Result<&'a str> {
    row.get(i)
        .map(String::as_str)
        .ok_or_else(|| anyhow!("spill row missing field {i} ({what})"))
}

impl Recorder {
    /// Streaming replacement for [`Recorder::merge_shards`]: k-way
    /// merge the per-shard spill sets into one recorder, byte-identical
    /// to the in-memory merge of the recorders that produced them.
    /// Shard order is the slice order, mirroring `merge_shards`.
    pub fn merge_spills(names: NodeNames, spills: &[SpillFiles])
        -> anyhow::Result<Recorder> {
        let mut merged = Recorder::with_names(names);
        let states: Vec<&Path> =
            spills.iter().map(|s| s.states.as_path()).collect();
        merge_stream(&states, |row| {
            let t = parse_time_bits(field(row, 0, "time")?)?;
            let node = field(row, 1, "node")?;
            let label = field(row, 2, "state")?;
            let s = DisplayState::from_label(label).ok_or_else(
                || anyhow!("unknown display state {label:?} in spill"))?;
            merged.node_state(t, node, s);
            Ok(())
        })?;
        let jobs: Vec<&Path> =
            spills.iter().map(|s| s.jobs.as_path()).collect();
        merge_stream(&jobs, |row| {
            let end = parse_time_bits(field(row, 0, "end")?)?;
            let node = field(row, 1, "node")?;
            let start = parse_time_bits(field(row, 2, "start")?)?;
            merged.job_run(node, start, end);
            Ok(())
        })?;
        let notes: Vec<&Path> =
            spills.iter().map(|s| s.notes.as_path()).collect();
        merge_stream(&notes, |row| {
            let t = parse_time_bits(field(row, 0, "time")?)?;
            merged.milestone(t, field(row, 1, "label")?);
            Ok(())
        })?;
        Ok(merged)
    }

    /// Figure 10 straight from the spill streams: one merged pass over
    /// the states streams establishes the node column order, one over
    /// the jobs streams collects compact per-node busy intervals, and
    /// the bucket sweep renders from those — the merged recorder (with
    /// its full transition log and milestone strings) is never
    /// materialized. Byte-identical to
    /// `Recorder::merge_spills(..)?.fig10_usage(..)`.
    pub fn fig10_from_spills(spills: &[SpillFiles], bucket_secs: f64,
                             until: SimTime) -> anyhow::Result<Table> {
        // Column order: first appearance in the merged transition
        // stream (exactly how the in-memory recorder builds `order`).
        let mut index: HashMap<String, usize> = HashMap::new();
        let mut names_in_order: Vec<String> = Vec::new();
        let states: Vec<&Path> =
            spills.iter().map(|s| s.states.as_path()).collect();
        merge_stream(&states, |row| {
            let node = field(row, 1, "node")?;
            if !index.contains_key(node) {
                index.insert(node.to_string(), names_in_order.len());
                names_in_order.push(node.to_string());
            }
            Ok(())
        })?;
        // Busy intervals per column, in merged arrival order (end-time
        // sorted), then stably re-sorted by start like the in-memory
        // renderer.
        let mut per_node: Vec<Vec<(f64, f64)>> =
            vec![Vec::new(); names_in_order.len()];
        let jobs: Vec<&Path> =
            spills.iter().map(|s| s.jobs.as_path()).collect();
        merge_stream(&jobs, |row| {
            let end = parse_time_bits(field(row, 0, "end")?)?;
            let node = field(row, 1, "node")?;
            let start = parse_time_bits(field(row, 2, "start")?)?;
            if let Some(&i) = index.get(node) {
                per_node[i].push((start.0, end.0));
            }
            Ok(())
        })?;
        for runs in &mut per_node {
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }
        let mut header = vec!["time".to_string()];
        header.extend(names_in_order.iter().cloned());
        let mut table = Table::new(header);
        let mut cursor = vec![0usize; per_node.len()];
        let mut t = 0.0;
        while t <= until.0 {
            let mut row = vec![SimTime(t).hms()];
            for (i, runs) in per_node.iter().enumerate() {
                let idx = &mut cursor[i];
                while *idx < runs.len() && runs[*idx].1 <= t {
                    *idx += 1;
                }
                let busy = *idx < runs.len()
                    && runs[*idx].0 < t + bucket_secs
                    && runs[*idx].1 > t;
                row.push(if busy { "1".into() } else { "0".into() });
            }
            table.push(row);
            t += bucket_secs;
        }
        Ok(table)
    }

    /// Figure 11 straight from the spill streams: a single merged pass
    /// over the states streams with O(nodes) live state — buckets are
    /// emitted as stream time passes them, so nothing is accumulated.
    /// Byte-identical to `Recorder::merge_spills(..)?.fig11_states(..)`.
    pub fn fig11_from_spills(spills: &[SpillFiles], bucket_secs: f64,
                             until: SimTime) -> anyhow::Result<Table> {
        fn emit_row(table: &mut Table,
                    current: &HashMap<String, DisplayState>, t: f64) {
            let count = |want: DisplayState| {
                current.values().filter(|&&s| s == want).count().to_string()
            };
            table.push(vec![
                SimTime(t).hms(),
                count(DisplayState::Used),
                count(DisplayState::PoweringOn),
                count(DisplayState::Idle),
                count(DisplayState::PoweringOff),
                count(DisplayState::Failed),
            ]);
        }
        let mut table = Table::new(vec![
            "time", "used", "powering_on", "idle", "powering_off",
            "failed",
        ]);
        let mut current: HashMap<String, DisplayState> = HashMap::new();
        let mut t = 0.0;
        let states: Vec<&Path> =
            spills.iter().map(|s| s.states.as_path()).collect();
        merge_stream(&states, |row| {
            let rt = parse_time_bits(field(row, 0, "time")?)?;
            let node = field(row, 1, "node")?;
            let label = field(row, 2, "state")?;
            let s = DisplayState::from_label(label).ok_or_else(
                || anyhow!("unknown display state {label:?} in spill"))?;
            // A bucket at `t` counts every transition with time <= t,
            // so rows at exactly `t` apply before the bucket is cut.
            while t <= until.0 && rt.0 > t {
                emit_row(&mut table, &current, t);
                t += bucket_secs;
            }
            current.insert(node.to_string(), s);
            Ok(())
        })?;
        while t <= until.0 {
            emit_row(&mut table, &current, t);
            t += bucket_secs;
        }
        Ok(table)
    }

    /// Write this in-memory recorder out as one shard's spill set,
    /// preserving record order — the bridge that lets the two merge
    /// paths be property-compared against each other.
    pub fn spill_to(&self, dir: impl AsRef<Path>, shard: u32)
        -> anyhow::Result<SpillFiles> {
        let mut sink = ShardSink::create(dir, shard)?;
        for &(t, id, s) in &self.transitions {
            sink.node_state(t, &self.names.name(id), s);
        }
        for &(id, s, e) in &self.job_runs {
            sink.job_run(&self.names.name(id), s, e);
        }
        for (t, label) in &self.milestones {
            sink.milestone(*t, label);
        }
        sink.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime(s)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("evhc_spill_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    /// Two shard recorders with awkward names and overlapping times —
    /// the spill roundtrip must agree with the in-memory merge exactly.
    #[test]
    fn spill_merge_matches_in_memory_merge() {
        let mut a = Recorder::new();
        a.node_state(t(0.0), "s0,comma", DisplayState::Idle);
        a.node_state(t(10.0), "s0,comma", DisplayState::Used);
        a.job_run("s0,comma", t(10.0), t(20.0));
        a.milestone(t(10.0), "s0 \"started\"");
        let mut b = Recorder::new();
        b.node_state(t(5.0), "s1-n1", DisplayState::Idle);
        b.node_state(t(10.0), "s1-n1", DisplayState::Used);
        b.job_run("s1-n1", t(10.0), t(20.0));
        b.milestone(t(10.0), "s1 started");

        let dir = tmp("unit_merge");
        let spills = vec![
            a.spill_to(&dir, 0).expect("spill a"),
            b.spill_to(&dir, 1).expect("spill b"),
        ];
        assert!(spills.iter().all(|s| s.bytes > 0));

        let mem = Recorder::merge_shards(NodeNames::new(), &[a, b]);
        let streamed =
            Recorder::merge_spills(NodeNames::new(), &spills).expect("merge");
        assert_eq!(mem.transitions_named(), streamed.transitions_named());
        assert_eq!(mem.milestones, streamed.milestones);
        assert_eq!(mem.node_names(), streamed.node_names());
        assert_eq!(mem.fig10_usage(5.0, t(25.0)).to_csv(),
                   streamed.fig10_usage(5.0, t(25.0)).to_csv());
        assert_eq!(mem.fig11_states(5.0, t(25.0)).to_csv(),
                   streamed.fig11_states(5.0, t(25.0)).to_csv());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn time_bits_roundtrip_is_exact() {
        for v in [0.0, 1.5, 1.0e-12, 12345.678901234567, f64::MAX] {
            let enc = time_bits(SimTime(v));
            let back = parse_time_bits(&enc).expect("roundtrip");
            assert_eq!(back.0.to_bits(), v.to_bits(), "{v}");
        }
        assert!(parse_time_bits("not-bits").is_err());
    }

    #[test]
    fn spill_recorder_mode_streams_instead_of_accumulating() {
        let dir = tmp("unit_mode");
        let sink = ShardSink::create(&dir, 3).expect("sink");
        let names = NodeNames::new();
        let mut rec = Recorder::with_spill(names.clone(), sink);
        assert!(rec.is_spilling());
        rec.node_state(t(1.0), "wn-1", DisplayState::Used);
        rec.job_run("wn-1", t(1.0), t(2.0));
        rec.milestone(t(2.0), "done");
        // Nothing accumulated in memory...
        assert!(rec.transitions.is_empty());
        assert!(rec.job_runs.is_empty());
        assert!(rec.milestones.is_empty());
        // ...but the merged view sees everything.
        let files = rec.finish_spill().expect("spilling").expect("io");
        assert!(!rec.is_spilling());
        assert_eq!(files.shard, 3);
        let merged =
            Recorder::merge_spills(names, &[files]).expect("merge");
        assert_eq!(merged.node_names(), vec!["wn-1"]);
        assert_eq!(merged.busy_secs_per_node()["wn-1"], 1.0);
        assert_eq!(merged.milestones,
                   vec![(t(2.0), "done".to_string())]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn newline_in_field_is_rejected_at_finish() {
        let dir = tmp("unit_newline");
        let mut sink = ShardSink::create(&dir, 0).expect("sink");
        sink.milestone(t(1.0), "line one\nline two");
        let err = sink.finish().expect_err("newline must be rejected");
        assert!(err.to_string().contains("newline-free"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_spills_of_nothing_is_empty() {
        let merged = Recorder::merge_spills(NodeNames::new(), &[])
            .expect("empty merge");
        assert!(merged.transitions.is_empty());
        assert!(merged.node_names().is_empty());
    }

    #[test]
    fn figures_from_spills_match_merged_render() {
        // Overlapping intervals, out-of-order starts within one node,
        // a node that only ever appears in job runs (no column), and
        // transitions at exact bucket boundaries.
        let mut a = Recorder::new();
        a.node_state(t(0.0), "wn-a", DisplayState::PoweringOn);
        a.node_state(t(5.0), "wn-a", DisplayState::Used);
        a.node_state(t(10.0), "wn-a", DisplayState::Idle);
        a.job_run("wn-a", t(5.0), t(9.0));
        a.job_run("wn-a", t(2.0), t(11.0)); // later end, earlier start
        a.job_run("ghost", t(0.0), t(4.0)); // never in transitions
        let mut b = Recorder::new();
        b.node_state(t(1.0), "wn-b", DisplayState::Idle);
        b.node_state(t(10.0), "wn-b", DisplayState::Used);
        b.node_state(t(14.0), "wn-b", DisplayState::Off);
        b.job_run("wn-b", t(10.0), t(14.0));

        let dir = tmp("unit_fig_stream");
        let spills = vec![
            a.spill_to(&dir, 0).expect("spill a"),
            b.spill_to(&dir, 1).expect("spill b"),
        ];
        let merged = Recorder::merge_spills(NodeNames::new(), &spills)
            .expect("merge");
        for bucket in [2.0, 5.0] {
            for until in [0.0, 12.0, 30.0] {
                let f10 = Recorder::fig10_from_spills(
                    &spills, bucket, t(until)).expect("fig10 stream");
                assert_eq!(f10.to_csv(),
                           merged.fig10_usage(bucket, t(until)).to_csv(),
                           "fig10 bucket={bucket} until={until}");
                let f11 = Recorder::fig11_from_spills(
                    &spills, bucket, t(until)).expect("fig11 stream");
                assert_eq!(f11.to_csv(),
                           merged.fig11_states(bucket, t(until)).to_csv(),
                           "fig11 bucket={bucket} until={until}");
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn prop_figures_from_spills_match_merged_render() {
        use crate::util::proptest::check_n;
        use crate::util::prng::Prng;

        // Random per-shard recorders with *time-sorted* streams (the
        // spill precondition, guaranteed by dispatch-order recording in
        // the engines) — the streaming renders must match the merged
        // recorder's byte for byte.
        #[derive(Debug)]
        struct Case {
            shards: Vec<(Vec<(f64, u32, DisplayState)>,
                         Vec<(u32, f64, f64)>)>,
            bucket: f64,
            until: f64,
        }
        let states = [DisplayState::Used, DisplayState::PoweringOn,
                      DisplayState::Idle, DisplayState::PoweringOff,
                      DisplayState::Off, DisplayState::Failed];
        let gen = |r: &mut Prng| {
            let shards = (0..1 + r.next_below(3))
                .map(|_| {
                    let mut ts = 0.0;
                    let trans = (0..r.next_below(20))
                        .map(|_| {
                            ts += r.uniform(0.0, 7.0);
                            (ts, r.next_below(5) as u32,
                             states[r.next_below(6) as usize])
                        })
                        .collect::<Vec<_>>();
                    let mut te = 0.0;
                    let runs = (0..r.next_below(15))
                        .map(|_| {
                            te += r.uniform(0.0, 9.0);
                            (r.next_below(5) as u32,
                             (te - r.uniform(0.0, 30.0)).max(0.0), te)
                        })
                        .collect::<Vec<_>>();
                    (trans, runs)
                })
                .collect();
            Case {
                shards,
                bucket: r.uniform(1.0, 10.0),
                until: r.uniform(0.0, 120.0),
            }
        };
        check_n("fig-from-spills ≡ merged render", 32, gen, |case| {
            let dir = tmp("prop_fig_stream");
            let mut spills = Vec::new();
            for (i, (trans, runs)) in case.shards.iter().enumerate() {
                let mut rec = Recorder::new();
                for &(at, node, s) in trans {
                    rec.node_state(t(at), &format!("wn-{node}"), s);
                }
                for &(node, s, e) in runs {
                    rec.job_run(&format!("wn-{node}"), t(s), t(e));
                }
                spills.push(rec.spill_to(&dir, i as u32)
                    .map_err(|e| e.to_string())?);
            }
            let merged = Recorder::merge_spills(NodeNames::new(), &spills)
                .map_err(|e| e.to_string())?;
            let f10 = Recorder::fig10_from_spills(
                &spills, case.bucket, t(case.until))
                .map_err(|e| e.to_string())?;
            if f10.to_csv()
                != merged.fig10_usage(case.bucket, t(case.until)).to_csv()
            {
                return Err("fig10 diverged".into());
            }
            let f11 = Recorder::fig11_from_spills(
                &spills, case.bucket, t(case.until))
                .map_err(|e| e.to_string())?;
            if f11.to_csv()
                != merged.fig11_states(case.bucket, t(case.until)).to_csv()
            {
                return Err("fig11 diverged".into());
            }
            let _ = fs::remove_dir_all(&dir);
            Ok(())
        });
    }
}
