//! Interned node identity.
//!
//! Node names (`"vnode-5"`, `"front-end"`, …) used to be the unit of
//! identity across the LRMS, CLUES, the cluster world and the metrics
//! recorder — every scheduling decision hashed and cloned `String`s. At
//! the 10k-node/1M-job scale the simulator targets, that dominated the
//! profile. [`NodeId`] is a dense `u32` issued by a [`NodeNames`]
//! interner that all subsystems of one cluster share; names survive only
//! at the edges (TOSCA parsing, reports, API JSON, log lines).
//!
//! `NodeNames` is a cheaply-clonable handle (`Arc<RwLock<..>>`): every
//! accessor scopes its lock internally so handles can be held by several
//! subsystems at once, and the handle is `Send + Sync` so per-site shard
//! states (each owning a core + interner) can replay on worker threads
//! in the sharded engine. Within one cluster the interner is only ever
//! touched from one thread at a time, so the uncontended lock cost is
//! noise — and interning sits at the edges (registration, reporting),
//! not in the scheduling hot path.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, RwLock};

/// Dense interned node identifier. The numeric value doubles as the
/// index into id-keyed tables (`Vec<Option<..>>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    /// The id as a table index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node#{}", self.0)
    }
}

#[derive(Debug, Default)]
struct Inner {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

/// Shared name⇄id interner (one per cluster).
#[derive(Debug, Clone, Default)]
pub struct NodeNames(Arc<RwLock<Inner>>);

impl NodeNames {
    pub fn new() -> NodeNames {
        NodeNames::default()
    }

    /// Id for `name`, interning it on first sight.
    pub fn intern(&self, name: &str) -> NodeId {
        let mut g = self.0.write().expect("interner poisoned");
        if let Some(&i) = g.index.get(name) {
            return NodeId(i);
        }
        let i = g.names.len() as u32;
        g.names.push(name.to_string());
        g.index.insert(name.to_string(), i);
        NodeId(i)
    }

    /// Id for `name` if it was interned before (no insertion).
    pub fn get(&self, name: &str) -> Option<NodeId> {
        self.0
            .read()
            .expect("interner poisoned")
            .index
            .get(name)
            .map(|&i| NodeId(i))
    }

    /// Owned name for `id` (edge paths only: reports, logs).
    pub fn name(&self, id: NodeId) -> String {
        self.0
            .read()
            .expect("interner poisoned")
            .names
            .get(id.index())
            .cloned()
            .unwrap_or_else(|| format!("node#{}", id.0))
    }

    /// Run `f` over the borrowed name without cloning. `f` must not
    /// touch this interner (the lock is held while it runs).
    pub fn with_name<R>(&self, id: NodeId, f: impl FnOnce(&str) -> R) -> R {
        let g = self.0.read().expect("interner poisoned");
        f(g.names.get(id.index()).map(|s| s.as_str()).unwrap_or("?"))
    }

    pub fn len(&self) -> usize {
        self.0.read().expect("interner poisoned").names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Dense interned cloud-site identifier (mirrors [`NodeId`]). Site
/// names (`"CESNET-MCC"`, `"AWS"`, …) are interned once when a world is
/// built; every per-decision structure in the elasticity broker —
/// health snapshots, placement signals, cost rates — is keyed by this
/// `u32`, so the grow/shrink site-selection hot path performs no string
/// hashing or cloning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SiteId(pub u32);

impl SiteId {
    /// The id as a table index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for SiteId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "site#{}", self.0)
    }
}

/// Shared site-name⇄id interner (one per cluster world; mirrors
/// [`NodeNames`]). Ids are issued densely in interning order, so a
/// world that interns its sites in construction order can use
/// `SiteId(i)` and the site vector index interchangeably.
#[derive(Debug, Clone, Default)]
pub struct SiteNames(Arc<RwLock<Inner>>);

impl SiteNames {
    pub fn new() -> SiteNames {
        SiteNames::default()
    }

    /// Id for `name`, interning it on first sight.
    pub fn intern(&self, name: &str) -> SiteId {
        let mut g = self.0.write().expect("interner poisoned");
        if let Some(&i) = g.index.get(name) {
            return SiteId(i);
        }
        let i = g.names.len() as u32;
        g.names.push(name.to_string());
        g.index.insert(name.to_string(), i);
        SiteId(i)
    }

    /// Id for `name` if it was interned before (no insertion).
    pub fn get(&self, name: &str) -> Option<SiteId> {
        self.0
            .read()
            .expect("interner poisoned")
            .index
            .get(name)
            .map(|&i| SiteId(i))
    }

    /// Owned name for `id` (edge paths only: reports, logs).
    pub fn name(&self, id: SiteId) -> String {
        self.0
            .read()
            .expect("interner poisoned")
            .names
            .get(id.index())
            .cloned()
            .unwrap_or_else(|| format!("site#{}", id.0))
    }

    /// Run `f` over the borrowed name without cloning. `f` must not
    /// touch this interner (the lock is held while it runs).
    pub fn with_name<R>(&self, id: SiteId, f: impl FnOnce(&str) -> R) -> R {
        let g = self.0.read().expect("interner poisoned");
        f(g.names.get(id.index()).map(|s| s.as_str()).unwrap_or("?"))
    }

    /// Lexicographic order of two interned names under one lock — the
    /// deterministic final tie-break of site ranking, without cloning.
    pub fn cmp_names(&self, a: SiteId, b: SiteId) -> std::cmp::Ordering {
        let g = self.0.read().expect("interner poisoned");
        let na = g.names.get(a.index()).map(|s| s.as_str()).unwrap_or("");
        let nb = g.names.get(b.index()).map(|s| s.as_str()).unwrap_or("");
        na.cmp(nb)
    }

    pub fn len(&self) -> usize {
        self.0.read().expect("interner poisoned").names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_dense_and_stable() {
        let n = NodeNames::new();
        let a = n.intern("vnode-1");
        let b = n.intern("vnode-2");
        assert_eq!(a, NodeId(0));
        assert_eq!(b, NodeId(1));
        assert_eq!(n.intern("vnode-1"), a); // idempotent
        assert_eq!(n.len(), 2);
        assert_eq!(n.name(a), "vnode-1");
        assert_eq!(n.get("vnode-2"), Some(b));
        assert_eq!(n.get("vnode-3"), None);
    }

    #[test]
    fn handles_share_state() {
        let n = NodeNames::new();
        let m = n.clone();
        let a = n.intern("x");
        assert_eq!(m.get("x"), Some(a));
        assert!(m.with_name(a, |s| s == "x"));
    }

    #[test]
    fn unknown_id_renders_placeholder() {
        let n = NodeNames::new();
        assert_eq!(n.name(NodeId(9)), "node#9");
    }

    #[test]
    fn site_interning_mirrors_node_interning() {
        let s = SiteNames::new();
        let a = s.intern("CESNET-MCC");
        let b = s.intern("AWS");
        assert_eq!(a, SiteId(0));
        assert_eq!(b, SiteId(1));
        assert_eq!(s.intern("CESNET-MCC"), a);
        assert_eq!(s.get("AWS"), Some(b));
        assert_eq!(s.get("nope"), None);
        assert_eq!(s.name(a), "CESNET-MCC");
        assert_eq!(s.name(SiteId(9)), "site#9");
        assert_eq!(s.cmp_names(b, a), std::cmp::Ordering::Less); // AWS < CES
        assert_eq!(s.cmp_names(a, a), std::cmp::Ordering::Equal);
        assert!(s.with_name(b, |n| n == "AWS"));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn handles_cross_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NodeNames>();
        let n = NodeNames::new();
        let id = n.intern("x");
        let m = n.clone();
        std::thread::scope(|s| {
            s.spawn(move || assert_eq!(m.name(id), "x"))
                .join()
                .unwrap();
        });
    }
}
