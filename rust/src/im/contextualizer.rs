//! Ansible-like contextualization pipeline.
//!
//! The IM configures every VM from the front-end over SSH reverse
//! tunnels. Each node role runs a sequence of stages (package installs,
//! service config, NFS mounts, vRouter setup…); stage durations are
//! sampled around realistic medians so a worker node lands at the paper's
//! ~13–15 minutes of configuration time (which, plus VM boot and the
//! orchestrator's serialized workflow, yields the observed ~19–20 min
//! node power-on).

use crate::tosca::LrmsKind;
use crate::util::prng::Prng;

/// Node roles the IM knows how to contextualize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRole {
    /// Cluster front-end: LRMS controller + NFS server + vRouter CP.
    FrontEnd,
    /// Worker node.
    WorkerNode,
    /// Per-site vRouter appliance.
    SiteVRouter,
}

/// One contextualization stage (an Ansible role application).
#[derive(Debug, Clone)]
pub struct CtxStage {
    pub name: &'static str,
    pub secs: f64,
}

/// Median stage durations (seconds). Sampled log-normally with sigma 0.15
/// per stage to model real Ansible-run variance.
fn stage_medians(role: NodeRole, lrms: LrmsKind) -> Vec<(&'static str, f64)> {
    let lrms_server: (&'static str, f64) = match lrms {
        LrmsKind::Slurm => ("slurm-controller", 170.0),
        LrmsKind::HtCondor => ("condor-collector", 150.0),
    };
    let lrms_worker: (&'static str, f64) = match lrms {
        LrmsKind::Slurm => ("slurm-worker", 320.0),
        LrmsKind::HtCondor => ("condor-startd", 280.0),
    };
    match role {
        NodeRole::FrontEnd => vec![
            ("apt-base-packages", 150.0),
            ("ansible-bootstrap", 60.0),
            ("nfs-server", 90.0),
            lrms_server,
            ("clues-install", 120.0),
            ("vrouter-central-point", 110.0),
            ("easy-rsa-ca-init", 30.0),
        ],
        NodeRole::WorkerNode => vec![
            // Totals ~980 s median: with ~2.5 min VM boot this lands at
            // the paper's ~19 minutes per AWS node (deploy+config+join).
            ("apt-base-packages", 280.0),
            ("nfs-client-mount", 60.0),
            lrms_worker,
            ("udocker-prereqs", 180.0),
            ("dhcp-gateway-config", 20.0),
            ("node-join", 120.0),
        ],
        NodeRole::SiteVRouter => vec![
            ("apt-base-packages", 150.0),
            ("openvpn-install", 70.0),
            ("cert-retrieve-callback", 12.0),
            ("vrouter-configure", 60.0),
            ("dhcp-server-config", 25.0),
        ],
    }
}

/// Sample a contextualization plan for one node.
pub fn plan(role: NodeRole, lrms: LrmsKind, rng: &mut Prng) -> Vec<CtxStage> {
    stage_medians(role, lrms)
        .into_iter()
        .map(|(name, median)| CtxStage {
            name,
            secs: rng.lognormal(median, 0.15),
        })
        .collect()
}

/// Total duration of a plan.
pub fn total_secs(stages: &[CtxStage]) -> f64 {
    stages.iter().map(|s| s.secs).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_plan_lands_in_paper_range() {
        let mut rng = Prng::new(77);
        let mut totals = Vec::new();
        for _ in 0..50 {
            let p = plan(NodeRole::WorkerNode, LrmsKind::Slurm, &mut rng);
            totals.push(total_secs(&p));
        }
        let mean = totals.iter().sum::<f64>() / totals.len() as f64;
        // ~980 s median: boot (~2.5 min) + this ≈ the paper's ~19 min
        // AWS node power-on.
        assert!(mean > 800.0 && mean < 1250.0, "mean={mean}");
    }

    #[test]
    fn frontend_has_cp_and_ca_stages() {
        let mut rng = Prng::new(1);
        let p = plan(NodeRole::FrontEnd, LrmsKind::Slurm, &mut rng);
        let names: Vec<&str> = p.iter().map(|s| s.name).collect();
        assert!(names.contains(&"vrouter-central-point"));
        assert!(names.contains(&"easy-rsa-ca-init"));
        assert!(names.contains(&"slurm-controller"));
    }

    #[test]
    fn vrouter_plan_contains_cert_callback() {
        let mut rng = Prng::new(2);
        let p = plan(NodeRole::SiteVRouter, LrmsKind::Slurm, &mut rng);
        assert!(p.iter().any(|s| s.name == "cert-retrieve-callback"));
        assert!(total_secs(&p) > 120.0);
    }

    #[test]
    fn lrms_kind_changes_stages() {
        let mut rng = Prng::new(3);
        let s = plan(NodeRole::WorkerNode, LrmsKind::Slurm, &mut rng);
        let c = plan(NodeRole::WorkerNode, LrmsKind::HtCondor, &mut rng);
        assert!(s.iter().any(|st| st.name == "slurm-worker"));
        assert!(c.iter().any(|st| st.name == "condor-startd"));
    }

    #[test]
    fn durations_positive_and_varied() {
        let mut rng = Prng::new(4);
        let a = plan(NodeRole::WorkerNode, LrmsKind::Slurm, &mut rng);
        let b = plan(NodeRole::WorkerNode, LrmsKind::Slurm, &mut rng);
        assert!(a.iter().all(|s| s.secs > 0.0));
        assert_ne!(total_secs(&a), total_secs(&b));
    }
}
