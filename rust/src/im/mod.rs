//! Infrastructure Manager (IM) analogue.
//!
//! The IM is the multi-cloud provisioning arm of the stack (§3.3): it
//! talks to each site's API (here: [`crate::cloudsim::CloudSite`]),
//! creates networks *first*, boots VMs attached to them, wires SSH
//! reverse tunnels so Ansible can reach private-IP nodes from the single
//! public-IP front-end, runs contextualization, and exposes the
//! certificate callback the vRouter CA uses.
//!
//! The IM itself is synchronous bookkeeping: it *plans* operations and
//! returns their simulated durations; the cluster world schedules the
//! completion events on the DES queue.

pub mod contextualizer;
pub mod radl;

pub use contextualizer::{plan as ctx_plan, total_secs as ctx_total_secs,
                         CtxStage, NodeRole};

use std::collections::HashMap;

use anyhow::bail;

use crate::cloudsim::{CloudSite, NetworkId, VmId, VmRequest, VmTicket};
use crate::sim::SimTime;
use crate::tosca::LrmsKind;
use crate::util::prng::Prng;

/// SSH reverse-tunnel fabric: every private node keeps a reverse tunnel
/// to the front-end so the Ansible control node can reach it without a
/// public IP (the IM's signature trick).
#[derive(Debug, Default)]
pub struct SshTunnelFabric {
    /// node name → established at
    tunnels: HashMap<String, SimTime>,
    pub master: Option<String>,
}

impl SshTunnelFabric {
    pub fn set_master(&mut self, name: &str) {
        self.master = Some(name.to_string());
    }

    pub fn open(&mut self, node: &str, t: SimTime) -> anyhow::Result<()> {
        if self.master.is_none() {
            bail!("no master node set for the tunnel fabric");
        }
        self.tunnels.insert(node.to_string(), t);
        Ok(())
    }

    pub fn close(&mut self, node: &str) {
        self.tunnels.remove(node);
    }

    pub fn reachable(&self, node: &str) -> bool {
        self.tunnels.contains_key(node)
            || self.master.as_deref() == Some(node)
    }

    pub fn count(&self) -> usize {
        self.tunnels.len()
    }
}

/// A fully-specified node provisioning operation, with every simulated
/// latency the cluster world needs to schedule.
#[derive(Debug)]
pub struct NodeProvision {
    pub site_idx: usize,
    pub vm: VmId,
    pub name: String,
    pub role: NodeRole,
    /// Seconds until the VM is Running (from request).
    pub boot_secs: f64,
    /// Whether the boot will fail (failure injection).
    pub boot_fails: bool,
    /// Contextualization stages to run once the VM is up.
    pub ctx: Vec<CtxStage>,
    /// Total contextualization seconds (sum of stages).
    pub ctx_secs: f64,
}

/// The Infrastructure Manager.
pub struct Im {
    rng: Prng,
    /// Per-deployment created networks: site index → network.
    pub networks: HashMap<usize, NetworkId>,
    pub tunnels: SshTunnelFabric,
    /// Log of (site, vm name, stage) for reports.
    pub ctx_log: Vec<(String, String, &'static str)>,
}

impl Im {
    pub fn new(seed: u64) -> Im {
        Im {
            rng: Prng::new(seed ^ 0x1111),
            networks: HashMap::new(),
            tunnels: SshTunnelFabric::default(),
            ctx_log: Vec::new(),
        }
    }

    /// Step 1 of the paper's §3.1 flow: create the per-site private
    /// network (idempotent per site). The caller hands the IM the one
    /// site it is operating on (`site_idx` keys the per-deployment
    /// network map — in the site-partitioned cluster world, site state
    /// is owned by that site's shard, so the IM never sees the whole
    /// site vector). Returns (network, creation secs; 0 if it already
    /// existed).
    pub fn ensure_network(&mut self, site: &mut CloudSite,
                          site_idx: usize, deployment: &str)
        -> anyhow::Result<(NetworkId, f64)> {
        if let Some(&net) = self.networks.get(&site_idx) {
            return Ok((net, 0.0));
        }
        let (net, secs) =
            site.create_network(&format!("{deployment}-net"))?;
        self.networks.insert(site_idx, net);
        Ok((net, secs))
    }

    /// Provision one node: network-first, then the VM (public IP only for
    /// the front-end / CP), then plan its contextualization.
    #[allow(clippy::too_many_arguments)]
    pub fn provision_node(
        &mut self,
        site: &mut CloudSite,
        site_idx: usize,
        deployment: &str,
        name: &str,
        role: NodeRole,
        instance_type: &str,
        lrms: LrmsKind,
        t: SimTime,
    ) -> anyhow::Result<NodeProvision> {
        let (net, _net_secs) =
            self.ensure_network(site, site_idx, deployment)?;
        let public_ip = role == NodeRole::FrontEnd;
        let ticket: VmTicket = site.request_vm(
            &VmRequest {
                name: name.to_string(),
                instance_type: instance_type.to_string(),
                network: Some(net),
                public_ip,
            },
            t,
        )?;
        let ctx = ctx_plan(role, lrms, &mut self.rng);
        let ctx_secs = ctx_total_secs(&ctx);
        for s in &ctx {
            self.ctx_log.push((site.name().to_string(), name.to_string(),
                               s.name));
        }
        Ok(NodeProvision {
            site_idx,
            vm: ticket.vm,
            name: name.to_string(),
            role,
            boot_secs: ticket.boot_secs,
            boot_fails: ticket.will_fail,
            ctx,
            ctx_secs,
        })
    }

    /// After the FE is Running: it becomes the Ansible master.
    pub fn establish_master(&mut self, fe_name: &str) {
        self.tunnels.set_master(fe_name);
    }

    /// After any other VM is Running: open its reverse tunnel.
    pub fn connect_node(&mut self, node: &str, t: SimTime)
        -> anyhow::Result<()> {
        self.tunnels.open(node, t)
    }

    /// Certificate callback (§3.5.5): the orchestration layer retrieves
    /// client certs generated at the CP. Returns the subject it issued.
    pub fn retrieve_certificate(
        &mut self,
        overlay: &mut crate::vrouter::Overlay,
        subject: &str,
        t: SimTime,
    ) -> anyhow::Result<String> {
        // The IM only relays; issuance happens at the CP's CA.
        if overlay.ca.verify(subject) {
            return Ok(subject.to_string());
        }
        overlay.ca.issue(subject, t)?;
        Ok(subject.to_string())
    }

    /// Tear down a node (terminate + close its tunnel). Returns the
    /// provider termination latency.
    pub fn decommission_node(&mut self, site: &mut CloudSite, vm: VmId,
                             name: &str, t: SimTime)
        -> anyhow::Result<f64> {
        let secs = site.terminate_vm(vm, t)?;
        self.tunnels.close(name);
        Ok(secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudsim::SiteSpec;
    use crate::netsim::NetId;

    fn sites() -> Vec<CloudSite> {
        vec![
            CloudSite::new(SiteSpec::cesnet_metacentrum(), 0, NetId(0), 1),
            CloudSite::new(SiteSpec::aws_us_east_2(), 1, NetId(1), 2),
        ]
    }

    #[test]
    fn network_first_then_vm() {
        let mut s = sites();
        let mut im = Im::new(9);
        let p = im
            .provision_node(&mut s[0], 0, "dep1", "front-end",
                            NodeRole::FrontEnd, "standard.medium",
                            LrmsKind::Slurm, SimTime(0.0))
            .unwrap();
        assert!(p.boot_secs > 0.0);
        assert!(p.ctx_secs > 300.0); // FE has the long CP/CA stages
        assert_eq!(im.networks.len(), 1);
        let vm = s[0].vm(p.vm).unwrap();
        assert!(vm.public_ip.is_some(), "FE needs the public IP");
        assert!(vm.private_ip.is_some());
    }

    #[test]
    fn network_reused_across_nodes_same_site() {
        let mut s = sites();
        let mut im = Im::new(9);
        im.provision_node(&mut s[1], 1, "dep1", "vnode-3",
                          NodeRole::WorkerNode, "t2.medium",
                          LrmsKind::Slurm, SimTime(0.0))
            .unwrap();
        let (net1, secs1) = im.ensure_network(&mut s[1], 1, "dep1").unwrap();
        assert_eq!(secs1, 0.0); // already created
        let p2 = im
            .provision_node(&mut s[1], 1, "dep1", "vnode-4",
                            NodeRole::WorkerNode, "t2.medium",
                            LrmsKind::Slurm, SimTime(5.0))
            .unwrap();
        assert_eq!(s[1].vm(p2.vm).unwrap().network, Some(net1));
        assert_eq!(s[1].networks.count(), 1);
    }

    #[test]
    fn workers_get_no_public_ip() {
        let mut s = sites();
        let mut im = Im::new(9);
        let p = im
            .provision_node(&mut s[1], 1, "dep1", "vnode-3",
                            NodeRole::WorkerNode, "t2.medium",
                            LrmsKind::Slurm, SimTime(0.0))
            .unwrap();
        assert!(s[1].vm(p.vm).unwrap().public_ip.is_none());
    }

    #[test]
    fn tunnel_fabric_requires_master() {
        let mut im = Im::new(1);
        assert!(im.connect_node("wn1", SimTime(0.0)).is_err());
        im.establish_master("front-end");
        im.connect_node("wn1", SimTime(1.0)).unwrap();
        assert!(im.tunnels.reachable("wn1"));
        assert!(im.tunnels.reachable("front-end"));
        assert!(!im.tunnels.reachable("wn2"));
        im.tunnels.close("wn1");
        assert!(!im.tunnels.reachable("wn1"));
    }

    #[test]
    fn certificate_callback_issues_once() {
        let mut im = Im::new(1);
        let mut ov = crate::vrouter::Overlay::new(
            crate::netsim::Cipher::Aes256Gcm);
        im.retrieve_certificate(&mut ov, "vrouter-aws", SimTime(0.0))
            .unwrap();
        // Second retrieval is idempotent.
        im.retrieve_certificate(&mut ov, "vrouter-aws", SimTime(1.0))
            .unwrap();
        assert_eq!(ov.ca.issued_count(), 1);
    }

    #[test]
    fn decommission_terminates_and_closes_tunnel() {
        let mut s = sites();
        let mut im = Im::new(9);
        im.establish_master("front-end");
        let p = im
            .provision_node(&mut s[1], 1, "dep1", "vnode-3",
                            NodeRole::WorkerNode, "t2.medium",
                            LrmsKind::Slurm, SimTime(0.0))
            .unwrap();
        s[1].complete_boot(p.vm, false, SimTime(120.0)).unwrap();
        im.connect_node("vnode-3", SimTime(121.0)).unwrap();
        let secs = im
            .decommission_node(&mut s[1], p.vm, "vnode-3", SimTime(500.0))
            .unwrap();
        assert!(secs > 0.0);
        assert!(!im.tunnels.reachable("vnode-3"));
    }

    #[test]
    fn quota_errors_propagate() {
        let mut s = sites();
        let mut im = Im::new(9);
        // CESNET quota: 3 VMs.
        for i in 0..3 {
            im.provision_node(&mut s[0], 0, "dep1", &format!("n{i}"),
                              NodeRole::WorkerNode, "standard.medium",
                              LrmsKind::Slurm, SimTime(0.0))
                .unwrap();
        }
        let err = im.provision_node(&mut s[0], 0, "dep1", "n3",
                                    NodeRole::WorkerNode, "standard.medium",
                                    LrmsKind::Slurm, SimTime(0.0));
        assert!(err.is_err());
    }
}
