//! RADL — the IM's native Resource and Application Description Language
//! (§3.3: the IM accepts both TOSCA and RADL).
//!
//! Supports the subset the EC3/IM ecosystem actually uses for clusters:
//!
//! ```text
//! network private ()
//! network public (outbound = 'yes')
//! system front (
//!   cpu.count >= 2 and
//!   memory.size >= 4g and
//!   net_interface.0.connection = 'private' and
//!   net_interface.1.connection = 'public'
//! )
//! system wn (
//!   cpu.count >= 2 and
//!   memory.size >= 4096m
//! )
//! deploy front 1
//! deploy wn 2
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// A feature constraint value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Str(String),
}

/// One `feature op value` constraint.
#[derive(Debug, Clone, PartialEq)]
pub struct Constraint {
    pub feature: String,
    /// One of `=`, `>=`, `<=`.
    pub op: String,
    pub value: Value,
}

/// A `system` block: named set of constraints.
#[derive(Debug, Clone, Default)]
pub struct System {
    pub name: String,
    pub constraints: Vec<Constraint>,
}

impl System {
    fn constraint(&self, feature: &str) -> Option<&Constraint> {
        self.constraints.iter().find(|c| c.feature == feature)
    }

    /// Required vCPUs (`cpu.count >= N`), defaulting to 1.
    pub fn cpu_count(&self) -> u32 {
        match self.constraint("cpu.count") {
            Some(Constraint { value: Value::Num(n), .. }) => *n as u32,
            _ => 1,
        }
    }

    /// Required memory in GB (`memory.size >= Ng|Nm`), defaulting to 1.
    pub fn memory_gb(&self) -> f64 {
        match self.constraint("memory.size") {
            Some(Constraint { value: Value::Num(n), .. }) => *n,
            _ => 1.0,
        }
    }

    /// Does this system ask for a public interface?
    pub fn wants_public_ip(&self) -> bool {
        self.constraints.iter().any(|c| {
            c.feature.starts_with("net_interface.")
                && c.feature.ends_with(".connection")
                && c.value == Value::Str("public".into())
        })
    }
}

/// A parsed RADL document.
#[derive(Debug, Clone, Default)]
pub struct Radl {
    /// network name → attributes.
    pub networks: BTreeMap<String, BTreeMap<String, String>>,
    pub systems: Vec<System>,
    /// (system name, count) in order.
    pub deploys: Vec<(String, u32)>,
}

impl Radl {
    pub fn system(&self, name: &str) -> Option<&System> {
        self.systems.iter().find(|s| s.name == name)
    }

    /// Total VMs the document deploys.
    pub fn total_vms(&self) -> u32 {
        self.deploys.iter().map(|(_, n)| n).sum()
    }

    /// Semantic validation: deploys must reference defined systems and
    /// referenced networks must exist.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, _) in &self.deploys {
            if self.system(name).is_none() {
                bail!("deploy of undefined system {name:?}");
            }
        }
        for sys in &self.systems {
            for c in &sys.constraints {
                if c.feature.ends_with(".connection") {
                    if let Value::Str(net) = &c.value {
                        if !self.networks.contains_key(net) {
                            bail!("system {:?} references undefined \
                                   network {net:?}", sys.name);
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Parse a memory literal: `4g`, `4096m`, `4`, `512M` → GB.
fn parse_mem(v: &str) -> Option<f64> {
    let lower = v.to_ascii_lowercase();
    if let Some(n) = lower.strip_suffix('g') {
        n.trim().parse::<f64>().ok()
    } else if let Some(n) = lower.strip_suffix('m') {
        n.trim().parse::<f64>().ok().map(|x| x / 1024.0)
    } else {
        lower.trim().parse::<f64>().ok()
    }
}

fn parse_value(feature: &str, raw: &str) -> Value {
    let raw = raw.trim();
    if raw.starts_with('\'') && raw.ends_with('\'') && raw.len() >= 2 {
        return Value::Str(raw[1..raw.len() - 1].to_string());
    }
    if feature == "memory.size" {
        if let Some(gb) = parse_mem(raw) {
            return Value::Num(gb);
        }
    }
    raw.parse::<f64>().map(Value::Num).unwrap_or_else(|_| {
        Value::Str(raw.to_string())
    })
}

fn parse_constraints(body: &str) -> anyhow::Result<Vec<Constraint>> {
    let mut out = Vec::new();
    for clause in body.split(" and ") {
        let clause = clause.trim().trim_end_matches("and").trim();
        if clause.is_empty() {
            continue;
        }
        // Order matters: check >= / <= before =.
        let (op, idx) = if let Some(i) = clause.find(">=") {
            (">=", i)
        } else if let Some(i) = clause.find("<=") {
            ("<=", i)
        } else if let Some(i) = clause.find('=') {
            ("=", i)
        } else {
            bail!("constraint without operator: {clause:?}");
        };
        let feature = clause[..idx].trim().to_string();
        let raw = clause[idx + op.len()..].trim();
        out.push(Constraint {
            value: parse_value(&feature, raw),
            feature,
            op: op.to_string(),
        });
    }
    Ok(out)
}

/// Parse a RADL document.
pub fn parse(src: &str) -> anyhow::Result<Radl> {
    let mut radl = Radl::default();
    // Normalize: join continued lines inside parentheses.
    let mut joined = String::new();
    let mut depth = 0i32;
    for ch in src.chars() {
        match ch {
            '(' => {
                depth += 1;
                joined.push(ch);
            }
            ')' => {
                depth -= 1;
                joined.push(ch);
            }
            '\n' if depth > 0 => joined.push(' '),
            _ => joined.push(ch),
        }
    }
    if depth != 0 {
        bail!("unbalanced parentheses");
    }

    for (lineno, line) in joined.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut words = line.split_whitespace();
        match words.next() {
            Some("network") => {
                let name = words
                    .next()
                    .with_context(|| format!("line {}: network needs a \
                                              name", lineno + 1))?;
                let rest = line[line.find(name).unwrap() + name.len()..]
                    .trim();
                let mut attrs = BTreeMap::new();
                if rest.starts_with('(') && rest.ends_with(')') {
                    for kv in rest[1..rest.len() - 1].split(" and ") {
                        if let Some((k, v)) = kv.split_once('=') {
                            attrs.insert(
                                k.trim().to_string(),
                                v.trim().trim_matches('\'').to_string());
                        }
                    }
                }
                radl.networks.insert(name.to_string(), attrs);
            }
            Some("system") => {
                let name = words
                    .next()
                    .with_context(|| format!("line {}: system needs a \
                                              name", lineno + 1))?;
                let open = line.find('(').with_context(|| {
                    format!("line {}: system body missing", lineno + 1)
                })?;
                let close = line.rfind(')').context("missing )")?;
                radl.systems.push(System {
                    name: name.to_string(),
                    constraints: parse_constraints(&line[open + 1..close])?,
                });
            }
            Some("deploy") => {
                let name = words.next().context("deploy needs a system")?;
                let count: u32 = words
                    .next()
                    .context("deploy needs a count")?
                    .parse()?;
                radl.deploys.push((name.to_string(), count));
            }
            Some(other) => bail!("line {}: unknown directive {other:?}",
                                 lineno + 1),
            None => {}
        }
    }
    radl.validate()?;
    Ok(radl)
}

/// The EC3-style cluster RADL equivalent of the built-in SLURM template.
pub const SLURM_CLUSTER_RADL: &str = "\
network private ()
network public (outbound = 'yes')
system front (
  cpu.count >= 2 and
  memory.size >= 4g and
  net_interface.0.connection = 'private' and
  net_interface.1.connection = 'public'
)
system wn (
  cpu.count >= 2 and
  memory.size >= 4g and
  net_interface.0.connection = 'private'
)
deploy front 1
deploy wn 2
";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_cluster_radl() {
        let r = parse(SLURM_CLUSTER_RADL).unwrap();
        assert_eq!(r.networks.len(), 2);
        assert_eq!(r.networks["public"]["outbound"], "yes");
        assert_eq!(r.systems.len(), 2);
        assert_eq!(r.deploys, vec![("front".to_string(), 1),
                                   ("wn".to_string(), 2)]);
        assert_eq!(r.total_vms(), 3);
    }

    #[test]
    fn system_accessors() {
        let r = parse(SLURM_CLUSTER_RADL).unwrap();
        let front = r.system("front").unwrap();
        assert_eq!(front.cpu_count(), 2);
        assert_eq!(front.memory_gb(), 4.0);
        assert!(front.wants_public_ip());
        let wn = r.system("wn").unwrap();
        assert!(!wn.wants_public_ip());
    }

    #[test]
    fn memory_units() {
        let r = parse("system s (\n memory.size >= 4096m\n)\ndeploy s 1\n")
            .unwrap();
        assert_eq!(r.system("s").unwrap().memory_gb(), 4.0);
        let r = parse("system s (\n memory.size >= 8g\n)\ndeploy s 1\n")
            .unwrap();
        assert_eq!(r.system("s").unwrap().memory_gb(), 8.0);
    }

    #[test]
    fn validation_rejects_dangling_refs() {
        assert!(parse("deploy ghost 2\n").is_err());
        let bad = "\
system s (
  net_interface.0.connection = 'nowhere'
)
deploy s 1
";
        assert!(parse(bad).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("system s (\n cpu.count ? 2\n)\n").is_err());
        assert!(parse("system s (\n").is_err()); // unbalanced
        assert!(parse("frobnicate x\n").is_err());
        assert!(parse("deploy s notanumber\n").is_err());
    }

    #[test]
    fn comments_ignored() {
        let r = parse("# header\nnetwork private () # trailing\n").unwrap();
        assert_eq!(r.networks.len(), 1);
    }
}
