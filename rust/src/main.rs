//! `evhc` — CLI for the Elastic Virtual Hybrid Cluster reproduction.
//!
//! Subcommands:
//!   usecase    run the paper's §4 scenario (figures + tables to results/)
//!   deploy     deploy a cluster from a TOSCA template and run a workload
//!   templates  list the built-in curated TOSCA templates
//!   verify     golden-check the AOT artifacts against the PJRT runtime
//!   infer      classify one synthetic audio file through the hot path

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig};
use evhc::sim::SimTime;
use evhc::util::cli::Command;
use evhc::util::csv::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let (sub, rest) = match args.split_first() {
        Some((s, rest)) => (s.as_str(), rest),
        None => ("help", &[][..]),
    };
    match sub {
        "usecase" => usecase(rest),
        "deploy" => deploy(rest),
        "templates" => templates(),
        "verify" => verify(rest),
        "infer" => infer(rest),
        "serve" => serve(rest),
        "orchent" => orchent(rest),
        "help" | "--help" | "-h" => {
            println!(
                "evhc — elastic virtual hybrid clusters across cloud sites\n\
                 \nUSAGE:\n  evhc <usecase|deploy|templates|verify|infer|\
serve|orchent> [options]\n\nRun `evhc <subcommand> --help` for details."
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown subcommand {other:?} (try `evhc help`)"),
    }
}

fn usecase_cmd() -> Command {
    Command::new("evhc usecase", "run the paper's §4 hybrid use case")
        .opt("scale", "F", Some("1.0"), "workload scale (1.0 = 3,676 jobs)")
        .opt("seed", "N", Some("42"), "simulation seed")
        .opt("infer-every", "N", Some("0"),
             "run real PJRT inference for 1/N jobs (0 = off)")
        .opt("out", "DIR", Some("results"), "output directory for figures")
        .flag("parallel", "parallel orchestrator updates (ablation)")
        .flag("no-flap", "disable the vnode-5 transient failure injection")
        .flag("verbose", "log milestones as they happen")
}

fn usecase(args: &[String]) -> anyhow::Result<()> {
    let p = usecase_cmd().parse(args)?;
    evhc::util::logging::init(if p.flag("verbose") { 1 } else { 0 });
    let scale: f64 = p.get_parsed("scale")?;
    let mut cfg = RunConfig::paper_usecase(scale, p.get_parsed("seed")?);
    cfg.inference_every = p.get_parsed("infer-every")?;
    cfg.serialized_orchestrator = !p.flag("parallel");
    if !p.flag("no-flap") {
        cfg.injections = InjectionPlan {
            transient_downs: vec![TransientDown {
                node_name: "vnode-5".into(),
                start: SimTime(4800.0 * scale.max(0.02)),
                duration_secs: 300.0,
            }],
        };
    }
    let total = cfg.workload.total_jobs();
    let report = HybridCluster::new(cfg)?.run()?;

    for (t, m) in &report.recorder.milestones {
        println!("{t} {m}");
    }
    let outdir = p.get_or("out", "results");
    std::fs::create_dir_all(outdir)?;
    report
        .recorder
        .fig10_usage(120.0, report.makespan)
        .write(format!("{outdir}/fig10_usage.csv"))?;
    report
        .recorder
        .fig11_states(120.0, report.makespan)
        .write(format!("{outdir}/fig11_states.csv"))?;
    let mut cost = Table::new(vec!["vm", "site", "role", "hours",
                                   "busy_hours", "cost_usd"]);
    for r in &report.per_vm {
        cost.push(vec![r.name.clone(), r.site.clone(),
                       format!("{:?}", r.role), format!("{:.3}", r.hours),
                       format!("{:.3}", r.busy_hours),
                       format!("{:.4}", r.cost_usd)]);
    }
    cost.write(format!("{outdir}/cost_table.csv"))?;

    println!("\njobs {}/{} | makespan {} | cost ${:.2} | paid util {:.0}% \
              | {} events in {:.2}s",
             report.jobs_completed, total, report.makespan,
             report.total_cost_usd, report.paid_utilization() * 100.0,
             report.events, report.wall_secs);
    if report.inferences_run > 0 {
        println!("PJRT: {} inferences, {:.1} ms mean",
                 report.inferences_run,
                 report.inference_wall_secs * 1e3
                     / report.inferences_run as f64);
    }
    println!("figures written to {outdir}/");
    Ok(())
}

fn deploy_cmd() -> Command {
    Command::new("evhc deploy", "deploy a cluster from a TOSCA template")
        .opt("template", "NAME|PATH", Some("slurm"),
             "built-in template name or path to a TOSCA YAML file")
        .opt("scale", "F", Some("0.1"), "workload scale")
        .opt("seed", "N", Some("1"), "simulation seed")
        .flag("verbose", "log milestones")
}

fn deploy(args: &[String]) -> anyhow::Result<()> {
    let p = deploy_cmd().parse(args)?;
    evhc::util::logging::init(if p.flag("verbose") { 1 } else { 0 });
    let tpl_arg = p.get_or("template", "slurm");
    let template = if std::path::Path::new(tpl_arg).exists() {
        evhc::tosca::parse(&std::fs::read_to_string(tpl_arg)?)?
    } else {
        evhc::tosca::builtin(tpl_arg)?
    };
    println!("deploying {:?} ({} on {}, {} initial / {} max workers)",
             template.name, template.description, template.lrms.name(),
             template.scalable.count, template.scalable.max_instances);
    let mut cfg = RunConfig::paper_usecase(p.get_parsed("scale")?,
                                           p.get_parsed("seed")?);
    cfg.template = template;
    let report = HybridCluster::new(cfg)?.run()?;
    for (t, m) in &report.recorder.milestones {
        println!("{t} {m}");
    }
    println!("\njobs {} | makespan {} | cost ${:.2}",
             report.jobs_completed, report.makespan,
             report.total_cost_usd);
    Ok(())
}

fn templates() -> anyhow::Result<()> {
    for name in ["slurm", "htcondor"] {
        let t = evhc::tosca::builtin(name)?;
        println!("{name:<10} {} — {} (workers {}..{}, cipher {})",
                 t.name, t.description, t.scalable.min_instances,
                 t.scalable.max_instances, t.vpn_cipher.name());
    }
    Ok(())
}

fn verify(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("evhc verify",
                           "golden-check artifacts against the runtime")
        .opt("artifacts", "DIR", Some("artifacts"), "artifacts directory");
    let p = cmd.parse(args)?;
    let dir = p.get_or("artifacts", "artifacts");
    for entry in evhc::runtime::read_manifest(std::path::Path::new(dir))? {
        let rt = evhc::runtime::ModelRuntime::load(dir, entry.batch)?;
        let err = rt.verify_golden()?;
        println!("{}: OK (|Δ|={err:.2e}, {} params)", entry.name,
                 entry.param_count);
    }
    Ok(())
}

fn infer(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("evhc infer",
                           "classify one synthetic audio file")
        .opt("file-id", "N", Some("0"), "synthetic file id")
        .opt("artifacts", "DIR", Some("artifacts"), "artifacts directory")
        .opt("top", "K", Some("5"), "show top-K classes");
    let p = cmd.parse(args)?;
    let rt = evhc::runtime::ModelRuntime::load(
        p.get_or("artifacts", "artifacts"), 1)?;
    let t0 = std::time::Instant::now();
    let logits = rt.infer_file(p.get_parsed("file-id")?)?;
    let dt = t0.elapsed();
    let k: usize = p.get_parsed("top")?;
    println!("inference in {dt:?}; top-{k} classes:");
    for (cls, logit) in evhc::runtime::ModelRuntime::top_k(&logits, k) {
        println!("  class {cls:>3}  logit {logit:>8.3}");
    }
    Ok(())
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("evhc serve",
                           "run the Orchestrator REST API server")
        .opt("bind", "ADDR", Some("127.0.0.1:8080"), "listen address");
    let p = cmd.parse(args)?;
    evhc::util::logging::init(1);
    let srv = evhc::api::ApiServer::start(p.get_or("bind",
                                                   "127.0.0.1:8080"))?;
    println!("orchestrator API listening on http://{}", srv.addr);
    println!("endpoints: /health /templates /deployments");
    println!("Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn orchent(args: &[String]) -> anyhow::Result<()> {
    // orchent-style client: depls / show / create / delete over the API.
    let cmd = Command::new("evhc orchent",
                           "orchent-style client for the REST API")
        .opt("url", "URL", Some("127.0.0.1:8080"), "server host:port")
        .opt("template", "NAME", Some("slurm"),
             "template for `create` (built-in name)")
        .positional("action", "one of: depls, show, create, delete")
        .positional("id", "deployment id (for show/delete)");
    let p = cmd.parse(args)?;
    let host = p.get_or("url", "127.0.0.1:8080");
    let action = p.positional(0).unwrap_or("depls");
    use std::io::{Read, Write};
    let send = |req: String| -> anyhow::Result<String> {
        let mut s = std::net::TcpStream::connect(host)?;
        s.write_all(req.as_bytes())?;
        let mut buf = String::new();
        s.read_to_string(&mut buf)?;
        Ok(buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
    };
    let body = match action {
        "depls" => send(format!(
            "GET /deployments HTTP/1.1\r\nHost: {host}\r\nConnection: \
             close\r\n\r\n"))?,
        "show" => {
            let id = p.positional(1).unwrap_or("1");
            send(format!(
                "GET /deployments/{id} HTTP/1.1\r\nHost: {host}\r\n\
                 Connection: close\r\n\r\n"))?
        }
        "create" => {
            let tosca = match p.get_or("template", "slurm") {
                "htcondor" => evhc::tosca::HTCONDOR_ELASTIC_TEMPLATE,
                _ => evhc::tosca::SLURM_ELASTIC_TEMPLATE,
            };
            send(format!(
                "POST /deployments HTTP/1.1\r\nHost: {host}\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{tosca}",
                tosca.len()))?
        }
        "delete" => {
            let id = p.positional(1).unwrap_or("1");
            send(format!(
                "DELETE /deployments/{id} HTTP/1.1\r\nHost: {host}\r\n\
                 Connection: close\r\n\r\n"))?
        }
        other => anyhow::bail!("unknown action {other:?}"),
    };
    // Pretty-print through the JSON parser.
    match evhc::api::json::parse(&body) {
        Ok(v) => println!("{}", v.render()),
        Err(_) => println!("{body}"),
    }
    Ok(())
}
