//! In-tree substitute for the `anyhow` crate (the build environment is
//! offline, so the real crate cannot be fetched; see the workspace
//! ROADMAP). Implements exactly the API surface this workspace uses:
//!
//! * [`Result`] / [`Error`] — a single-message error type,
//! * [`anyhow!`] / [`bail!`] — message construction / early return,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (any error convertible into [`Error`], including [`Error`] itself)
//!   and on `Option`.
//!
//! Divergence from the real crate: the cause chain is flattened eagerly
//! into one `"context: cause"` string instead of being kept as a linked
//! chain, and `downcast`/backtraces are unsupported (unused here).

use std::fmt;

/// `Result<T, anyhow::Error>` (the error type defaults like upstream).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: every std error converts into `Error`. This is
// coherent with the reflexive `From<Error> for Error` because `Error`
// itself deliberately does NOT implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let cause = e.into();
                Err(Error { msg: format!("{context}: {cause}") })
            }
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let cause = e.into();
                Err(Error { msg: format!("{}: {}", f(), cause) })
            }
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert!(format!("{e:?}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");

        // Context on an already-anyhow Result (nesting).
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
        let x = 3;
        assert_eq!(anyhow!("x={x}").to_string(), "x=3");
    }
}
