//! FIG9 — workload timeline (paper Figure 9).
//!
//! Regenerates the four-block submission timeline and benches the
//! synthetic-clip generator that stands in for the UrbanSound8K files.

use evhc::util::bench::{bench_case, section};
use evhc::util::csv::Table;
use evhc::workload::{synth_clip, Workload, TOTAL_FILES};

fn main() {
    section("FIG9: workload timeline (four blocks, Fig. 9)");
    let w = Workload::paper(1.0);
    let mut t = Table::new(vec!["block", "submit_at", "jobs"]);
    for (i, b) in w.blocks.iter().enumerate() {
        t.push(vec![format!("{}", i + 1), b.at.hms(),
                    format!("{}", b.jobs)]);
    }
    print!("{}", t.to_text());
    assert_eq!(w.total_jobs(), TOTAL_FILES);
    println!("total jobs: {} (paper: 3,676 audio files, 2.8 GB)",
             w.total_jobs());

    section("synthetic audio generator (UrbanSound8K stand-in)");
    let mut sink = 0f32;
    bench_case("synth_clip (96x257 spectrogram)", 3, 20, || {
        let c = synth_clip(123);
        sink += c[0];
    });
    std::hint::black_box(sink);

    let _ = std::fs::create_dir_all("results");
    t.write("results/fig9_workload.csv").expect("write");
    println!("\nwrote results/fig9_workload.csv");
}
