//! X-TOPO — overlay topology study: simple star (Fig. 5), redundant star
//! (Fig. 6), stand-alone nodes (Fig. 7), and the future-work
//! shortest-path extension (§5).

use evhc::netsim::{Cipher, LinkSpec, Network, NetId};
use evhc::sim::SimTime;
use evhc::util::bench::section;
use evhc::util::csv::Table;
use evhc::util::stats::mean;
use evhc::vrouter::Overlay;

/// Build an N-site mesh underlay.
fn mesh(n: usize) -> (Network, Vec<NetId>) {
    let mut net = Network::new();
    let ids: Vec<NetId> = (0..n)
        .map(|i| net.add_location(&format!("site{i}")))
        .collect();
    for i in 0..n {
        for j in (i + 1)..n {
            // Mix of continental and transatlantic links.
            let spec = if (i + j) % 3 == 0 {
                LinkSpec::transatlantic()
            } else {
                LinkSpec::wan()
            };
            net.set_link(ids[i], ids[j], spec);
        }
    }
    (net, ids)
}

/// All-pairs mean latency between site routers.
fn mean_latency(ov: &Overlay, net: &Network, names: &[String]) -> f64 {
    let mut lats = Vec::new();
    for a in names {
        for b in names {
            if a != b {
                lats.push(ov.latency(net, a, b).unwrap());
            }
        }
    }
    mean(&lats) * 1e3
}

fn main() {
    let n_sites = 6;
    let (net, ids) = mesh(n_sites);

    section("X-TOPO: star vs redundant star vs shortest-path (6 sites)");
    let mut t = Table::new(vec!["topology", "mean_pair_latency_ms",
                                "public_ips", "survives_cp_failure"]);

    // --- simple star (Fig. 5) -------------------------------------------
    let mut star = Overlay::new(Cipher::Aes256Gcm);
    star.add_central_point("cp0", ids[0], 0x0A000000, SimTime(0.0))
        .unwrap();
    let mut names = Vec::new();
    for (i, &loc) in ids.iter().enumerate().skip(1) {
        let name = format!("vr{i}");
        star.add_site_router(&name, loc, 0x0A000000 + ((i as u32) << 8),
                             SimTime(1.0)).unwrap();
        names.push(name);
    }
    let star_lat = mean_latency(&star, &net, &names);
    t.push(vec!["star (Fig. 5)".into(), format!("{star_lat:.1}"),
                "1".into(), "no".into()]);

    // --- redundant star (Fig. 6) -----------------------------------------
    let mut red = Overlay::new(Cipher::Aes256Gcm);
    red.add_central_point("cp0", ids[0], 0x0A000000, SimTime(0.0)).unwrap();
    red.add_central_point("cp1", ids[1], 0x0A000100, SimTime(0.0)).unwrap();
    let mut rnames = Vec::new();
    for (i, &loc) in ids.iter().enumerate().skip(2) {
        let name = format!("vr{i}");
        red.add_site_router(&name, loc, 0x0A000000 + ((i as u32) << 8),
                            SimTime(1.0)).unwrap();
        rnames.push(name);
    }
    let red_lat = mean_latency(&red, &net, &rnames);
    // Fail the primary: connectivity must survive via the backup.
    let rehomed = red.fail_central_point("cp0", SimTime(100.0)).unwrap();
    let survives = rnames.iter().all(|a| rnames.iter()
        .all(|b| red.is_connected(a, b)));
    t.push(vec!["redundant star (Fig. 6)".into(), format!("{red_lat:.1}"),
                "2".into(),
                format!("yes ({} re-homed)", rehomed.len())]);
    assert!(survives);

    // --- shortest-path extension (§5 future work) -------------------------
    let mut sp = Overlay::new(Cipher::Aes256Gcm);
    sp.add_central_point("cp0", ids[0], 0x0A000000, SimTime(0.0)).unwrap();
    let mut snames = Vec::new();
    for (i, &loc) in ids.iter().enumerate().skip(1) {
        let name = format!("vr{i}");
        sp.add_site_router(&name, loc, 0x0A000000 + ((i as u32) << 8),
                           SimTime(1.0)).unwrap();
        snames.push(name);
    }
    sp.shortest_path = true;
    let sp_lat = mean_latency(&sp, &net, &snames);
    t.push(vec!["star + shortest-path (§5)".into(), format!("{sp_lat:.1}"),
                "1".into(), "no".into()]);

    print!("{}", t.to_text());
    let _ = std::fs::create_dir_all("results");
    t.write("results/topology.csv").unwrap();

    // Shape: direct tunnels strictly beat the star detour.
    assert!(sp_lat < star_lat,
            "shortest-path must cut latency ({sp_lat} !< {star_lat})");

    section("stand-alone nodes (Fig. 7): star + 2 standalone clients");
    let mut sa = Overlay::new(Cipher::Aes256Gcm);
    sa.add_central_point("cp0", ids[0], 0x0A000000, SimTime(0.0)).unwrap();
    sa.add_site_router("vr1", ids[1], 0x0A000100, SimTime(1.0)).unwrap();
    sa.add_standalone("workstation", ids[2], SimTime(2.0)).unwrap();
    sa.add_standalone("legacy-node", ids[3], SimTime(3.0)).unwrap();
    for (a, b) in [("workstation", "vr1"), ("workstation", "legacy-node"),
                   ("legacy-node", "cp0")] {
        let lat = sa.latency(&net, a, b).unwrap() * 1e3;
        println!("  {a:>12} → {b:<12} {lat:6.1} ms  via {:?}",
                 sa.element_path(a, b).unwrap());
        assert!(sa.is_connected(a, b));
    }
    println!("\nwrote results/topology.csv");
}
