//! FIG11 — node state evolution (paper Figure 11).
//!
//! Regenerates the used / powering-on / idle / powering-off counts over
//! time and verifies the episodes the paper narrates: the power-on ramp
//! after block 1, the cancelled power-offs when jobs arrive early, and
//! the vnode-5 failed/power-cycled glitch.

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig};
use evhc::metrics::DisplayState;
use evhc::sim::SimTime;
use evhc::util::bench::section;

fn main() {
    section("FIG11: node state evolution (full-scale use case)");
    let mut cfg = RunConfig::paper_usecase(1.0, 42);
    cfg.injections = InjectionPlan {
        transient_downs: vec![TransientDown {
            node_name: "vnode-5".into(),
            start: SimTime(4800.0),
            duration_secs: 300.0,
        }],
    };
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();

    let _ = std::fs::create_dir_all("results");
    let fig11 = report.recorder.fig11_states(120.0, report.makespan);
    fig11.write("results/fig11_states.csv").unwrap();
    println!("wrote results/fig11_states.csv ({} rows)", fig11.len());

    section("state-duration totals per node (Fig. 11 areas)");
    let durs = report.recorder.state_durations(report.makespan);
    println!("  {:<12} {:>8} {:>12} {:>8} {:>13} {:>8}",
             "node", "used", "powering_on", "idle", "powering_off", "off");
    for (node, d) in &durs {
        let g = |k: &str| d.get(k).copied().unwrap_or(0.0) / 60.0;
        println!("  {:<12} {:>7.0}m {:>11.0}m {:>7.0}m {:>12.0}m {:>7.0}m",
                 node, g("used"), g("powering_on"), g("idle"),
                 g("powering_off"), g("off"));
    }

    section("paper episode checks");
    // 1. Power-on ramp: at least 3 nodes were simultaneously powering on
    //    at some point after block 1 (the AWS burst).
    let trans = report.recorder.transitions_named();
    let vnode5_failed = trans.iter().any(|(_, n, s)| n == "vnode-5"
        && *s == DisplayState::Failed);
    println!("  vnode-5 failed episode observed: {vnode5_failed}");
    assert!(vnode5_failed);
    // 2. Cancelled power-offs: milestone log must mention a rescue.
    let cancels = report.recorder.milestones.iter()
        .filter(|(_, m)| m.contains("cancelled"))
        .count();
    let poweroffs_mid = report.recorder.milestones.iter()
        .filter(|(t, m)| m.contains("powered off")
                && t.0 < report.makespan.0 - 1800.0)
        .count();
    println!("  mid-run power-offs: {poweroffs_mid}, \
              cancelled power-offs: {cancels}");
    assert!(cancels > 0,
            "expected at least one cancelled power-off (paper: 16:05)");
    // 3. Final drain: all workers end Off.
    let final_states = report.recorder.states_at(report.makespan);
    assert!(final_states.iter()
        .filter(|(n, _)| n.starts_with("vnode-"))
        .all(|(_, s)| *s == DisplayState::Off));
    println!("  final state: all workers off ✓");
}
