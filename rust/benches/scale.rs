//! SCALE — scheduling hot-path throughput at production scale.
//!
//! Generates synthetic HTC scenarios (1k/5k/10k nodes spread over 2–8
//! sites, 100k–1M single/dual-slot jobs in four submission blocks),
//! replays them through the discrete-event queue against the LRMS core,
//! and reports events/sec and ms per scheduling sweep. The 5k-node
//! scenario is run on both the indexed scheduler and the naive reference
//! scheduler *in the same process* so the speedup number is apples to
//! apples; results are written to `BENCH_scale.json` at the repo root so
//! future PRs accumulate a perf trajectory.
//!
//!     cargo bench --bench scale              # full suite (~10k nodes)
//!     EVHC_SCALE_BENCH_QUICK=1 cargo bench --bench scale   # CI mode

use std::time::Instant;

use evhc::api::json::Json;
use evhc::lrms::core::{BatchCore, Placement};
use evhc::lrms::JobId;
use evhc::sim::{EventQueue, SimTime};
use evhc::util::bench::section;
use evhc::util::prng::Prng;

struct Scenario {
    name: &'static str,
    nodes: u32,
    sites: u32,
    jobs: u32,
    slots_per_node: u32,
    /// Run the naive reference scheduler too (skipped at 10k nodes —
    /// O(jobs·nodes) makes it minutes-long there).
    with_naive: bool,
}

#[derive(Debug, Clone, Copy)]
struct Measured {
    events: u64,
    wall_s: f64,
    events_per_sec: f64,
    ms_per_tick: f64,
    completed: u32,
}

enum Ev {
    SubmitBlock(u32),
    JobDone(JobId),
}

/// Replay one synthetic scenario to completion on `core`.
fn run_scenario(core: &mut BatchCore, sc: &Scenario, seed: u64)
    -> Measured {
    let mut rng = Prng::new(seed);
    for i in 0..sc.nodes {
        let site = i % sc.sites;
        core.register_node(&format!("s{site}-wn-{i}"), sc.slots_per_node,
                           SimTime(0.0));
    }
    let mut q: EventQueue<Ev> = EventQueue::new();
    let blocks = 4u32;
    for b in 0..blocks {
        let n = sc.jobs / blocks
            + if b == 0 { sc.jobs % blocks } else { 0 };
        q.schedule_at(SimTime(b as f64 * 900.0), Ev::SubmitBlock(n));
    }
    let mut completed = 0u32;
    let mut ticks = 0u64;
    let mut tick_secs = 0.0;
    let wall = Instant::now();
    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::SubmitBlock(n) => {
                for i in 0..n {
                    // Mixed 1/2-slot jobs; empty name → no allocation.
                    core.submit("", 1 + (i % 2), t);
                }
            }
            Ev::JobDone(j) => {
                let _ = core.on_job_finished(j, true, t);
                completed += 1;
            }
        }
        let t0 = Instant::now();
        let assigned = core.schedule(t);
        tick_secs += t0.elapsed().as_secs_f64();
        ticks += 1;
        for (job, _node) in assigned {
            q.schedule_in(15.0 + rng.next_f64() * 5.0, Ev::JobDone(job));
        }
        if completed >= sc.jobs {
            break;
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let events = q.dispatched();
    Measured {
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s.max(1e-9),
        ms_per_tick: tick_secs * 1e3 / ticks.max(1) as f64,
        completed,
    }
}

fn measured_json(m: &Measured) -> Json {
    Json::Object(vec![
        ("events".into(), Json::Num(m.events as f64)),
        ("wall_s".into(), Json::Num(m.wall_s)),
        ("events_per_sec".into(), Json::Num(m.events_per_sec)),
        ("ms_per_tick".into(), Json::Num(m.ms_per_tick)),
        ("completed".into(), Json::Num(m.completed as f64)),
    ])
}

fn report_line(label: &str, m: &Measured) {
    println!(
        "  {label:<18} {:>12.0} ev/s  {:>9.4} ms/tick  \
         ({} events, {:.2}s wall, {} jobs)",
        m.events_per_sec, m.ms_per_tick, m.events, m.wall_s, m.completed
    );
}

fn main() {
    let quick = std::env::var("EVHC_SCALE_BENCH_QUICK").is_ok();
    let scenarios: Vec<Scenario> = if quick {
        vec![
            Scenario { name: "1k-nodes-20k-jobs", nodes: 1000, sites: 2,
                       jobs: 20_000, slots_per_node: 2, with_naive: true },
        ]
    } else {
        vec![
            Scenario { name: "1k-nodes-100k-jobs", nodes: 1000, sites: 2,
                       jobs: 100_000, slots_per_node: 2,
                       with_naive: true },
            Scenario { name: "5k-nodes-200k-jobs", nodes: 5000, sites: 4,
                       jobs: 200_000, slots_per_node: 2,
                       with_naive: true },
            Scenario { name: "10k-nodes-1M-jobs", nodes: 10_000, sites: 8,
                       jobs: 1_000_000, slots_per_node: 4,
                       with_naive: false },
        ]
    };

    section(&format!(
        "SCALE: scheduling hot path ({} mode)",
        if quick { "quick" } else { "full" }
    ));

    let mut rows = Vec::new();
    for sc in &scenarios {
        println!("\n--- {} ({} sites, {} slots/node) ---",
                 sc.name, sc.sites, sc.slots_per_node);
        let mut indexed_core = BatchCore::new(Placement::PackFirstFit);
        let indexed = run_scenario(&mut indexed_core, sc, 7);
        assert_eq!(indexed.completed, sc.jobs,
                   "indexed run must drain the workload");
        report_line("indexed", &indexed);

        let naive = if sc.with_naive {
            let mut naive_core = BatchCore::new_naive(Placement::PackFirstFit);
            let m = run_scenario(&mut naive_core, sc, 7);
            assert_eq!(m.completed, sc.jobs,
                       "naive run must drain the workload");
            report_line("naive-reference", &m);
            Some(m)
        } else {
            println!("  naive-reference    skipped (O(jobs x nodes) \
                      at this size)");
            None
        };

        let speedup = naive
            .map(|n| indexed.events_per_sec / n.events_per_sec.max(1e-9));
        if let Some(s) = speedup {
            println!("  speedup            {s:>11.1}x events/sec \
                      (indexed vs naive)");
        }

        let mut fields = vec![
            ("name".into(), Json::Str(sc.name.into())),
            ("nodes".into(), Json::Num(sc.nodes as f64)),
            ("sites".into(), Json::Num(sc.sites as f64)),
            ("jobs".into(), Json::Num(sc.jobs as f64)),
            ("slots_per_node".into(),
             Json::Num(sc.slots_per_node as f64)),
            ("indexed".into(), measured_json(&indexed)),
        ];
        if let Some(n) = &naive {
            fields.push(("naive".into(), measured_json(n)));
        }
        if let Some(s) = speedup {
            fields.push(("speedup_events_per_sec".into(), Json::Num(s)));
        }
        rows.push(Json::Object(fields));
    }

    // Spread policy spot-check so both index structures stay honest.
    section("SCALE: SpreadMostFree spot-check");
    let sc = Scenario {
        name: "spread-2k-50k",
        nodes: 2000,
        sites: 4,
        jobs: if quick { 10_000 } else { 50_000 },
        slots_per_node: 2,
        with_naive: true,
    };
    let mut spread_core = BatchCore::new(Placement::SpreadMostFree);
    let spread = run_scenario(&mut spread_core, &sc, 11);
    report_line("indexed-spread", &spread);
    let mut spread_naive_core = BatchCore::new_naive(Placement::SpreadMostFree);
    let spread_naive = run_scenario(&mut spread_naive_core, &sc, 11);
    report_line("naive-spread", &spread_naive);
    rows.push(Json::Object(vec![
        ("name".into(), Json::Str(sc.name.into())),
        ("nodes".into(), Json::Num(sc.nodes as f64)),
        ("sites".into(), Json::Num(sc.sites as f64)),
        ("jobs".into(), Json::Num(sc.jobs as f64)),
        ("slots_per_node".into(), Json::Num(sc.slots_per_node as f64)),
        ("indexed".into(), measured_json(&spread)),
        ("naive".into(), measured_json(&spread_naive)),
        ("speedup_events_per_sec".into(),
         Json::Num(spread.events_per_sec
                   / spread_naive.events_per_sec.max(1e-9))),
    ]));

    let doc = Json::Object(vec![
        ("bench".into(), Json::Str("scale".into())),
        ("quick".into(), Json::Bool(quick)),
        ("scenarios".into(), Json::Array(rows)),
    ]);
    std::fs::write("BENCH_scale.json", doc.render() + "\n")
        .expect("write BENCH_scale.json");
    println!("\nwrote BENCH_scale.json");
}
